"""Docs link check (stdlib only): every relative markdown link resolves.

Scans the repo's ``*.md`` files (top level + ``docs/``) for
``[text](target)`` links and inline-code references to repo paths, and
fails if a referenced file or directory does not exist.  External links
(``http``/``https``/``mailto``) are skipped — CI must not depend on
network reachability.  Run as ``python tools/check_docs.py`` from the repo
root.
"""
from __future__ import annotations

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
# `path/to/file.py` style inline-code refs that look like repo paths
CODE_PATH = re.compile(r"`((?:src|tests|benchmarks|examples|docs|tools|"
                       r"\.github)/[A-Za-z0-9_./\-]+)`")


def md_files(root: str) -> list[str]:
    out = [os.path.join(root, f) for f in sorted(os.listdir(root))
           if f.endswith(".md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return out


def check(root: str = ".") -> list[str]:
    failures = []
    for path in md_files(root):
        base = os.path.dirname(path)
        with open(path) as f:
            text = f.read()
        refs = [(m, base) for m in LINK.findall(text)] + \
               [(m, root) for m in CODE_PATH.findall(text)]
        for target, anchor in refs:
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = os.path.normpath(os.path.join(anchor, target))
            if not os.path.exists(resolved):
                failures.append(f"{os.path.relpath(path, root)}: "
                                f"broken reference -> {target}")
    return failures


def main() -> None:
    failures = check(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
                     or ".")
    if failures:
        print("DOCS CHECK FAIL:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("docs check ok: all markdown references resolve")


if __name__ == "__main__":
    main()
