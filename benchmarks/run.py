"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--fast`` shrinks RL training
budgets (CI); the full run reproduces EXPERIMENTS.md numbers.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig8,roofline]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--out", default="experiments/bench")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    from benchmarks import figures
    from benchmarks.roofline_table import markdown, roofline_table

    results: dict = {}
    t0 = time.time()

    def want(name: str) -> bool:
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("fig3"):
        results["fig3"] = {f"{m}@{s}": v for (m, s), v in figures.fig3_share_sweep(args.fast).items()}
    if want("fig4"):
        results["fig4"] = {f"{m}@{l}": v for (m, l), v in figures.fig4_bw_partitioning(args.fast).items()}
    if want("fig5"):
        results["fig5"] = figures.fig5_variants(args.fast)
    scheds = queues = None
    if want("fig8"):
        results["fig8"], scheds, queues = figures.fig8_throughput(args.fast)
    if want("fig11") or want("fig12") or want("fig8"):
        results["fig11_12"] = figures.fig11_12_slowdown_fairness(scheds, queues, args.fast)
    if want("fig9"):
        results["fig9"] = figures.fig9_window(args.fast)
    if want("fig10"):
        results["fig10"] = figures.fig10_cmax(args.fast)
    if want("roofline"):
        rows = roofline_table(args.fast)
        results["roofline"] = rows
        with open(os.path.join(args.out, "roofline.md"), "w") as f:
            f.write(markdown(rows))

    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"total,{(time.time()-t0)*1e6:.0f},done")


if __name__ == "__main__":
    main()
