"""One benchmark function per paper figure (Figs. 3-5 observations,
Figs. 8-12 evaluation). Each prints `name,us_per_call,derived` CSV rows and
returns a dict for EXPERIMENTS.md."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_zoo, rl_scheduler
from repro.core import POLICIES, Schedule, corun_time, solo_run_time, paper_queues
from repro.core.metrics import avg_app_slowdown, fairness, relative_throughput
from repro.core.partition import Partition, Slice, enumerate_partitions
from repro.core.workloads import zoo_by_class


def _pair_pool(zoo):
    by = zoo_by_class(zoo)
    return {
        "CI+MI": (by["CI"][0], by["MI"][0]),
        "CI+CI": (by["CI"][0], by["CI"][1]),
        "MI+MI": (by["MI"][0], by["MI"][1]),
        "CI+US": (by["CI"][0], by["US"][0]),
    }


# ---------------------------------------------------------------------------
# Fig. 3: co-run throughput vs MPS compute-share sweep
# ---------------------------------------------------------------------------

def fig3_share_sweep(fast=False):
    zoo = get_zoo()
    out = {}
    t0 = time.time()
    n = 0
    for mix, (a, b) in _pair_pool(zoo).items():
        for share in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9):
            p = Partition((Slice(8, (round(share, 2), round(1 - share, 2))),), f"mps{share}")
            tp = solo_run_time([a, b]) / corun_time([a, b], p)
            out[(mix, share)] = tp
            emit(f"fig3/{mix}/share={share:.1f}", (time.time() - t0) * 1e6 / max(1, n := n + 1), f"{tp:.4f}")
    return out


# ---------------------------------------------------------------------------
# Fig. 4: shared vs private bandwidth at equal compute allocation
# ---------------------------------------------------------------------------

def fig4_bw_partitioning(fast=False):
    zoo = get_zoo()
    out = {}
    t0 = time.time()
    n = 0
    shared_half = Partition((Slice(8, (0.5, 0.5)),), "shared")          # one domain
    private_half = Partition((Slice(4, (1.0,)), Slice(4, (1.0,))), "private")
    for mix, (a, b) in _pair_pool(zoo).items():
        for label, p in (("shared", shared_half), ("private", private_half)):
            tp = solo_run_time([a, b]) / corun_time([a, b], p)
            out[(mix, label)] = tp
            emit(f"fig4/{mix}/{label}", (time.time() - t0) * 1e6 / max(1, n := n + 1), f"{tp:.4f}")
    return out


# ---------------------------------------------------------------------------
# Fig. 5: partitioning-variant comparison for a 4-job mix
# ---------------------------------------------------------------------------

def fig5_variants(fast=False):
    # mix with scale-heterogeneous jobs (the hierarchical option's home turf:
    # right-sizing slices for US jobs while big jobs share the rest)
    zoo = get_zoo()
    by = zoo_by_class(zoo)
    jobs = [by["CI"][0], by["MI"][0], by["US"][0], by["US"][-1]]
    styles = {"mps": [], "mig": [], "hier": []}
    for p in enumerate_partitions(4):
        if p.style in styles:
            styles[p.style].append(p)
    out = {}
    t0 = time.time()
    n = 0
    for style, parts in styles.items():
        best = 0.0
        for p in parts:
            from repro.core.baselines import exhaustive_schedule

            sched = exhaustive_schedule(jobs, 4, parts)
            best = max(best, relative_throughput(sched))
            break  # exhaustive_schedule already optimizes within the style
        out[style] = best
        emit(f"fig5/{style}", (time.time() - t0) * 1e6 / max(1, n := n + 1), f"{best:.4f}")
    return out


# ---------------------------------------------------------------------------
# Fig. 8: throughput, 5 methods x 12 queues
# ---------------------------------------------------------------------------

METHODS = ("time_sharing", "mig_only", "mps_only", "mig_mps_default", "rl", "oracle")


def _method_schedules(queues, zoo, window, c_max, fast):
    sched_rl, env_cfg = rl_scheduler(zoo, window, c_max, fast)
    all_scheds: dict[str, dict[str, Schedule]] = {m: {} for m in METHODS}
    for qname, queue in queues.items():
        for m in METHODS:
            if m == "rl":
                all_scheds[m][qname] = sched_rl.schedule(queue)
            else:
                all_scheds[m][qname] = POLICIES[m](queue, c_max)
    return all_scheds


def fig8_throughput(fast=False, window=12, c_max=4):
    zoo = get_zoo()
    queues = paper_queues(zoo, window=window, per_kind=3)
    t0 = time.time()
    scheds = _method_schedules(queues, zoo, window, c_max, fast)
    out = {}
    for m in METHODS:
        tps = [relative_throughput(s) for s in scheds[m].values()]
        out[m] = {"per_queue": tps, "am": float(np.mean(tps)), "max": float(np.max(tps))}
        emit(f"fig8/{m}/AM", (time.time() - t0) * 1e6 / len(queues), f"{out[m]['am']:.4f}")
        emit(f"fig8/{m}/max", 0.0, f"{out[m]['max']:.4f}")
    return out, scheds, queues


# ---------------------------------------------------------------------------
# Fig. 9 / Fig. 10: window and Cmax scaling
# ---------------------------------------------------------------------------

def fig9_window(fast=False):
    zoo = get_zoo()
    out = {}
    t0 = time.time()
    for w in ((4, 8, 12) if fast else (4, 8, 12, 16)):
        queues = paper_queues(zoo, window=w, per_kind=1)
        sched_rl, _ = rl_scheduler(zoo, w, 4, fast, episodes=800)
        tps = [relative_throughput(sched_rl.schedule(q)) for q in queues.values()]
        out[w] = float(np.mean(tps))
        emit(f"fig9/W={w}", (time.time() - t0) * 1e6, f"{out[w]:.4f}")
    return out


def fig10_cmax(fast=False):
    zoo = get_zoo()
    out = {}
    t0 = time.time()
    for c in (2, 3, 4):
        queues = paper_queues(zoo, window=12, per_kind=1)
        sched_rl, _ = rl_scheduler(zoo, 12, c, fast, episodes=800)
        tps = [relative_throughput(sched_rl.schedule(q)) for q in queues.values()]
        out[c] = float(np.mean(tps))
        emit(f"fig10/Cmax={c}", (time.time() - t0) * 1e6, f"{out[c]:.4f}")
    return out


# ---------------------------------------------------------------------------
# Fig. 11 / Fig. 12: slowdown and fairness (reuse fig8 schedules)
# ---------------------------------------------------------------------------

def fig11_12_slowdown_fairness(scheds=None, queues=None, fast=False):
    if scheds is None:
        _, scheds, queues = fig8_throughput(fast=fast)
    out = {}
    for m in METHODS:
        slows = [avg_app_slowdown(s) for s in scheds[m].values()]
        fairs = [fairness(s) for s in scheds[m].values()]
        out[m] = {"avg_slowdown": float(np.mean(slows)), "best_slowdown": float(np.min(slows)),
                  "fairness": float(np.mean(fairs))}
        emit(f"fig11/{m}/avg_slowdown", 0.0, f"{out[m]['avg_slowdown']:.4f}")
        emit(f"fig12/{m}/fairness", 0.0, f"{out[m]['fairness']:.4f}")
    return out
