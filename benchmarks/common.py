"""Shared benchmark utilities: cached agent training, CSV emission."""
from __future__ import annotations

import os
import time


from repro import checkpoint as ck
from repro.core import DQNAgent, EnvConfig, RLScheduler, TrainConfig, make_zoo, train_agent
from repro.core.agent import DQNConfig
from repro.core.env import CoScheduleEnv

AGENT_DIR = "experiments/agents"
DRYRUN_DIR = "experiments/dryrun"


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def missing_keys(path: str, required) -> list[str]:
    """Keys absent from a committed BENCH json (all of them if no file) —
    the shared --smoke guard check."""
    import json

    if not os.path.exists(path):
        return list(required)
    with open(path) as f:
        data = json.load(f)
    return [k for k in required if k not in data]


def get_zoo():
    return make_zoo(dryrun_dir=DRYRUN_DIR if os.path.isdir(DRYRUN_DIR) else None)


def trained_agent(zoo, window: int = 12, c_max: int = 4, episodes: int = 2000,
                  fast: bool = False, tag: str = "") -> tuple[DQNAgent, EnvConfig]:
    """Train (or load cached) DQN agent for a (window, c_max) setting."""
    if fast:
        episodes = min(episodes, 400)
    env_cfg = EnvConfig(window=window, c_max=c_max)
    env = CoScheduleEnv(env_cfg)
    cache = os.path.join(AGENT_DIR, f"w{window}_c{c_max}_e{episodes}{tag}")
    agent = DQNAgent(env.state_dim, env.n_actions, DQNConfig(), seed=0)
    try:
        tree, extra, _ = ck.restore(cache)
        import jax.numpy as jnp

        agent.params = {k: jnp.asarray(v) for k, v in tree["params"].items()}
        agent.target_params = agent.params
        agent.env_steps = int(extra.get("env_steps", 10**9))
        return agent, env_cfg
    except FileNotFoundError:
        pass
    t0 = time.time()
    agent, _ = train_agent(
        zoo, env_cfg,
        TrainConfig(episodes=episodes,
                    eval_every=max(100, episodes // 4),
                    dqn=DQNConfig(eps_decay_steps=max(1500, episodes * 7))),
    )
    ck.save(cache, episodes, {"params": agent.params}, extra={"env_steps": agent.env_steps},
            keep_last=1)
    emit(f"train_agent_w{window}", (time.time() - t0) * 1e6 / max(1, episodes), "cached")
    return agent, env_cfg


def rl_scheduler(zoo, window=12, c_max=4, fast=False, episodes=3000) -> tuple[RLScheduler, EnvConfig]:
    agent, env_cfg = trained_agent(zoo, window, c_max, episodes=episodes, fast=fast)
    return RLScheduler(agent, env_cfg), env_cfg
