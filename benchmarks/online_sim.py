"""Online cluster benchmark: policies under multi-tenant arrival traces.

Serves identical arrival traces (Poisson / bursty MMPP / diurnal /
heavy-tailed job scales / fragmentation-stressing right-sized widths)
through the event-driven cluster simulator with each dispatch policy, and
writes ``BENCH_online.json`` — the online-phase trajectory future PRs
regress against.  The headline figures are makespan-derived throughput
ratios vs the time-sharing baseline (the paper's Fig. 8 metric, streamed:
up to 1.87x in the paper's queues); the RL policy runs twice, once frozen
and once with MISO-style periodic re-training against the live profile
repository.

Every trace family is additionally served under both dispatch modes —
slice-level **concurrent + backfill** (the default) vs the PR-3
**blocking-window** pod — with the same frozen policies, and the
``concurrent_vs_blocking`` throughput ratios land in the
``dispatch_comparison`` section: 1.0 on full-pod-only families (the modes
are bit-compatible there) and strictly above 1.0 on the fragmented family,
where right-sized jobs pack disjoint slices and small groups backfill idle
gaps.

    PYTHONPATH=src python -m benchmarks.online_sim [--fast] \
        [--out BENCH_online.json]

``--smoke`` is the CI guard (< 60 s): a tiny agent, short traces, RL with
re-training vs time sharing, plus the dispatch-mode comparison; fails
(exit 1) if the RL policy's throughput drops below ``--ratio-floor`` x
time sharing on the Poisson trace, if concurrent dispatch falls below
blocking on any smoke family, if it fails to *beat* blocking by
``--frag-margin`` on the fragmented family, or if the committed
``BENCH_online.json`` is missing required keys.  Smoke mode does not
overwrite the committed trajectory unless ``--out`` is given.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.bench_gate import CONC_BLK_FLOOR, FRAG_MARGIN
from benchmarks.common import emit, missing_keys
from repro.core import EnvConfig, TrainConfig, make_zoo, train_agent
from repro.core.agent import DQNConfig
from repro.online import (
    ClusterSimulator, GreedyPackerPolicy, OnlineRetrainer, RLDispatchPolicy,
    StaticPartitionPolicy, TRACE_FAMILIES, TimeSharingPolicy,
    default_retrain_train_config,
)

REQUIRED_KEYS = ("window", "n_arrivals", "traces", "rl_vs_time_sharing",
                 "dispatch_comparison", "note")


def _simulate(policy, trace, window, retrainer=None, mode="concurrent"):
    t0 = time.perf_counter()
    sim = ClusterSimulator(
        policy, window=window, mode=mode,
        tick_interval_s=retrainer.interval_s if retrainer else None,
        on_tick=retrainer)
    res = sim.run(trace)
    out = res.summary()
    out["sim_wall_s"] = time.perf_counter() - t0
    if retrainer is not None:
        out["retrains"] = len(retrainer.history)
        out["retrain_history"] = retrainer.history
    return out


def _bench_trace(tname, trace, agent, env_cfg, window, retrain_cfg,
                 baselines: bool):
    """All policies on one trace; fresh repositories so profiling restarts."""
    out: dict = {"arrivals": len(trace), "span_s": trace[-1].t}
    out["time_sharing"] = _simulate(TimeSharingPolicy(), trace, window)
    # dispatch-mode comparison: same frozen policies, blocking pod
    out["time_sharing_blocking"] = _simulate(TimeSharingPolicy(), trace,
                                             window, mode="blocking")
    if baselines:
        out["greedy_packer"] = _simulate(GreedyPackerPolicy(), trace, window)
        out["mig_mps_default"] = _simulate(
            StaticPartitionPolicy("mig_mps_default"), trace, window)
        out["rl"] = _simulate(RLDispatchPolicy(agent, env_cfg), trace, window)
        out["rl_blocking"] = _simulate(RLDispatchPolicy(agent, env_cfg),
                                       trace, window, mode="blocking")
    pol = RLDispatchPolicy(agent, env_cfg)
    rt = OnlineRetrainer(policy=pol, **retrain_cfg)
    out["rl_retrain"] = _simulate(pol, trace, window, retrainer=rt)
    ts_tp = out["time_sharing"]["throughput"]
    for name in ("greedy_packer", "mig_mps_default", "rl", "rl_retrain"):
        if name in out:
            out[f"{name}_vs_time_sharing"] = out[name]["throughput"] / ts_tp
    cvb = {"time_sharing": (out["time_sharing"]["throughput"]
                            / out["time_sharing_blocking"]["throughput"])}
    if "rl_blocking" in out:
        cvb["rl"] = out["rl"]["throughput"] / out["rl_blocking"]["throughput"]
    out["concurrent_vs_blocking"] = cvb
    emit(f"online_{tname}", out["rl_retrain"]["sim_wall_s"] * 1e6,
         f"rl_rt/ts={out['rl_retrain_vs_time_sharing']:.3f} "
         f"conc/blk={cvb['time_sharing']:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="shrink the full run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: tiny counts, ratio floors + key check")
    ap.add_argument("--ratio-floor", type=float, default=0.98,
                    help="min rl_retrain/time_sharing throughput in --smoke")
    ap.add_argument("--frag-margin", type=float, default=FRAG_MARGIN,
                    help="min concurrent/blocking throughput on the "
                         "fragmented family in --smoke (shared with "
                         "benchmarks.bench_gate)")
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--arrivals", type=int, default=None)
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--load", type=float, default=1.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retrain-interval-min", type=float, default=None)
    ap.add_argument("--bench-json", default="BENCH_online.json",
                    help="committed trajectory checked for keys in --smoke")
    ap.add_argument("--out", default=None,
                    help="where to write results (default BENCH_online.json; "
                         "smoke mode writes nothing unless given)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        window = args.window or 6
        episodes = args.episodes or 120
        n = args.arrivals or 32
        families = ("poisson", "fragmented", "mmpp")
        interval_min = args.retrain_interval_min or 40.0
        retrain_episodes = 80
    else:
        window = args.window or 8
        episodes = args.episodes or (600 if args.fast else 1500)
        n = args.arrivals or (60 if args.fast else 120)
        families = tuple(TRACE_FAMILIES)
        interval_min = args.retrain_interval_min or 30.0
        retrain_episodes = 240

    zoo = make_zoo(dryrun_dir=None)
    env_cfg = EnvConfig(window=window, c_max=4)
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    agent, hist = train_agent(
        zoo, env_cfg,
        TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                    dqn=DQNConfig(eps_decay_steps=episodes * 6)))
    emit("online_train_agent", (time.perf_counter() - t0) * 1e6 / episodes,
         f"tp={hist[-1]['eval_throughput']:.3f}")
    retrain_cfg = {
        "train_cfg": default_retrain_train_config(retrain_episodes),
        "interval_s": interval_min * 60.0,
        "min_jobs": 4,
    }

    traces = {}
    for i, fam in enumerate(families):
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=args.load,
                                    seed=args.seed + i)
        traces[fam] = _bench_trace(fam, trace, agent, env_cfg, window,
                                   retrain_cfg, baselines=not args.smoke)

    rl_vs_ts = {t: traces[t]["rl_retrain_vs_time_sharing"] for t in traces}
    dispatch_cmp = {t: traces[t]["concurrent_vs_blocking"] for t in traces}
    frag = traces.get("fragmented", {})
    result = {
        "window": window,
        "n_arrivals": n,
        "load": args.load,
        "seed": args.seed,
        "train_episodes": episodes,
        "retrain": {"interval_min": interval_min,
                    "episodes": retrain_episodes},
        "traces": traces,
        "rl_vs_time_sharing": rl_vs_ts,
        "dispatch_comparison": dispatch_cmp,
        "acceptance": {
            "poisson_arrivals": traces.get("poisson", {}).get("arrivals", 0),
            "rl_retrain_beats_time_sharing_on_poisson":
                rl_vs_ts.get("poisson", 0.0) > 1.0,
            "concurrent_ge_blocking_all_families":
                all(min(r.values()) >= CONC_BLK_FLOOR
                    for r in dispatch_cmp.values()),
            "concurrent_strictly_beats_blocking_on_fragmented":
                frag.get("concurrent_vs_blocking",
                         {}).get("time_sharing", 0.0) > 1.0,
            "fragmented_backfills":
                frag.get("time_sharing", {}).get("backfills", 0),
        },
        "note": ("throughput = total solo work / makespan (time sharing ~1.0 "
                 "on a saturated pod); *_vs_time_sharing are ratios of that "
                 "metric on identical traces; rl_retrain re-trains the agent "
                 "on the live profile repository every interval_min simulated "
                 "minutes, warm-started from current params, and hot-swaps "
                 "it; all policies pay the same first-sight profiling cost "
                 "(unprofiled jobs run solo); dispatch_comparison = "
                 "concurrent-dispatch/blocking-window throughput per policy "
                 "on identical traces — 1.0 where placements are full-pod "
                 "(bit-compatible modes), >1.0 on the fragmented family "
                 "where right-sized jobs pack disjoint slices and backfill "
                 "idle gaps; slice_utilization/idle_slice_frac in each "
                 "summary are claimed-unit-seconds over N_UNITS x makespan"),
    }

    if args.smoke:
        failures = []
        ratio = rl_vs_ts.get("poisson", 0.0)
        if ratio < args.ratio_floor:
            failures.append(f"rl_retrain/time_sharing {ratio:.3f} below "
                            f"floor {args.ratio_floor:.2f}")
        for fam, cmp_ in dispatch_cmp.items():
            worst = min(cmp_.values())
            if worst < CONC_BLK_FLOOR:
                failures.append(f"concurrent below blocking on {fam}: "
                                f"{worst:.3f}")
        frag_ratio = dispatch_cmp.get("fragmented", {}).get("time_sharing", 0.0)
        if frag_ratio < args.frag_margin:
            failures.append(f"fragmented concurrent/blocking {frag_ratio:.3f} "
                            f"below margin {args.frag_margin:.2f}")
        missing = missing_keys(args.bench_json, REQUIRED_KEYS)
        if missing:
            failures.append(f"{args.bench_json} missing keys: {missing}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"smoke": True, **result}, f, indent=1)
        if failures:
            print("SMOKE FAIL: " + "; ".join(failures))
            sys.exit(1)
        print(f"smoke ok: rl_retrain/ts {ratio:.3f} on poisson "
              f"(floor {args.ratio_floor:.2f}), fragmented conc/blk "
              f"{frag_ratio:.3f} (margin {args.frag_margin:.2f}), "
              f"{args.bench_json} keys present")
        return

    out = args.out or "BENCH_online.json"
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}: rl_retrain/ts " +
          ", ".join(f"{t}={r:.3f}" for t, r in rl_vs_ts.items()) +
          "; conc/blk " +
          ", ".join(f"{t}={r['time_sharing']:.3f}"
                    for t, r in dispatch_cmp.items()))


if __name__ == "__main__":
    main()
