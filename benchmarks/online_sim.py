"""Online cluster benchmark: policies under multi-tenant arrival traces.

Serves identical arrival traces (Poisson / bursty MMPP / diurnal /
heavy-tailed job scales / fragmentation-stressing right-sized widths)
through the event-driven cluster simulator with each dispatch policy, and
writes ``BENCH_online.json`` — the online-phase trajectory future PRs
regress against.  The headline figures are makespan-derived throughput
ratios vs the time-sharing baseline (the paper's Fig. 8 metric, streamed:
up to 1.87x in the paper's queues); the RL policy runs twice, once frozen
and once with MISO-style periodic re-training against the live profile
repository.

Every trace family is additionally served under both dispatch modes —
slice-level **concurrent + backfill** (the default) vs the PR-3
**blocking-window** pod — with the same frozen policies, and the
``concurrent_vs_blocking`` throughput ratios land in the
``dispatch_comparison`` section: 1.0 on full-pod-only families (the modes
are bit-compatible there) and strictly above 1.0 on the fragmented family,
where right-sized jobs pack disjoint slices and small groups backfill idle
gaps.

The ``arrival_aware`` section is the observation-mode comparison: a
**context-trained** agent (profiles + live cluster state — busy-unit mask,
queue ages, pending depth; see ``docs/observation.md``) vs the
**profile-only** agent vs time sharing, frozen, on every trace family.
The context agent is warm-started from the profile-only agent through
``widen_dqn_params`` (identical Q-function at zero context), so the
comparison isolates what the arrival-aware features add; the fragmented
family is the headline — the agent should recover dispatch-layer packing
gains from state alone.  ``benchmarks.bench_gate`` pins the committed
``rl_context_vs_profile_only`` ratio there.

The ``vectorized_sim`` section is the engine comparison: the in-graph
vectorized simulator (``repro.online.vecsim``, one jitted
``lax.while_loop`` per trace, ``vmap`` over a leading trace axis) vs the
Python event heap on identical solo-placement traces — single-trace wall
time both ways plus vmapped-sweep throughput (traces/sec at batch >= 64),
whose ``speedup_vs_heap`` is floored by ``benchmarks.bench_gate``.
``vectorized_rl`` is the same comparison for **RL serving**: the trained
agent's episodes run in-graph at the window-formation seam (observation
assembly + fit-masked greedy argmax inside the jitted episode) vs the
heap replaying the identical agent, plus the ``sweep(param_sets=...)``
population mode — P agents x batch traces in one device call.  The
``sim_wall`` block mirrors every policy×family cell's ``sim_wall_s`` so
the Python-vs-vectorized trend stays visible in the committed trajectory,
and ``--engine vectorized`` routes supported cells (solo-placement
policies, concurrent mode, no retrainer) through the vectorized engine —
each cell records which ``engine`` served it.

    PYTHONPATH=src python -m benchmarks.online_sim [--fast] [--profile] \
        [--out BENCH_online.json] [--engine {heap,vectorized}]
    PYTHONPATH=src python -m benchmarks.online_sim --section arrival_aware

``--profile`` records a per-phase wall-time breakdown in every heap cell
(``profile``: sim / policy / retrain seconds, plus per-family
``trace_gen_s``) so future perf PRs have a phase-level baseline.  The
``retrain_trigger`` section is the clock-vs-drift re-training A/B
(``OnlineRetrainer(trigger="drift")`` gated by the telemetry layer's
``DriftMonitor``); ``telemetry_overhead`` records the telemetry-on/off
sim-wall ratio for both engines, gated at ``TELEMETRY_OVERHEAD_MAX`` by
``benchmarks.bench_gate``.  In smoke mode ``--telemetry-artifacts DIR``
additionally serves one telemetry-enabled fleet cell and writes its
Chrome trace + events/metrics JSONL there for CI artifact upload,
cross-checking the metric aggregates against ``summary()``.

The ``queueing_reward`` section is the reward-source A/B: ``train_online``
(sim-in-the-loop training inside the vectorized engine — reward is the
engine-accumulated per-window wait/turnaround plus a makespan terminal,
with population-based training over scenario x exploration) refines the
committed proxy-trained agent, and both serve identical held-out traces
of every family; the gate requires the queueing-trained agent's p99 wait
to win on at least ``QUEUEING_WIN_FAMILIES_MIN`` of the five families.

``--section <name>`` recomputes only that section (for ``arrival_aware``,
re-training both agents deterministically from the committed run's
settings; ``vectorized_sim`` re-measures both engines; ``sim_wall``
derives from the committed ``traces`` cells) and merges it into the
committed ``BENCH_online.json`` — the incremental path for
observation-layer and engine changes.

``--smoke`` is the CI guard (< 60 s): a tiny agent, short traces, RL with
re-training vs time sharing, plus the dispatch-mode comparison and a
context-agent serve check; fails (exit 1) if the RL policy's throughput
drops below ``--ratio-floor`` x time sharing on the Poisson trace, if
concurrent dispatch falls below blocking on any smoke family, if it fails
to *beat* blocking by ``--frag-margin`` on the fragmented family, if the
context-trained agent cannot serve the fragmented smoke trace, or if the
committed ``BENCH_online.json`` is missing required keys.  Smoke mode does
not overwrite the committed trajectory unless ``--out`` is given.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
import time

from benchmarks.bench_gate import (
    ARRIVAL_FLOOR, CONC_BLK_FLOOR, FLEET_P99_FLOOR, FRAG_MARGIN,
    QUEUEING_WIN_FAMILIES_MIN, TELEMETRY_OVERHEAD_MAX, VECRL_SPEEDUP_FLOOR,
    VECSIM_SPEEDUP_FLOOR,
)
from benchmarks.common import emit, missing_keys
from repro.core import (
    CoScheduleEnv, DQNAgent, EnvConfig, TrainConfig, make_zoo, train_agent,
    widen_dqn_params,
)
from repro.core.agent import DQNConfig
from repro.core.env import context_dim
from repro.core.partition import N_UNITS
from repro.online import (
    ClusterSimulator, GreedyPackerPolicy, OnlineRetrainer, RLDispatchPolicy,
    SimConfig, StaticPartitionPolicy, TRACE_FAMILIES, Telemetry,
    TimeSharingPolicy, VectorizedClusterSimulator, VectorizedFleetSimulator,
    default_retrain_train_config,
)

REQUIRED_KEYS = ("window", "n_arrivals", "traces", "rl_vs_time_sharing",
                 "dispatch_comparison", "arrival_aware", "sim_wall",
                 "vectorized_sim", "vectorized_rl", "fleet_scale",
                 "queueing_reward", "note")

# fleet-scale grid: trace family -> pod widths (heterogeneous 4/8 fleets
# stress width eligibility and the frag router; uniform 8s isolate pure
# load balancing).  Arrival rates are capacity-scaled so `load` keeps its
# single-pod meaning across fleet shapes.
FLEET_FAMILIES = {"poisson": (8, 8, 8, 8), "fragmented": (8, 8, 4, 4)}
FLEET_ROUTERS = ("hash", "least_loaded", "frag")
FLEET_LOAD = 0.85

FLEET_NOTE = (
    "routers x {time_sharing, rl(frozen profile-only agent)} on capacity-"
    "scaled traces (load keeps its single-pod meaning: 1.0 saturates the "
    "whole fleet); headline metric is p50/p99 wait — tail latency, not "
    "makespan, is what routing moves at fleet scale; *_vs_hash_p99 > 1 "
    "means the router beats tenant-affine hashing (hash is lumpy over a "
    "small tenant pool, so load-aware routers win big at high load); "
    "vectorized_100k serves 10^5 arrivals through the vmapped pod-axis "
    "engine (hash routing is trace-computable, so the fleet splits into "
    "independent per-pod lanes); single_pod_parity re-runs each committed "
    "traces family under SimConfig(pods=(8,)) and requires key-by-key "
    "exact equality with the committed single-pod cells — the fleet "
    "refactor must not move the legacy numbers")


def _hash_split_max(trace, pods, seed=0) -> int:
    """Largest per-pod sub-stream under hash routing — sizes the
    vectorized fleet's per-lane capacity."""
    from repro.online.router import FleetView, PodView, make_router
    router = make_router("hash", seed)
    view = FleetView(pods=tuple(
        PodView(idx=i, width=w, free=(True,) * w, pending=0, ready=0,
                queue_units=0, busy_units=0) for i, w in enumerate(pods)))
    counts: dict[int, int] = {}
    for a in trace:
        p = router.route(a, view)
        counts[p] = counts.get(p, 0) + 1
    return max(counts.values())

ARRIVAL_NOTE = (
    "frozen-agent observation-mode comparison on identical traces: "
    "rl_context observes profiles + live cluster state (busy-unit mask, "
    "queue ages, pending depth — docs/observation.md) and was warm-started "
    "from rl_profile_only via widen_dqn_params (identical Q at zero "
    "context) then trained with per-episode sampled contexts and the "
    "fit-shaping term; ratios are makespan-derived throughput as "
    "everywhere else; ctx_seed seeds only the refresh's context draws and "
    "exploration (the warm start pins the starting Q-function); the "
    "fragmented family is gated by benchmarks.bench_gate "
    "(rl_context >= ARRIVAL_FLOOR x rl_profile_only)")


def _simulate(policy, trace, window, retrainer=None, mode="concurrent",
              engine="heap", profile=False):
    # the vectorized engine serves solo-placement plans in concurrent mode
    # with no periodic tick; everything else stays on the Python heap
    use_vec = (engine == "vectorized" and retrainer is None
               and mode == "concurrent"
               and VectorizedClusterSimulator.supports(policy))
    # --profile: shim the policy's decide() and the retrainer callable with
    # wall-clock accumulators so each cell splits its sim_wall_s into
    # sim / policy / retrain phases (heap cells only; the vectorized
    # engine's policy work is compiled into the graph)
    pt = None
    on_tick = retrainer
    if profile and not use_vec:
        from repro.online.telemetry import PhaseTimer
        pt = PhaseTimer()
        orig_decide = policy.decide

        def timed_decide(*a, **kw):
            t = time.perf_counter()
            try:
                return orig_decide(*a, **kw)
            finally:
                pt.add("policy_s", time.perf_counter() - t)

        policy.decide = timed_decide
        if retrainer is not None:
            def on_tick(now, sim, _rt=retrainer):
                t = time.perf_counter()
                try:
                    _rt(now, sim)
                finally:
                    pt.add("retrain_s", time.perf_counter() - t)
    t0 = time.perf_counter()
    try:
        if use_vec:
            res = VectorizedClusterSimulator(
                policy, window=window,
                capacity=max(128, 2 * len(trace))).run(trace)
        else:
            sim = ClusterSimulator(
                policy, window=window, mode=mode,
                tick_interval_s=retrainer.interval_s if retrainer else None,
                on_tick=on_tick)
            res = sim.run(trace)
    finally:
        if pt is not None:
            del policy.decide
    out = res.summary()
    out["sim_wall_s"] = time.perf_counter() - t0
    out["engine"] = "vectorized" if use_vec else "heap"
    if pt is not None:
        phases = pt.as_dict()
        phases.setdefault("policy_s", 0.0)
        phases.setdefault("retrain_s", 0.0)
        phases["sim_s"] = max(
            0.0, out["sim_wall_s"] - phases["policy_s"] - phases["retrain_s"])
        out["profile"] = phases
    if retrainer is not None:
        out["retrains"] = len(retrainer.history)
        out["retrain_history"] = retrainer.history
    return out


def _sim_wall_block(traces: dict) -> dict:
    """Per policy×family ``sim_wall_s`` lifted out of the traces section."""
    return {fam: {pol: cell["sim_wall_s"]
                  for pol, cell in fam_out.items()
                  if isinstance(cell, dict) and "sim_wall_s" in cell}
            for fam, fam_out in traces.items()}


def _fleet_cell(policy, trace, window, pods, router, seed=0):
    t0 = time.perf_counter()
    cfg = SimConfig(window=window, pods=pods, router=router,
                    router_seed=seed)
    res = ClusterSimulator(policy, cfg).run(trace)
    out = res.summary()
    out["sim_wall_s"] = time.perf_counter() - t0
    out["engine"] = "heap"
    return out


def _fleet_scale(zoo, agent, env_cfg, window, n, seed,
                 load=FLEET_LOAD, n_vec=100_000):
    """The fleet-scale grid: routers x policies per family, the 10^5
    vectorized cell, and per-family p99 ratios vs hash routing."""
    families: dict = {}
    for i, (fam, pods) in enumerate(FLEET_FAMILIES.items()):
        cap = sum(pods) / N_UNITS
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=load, seed=seed + i,
                                    capacity=cap)
        cells: dict = {}
        for router in FLEET_ROUTERS:
            cells[router] = {
                "time_sharing": _fleet_cell(TimeSharingPolicy(), trace,
                                            window, pods, router, seed),
                "rl": _fleet_cell(RLDispatchPolicy(agent, env_cfg), trace,
                                  window, pods, router, seed),
            }
            emit(f"fleet_{fam}_{router}",
                 cells[router]["rl"]["sim_wall_s"] * 1e6 / n,
                 f"ts_p99={cells[router]['time_sharing']['p99_wait_s']:.0f}s")
        ratios = {
            f"{r}_vs_hash_p99": {
                pol: (cells["hash"][pol]["p99_wait_s"]
                      / max(cells[r][pol]["p99_wait_s"], 1e-9))
                for pol in ("time_sharing", "rl")}
            for r in FLEET_ROUTERS if r != "hash"}
        families[fam] = {"pods": list(pods), "cells": cells,
                         "ratios": ratios}
    vec_cell = None
    if n_vec:
        pods = FLEET_FAMILIES["poisson"]
        cap = sum(pods) / N_UNITS
        trace = TRACE_FAMILIES["poisson"](zoo, n=n_vec, load=load,
                                          seed=seed, capacity=cap)
        capacity = int(1.02 * _hash_split_max(trace, pods, seed)) + 8
        t0 = time.perf_counter()
        vec = VectorizedFleetSimulator(
            TimeSharingPolicy(),
            SimConfig(window=window, pods=pods, router="hash",
                      router_seed=seed),
            capacity=capacity)
        vec_cell = vec.run(trace).summary()
        vec_cell["sim_wall_s"] = time.perf_counter() - t0
        vec_cell["engine"] = "vectorized"
        vec_cell["n_arrivals"] = n_vec
        vec_cell["family"] = "poisson"
        vec_cell["lane_capacity"] = capacity
        emit("fleet_vectorized_100k", vec_cell["sim_wall_s"] * 1e6 / n_vec,
             f"p99={vec_cell['p99_wait_s']:.0f}s")
    return {
        "n_arrivals": n, "load": load, "seed": seed, "window": window,
        "routers": list(FLEET_ROUTERS),
        "families": families,
        "vectorized_100k": vec_cell,
        "note": FLEET_NOTE,
    }


def _single_pod_parity(zoo, bench) -> dict:
    """Re-run each committed traces family on a ``pods=(8,)`` fleet and
    require exact key-by-key equality with the committed single-pod
    ``time_sharing`` cells (floats through JSON round-trip exactly)."""
    out: dict = {}
    n, load = bench["n_arrivals"], bench["load"]
    seed, window = bench["seed"], bench["window"]
    skip = {"sim_wall_s", "engine", "schema", "n_pods", "pods", "router",
            "refits", "p50_wait_s", "p99_wait_s"}
    for i, fam in enumerate(bench["traces"]):
        cell = bench["traces"][fam].get("time_sharing")
        if not isinstance(cell, dict):
            continue
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=load, seed=seed + i)
        fresh = ClusterSimulator(
            TimeSharingPolicy(),
            SimConfig(window=window, pods=(N_UNITS,))).run(trace).summary()
        keys = [k for k in cell if k not in skip]
        out[fam] = all(fresh.get(k) == cell[k] for k in keys)
    return out


def _vectorized_sim(zoo, window, n, load, seed, batch=64, capacity=128):
    """Engine comparison: heap vs vectorized, single trace + vmapped sweep.

    Same solo-placement workload both ways (time sharing, concurrent mode,
    ``batch`` seed-varied Poisson traces).  The heap's traces/sec comes
    from serving the first few traces one at a time; the vectorized
    engine's from one warm vmapped ``sweep`` call over the whole batch
    (compile time reported separately — it amortizes across sweeps).
    """
    traces = [TRACE_FAMILIES["poisson"](zoo, n=n, load=load, seed=seed + i)
              for i in range(batch)]
    n_heap = min(8, batch)
    t0 = time.perf_counter()
    heap_res = [ClusterSimulator(TimeSharingPolicy(), window=window).run(tr)
                for tr in traces[:n_heap]]
    heap_per_trace = (time.perf_counter() - t0) / n_heap
    vec = VectorizedClusterSimulator(TimeSharingPolicy(), window=window,
                                     capacity=capacity)
    t0 = time.perf_counter()
    vec_res = vec.run(traces[0])
    vec_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec.run(traces[0])
    vec_per_trace = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec.sweep(traces)
    sweep_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    summ = vec.sweep(traces)
    sweep_wall = time.perf_counter() - t0
    traces_per_s = batch / sweep_wall
    heap_traces_per_s = 1.0 / heap_per_trace
    # parity spot check rides along so the committed numbers carry proof
    # the two engines measured the same system
    h0, v0 = heap_res[0], vec_res
    section = {
        "family": "poisson", "window": window, "n_arrivals": n,
        "load": load, "seed": seed, "capacity": capacity,
        "single_trace": {
            "heap_wall_s": heap_per_trace,
            "vectorized_wall_s": vec_per_trace,
            "vectorized_compile_s": vec_compile_s,
        },
        "sweep": {
            "batch": batch,
            "wall_s": sweep_wall,
            "compile_s": sweep_compile_s,
            "traces_per_s": traces_per_s,
            "heap_traces_per_s": heap_traces_per_s,
            "speedup_vs_heap": traces_per_s / heap_traces_per_s,
        },
        "parity": {
            "heap_makespan_s": h0.makespan,
            "vectorized_makespan_s": v0.makespan,
            "heap_p99_wait_s": h0.p99_wait,
            "vectorized_p99_wait_s": v0.p99_wait,
            "sweep_mean_makespan_s": float(summ.makespan.mean()),
        },
        "note": ("heap_traces_per_s serves traces one at a time on the "
                 "Python event heap; traces_per_s is one warm vmapped "
                 "sweep call over the whole batch (compile_s amortizes "
                 "across sweeps and is excluded, matching how the engine "
                 "is used for fleet-scale evaluation); speedup_vs_heap is "
                 "their ratio, floored by benchmarks.bench_gate; parity "
                 "keys show both engines measured the same system "
                 "(decision-level equality is asserted in "
                 "tests/test_vecsim.py)"),
    }
    emit("vectorized_sim", sweep_wall * 1e6 / batch,
         f"speedup={section['sweep']['speedup_vs_heap']:.2f}x")
    return section


def _vectorized_rl(zoo, agent, env_cfg, window, n, load, seed,
                   batch=64, capacity=128, population=4):
    """Engine comparison for RL serving: in-graph agent episodes vs heap.

    The same trained agent both ways.  The heap replays it through
    :class:`RLDispatchPolicy` one trace at a time (a fresh policy per
    trace: the profile repository fills as jobs complete, and the
    vectorized engine's profiled lane also starts empty every run, so
    fresh-per-trace is the matched condition); the vectorized engine
    runs the DQN forward pass at the window-formation seam *inside* the
    jitted episode and sweeps the whole batch in one vmapped call.
    ``population`` extra param sets ride ``sweep(param_sets=...)``'s
    leading axis — one device call evaluates P agents x batch traces,
    the population-evaluation mode the axis exists for.
    """
    traces = [TRACE_FAMILIES["poisson"](zoo, n=n, load=load, seed=seed + i)
              for i in range(batch)]
    n_heap = min(8, batch)
    t0 = time.perf_counter()
    heap_res = [ClusterSimulator(RLDispatchPolicy(agent, env_cfg),
                                 window=window).run(tr)
                for tr in traces[:n_heap]]
    heap_per_trace = (time.perf_counter() - t0) / n_heap
    vec = VectorizedClusterSimulator(RLDispatchPolicy(agent, env_cfg),
                                     window=window, capacity=capacity)
    t0 = time.perf_counter()
    vec_res = vec.run(traces[0])
    vec_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec.run(traces[0])
    vec_per_trace = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec.sweep(traces)
    sweep_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    summ = vec.sweep(traces)
    sweep_wall = time.perf_counter() - t0
    traces_per_s = batch / sweep_wall
    heap_traces_per_s = 1.0 / heap_per_trace
    # population axis: the trained params plus seed-varied random inits
    env = CoScheduleEnv(env_cfg)
    param_sets = [agent.params] + [
        DQNAgent(env.state_dim, env.n_actions, seed=seed + 1 + k).params
        for k in range(population - 1)]
    t0 = time.perf_counter()
    vec.sweep(traces, param_sets=param_sets)
    pop_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    psumm = vec.sweep(traces, param_sets=param_sets)
    pop_wall = time.perf_counter() - t0
    h0, v0 = heap_res[0], vec_res
    section = {
        "family": "poisson", "window": window, "n_arrivals": n,
        "load": load, "seed": seed, "capacity": capacity,
        "single_trace": {
            "heap_wall_s": heap_per_trace,
            "vectorized_wall_s": vec_per_trace,
            "vectorized_compile_s": vec_compile_s,
        },
        "sweep": {
            "batch": batch,
            "wall_s": sweep_wall,
            "compile_s": sweep_compile_s,
            "traces_per_s": traces_per_s,
            "heap_traces_per_s": heap_traces_per_s,
            "speedup_vs_heap": traces_per_s / heap_traces_per_s,
        },
        "population": {
            "params_sets": len(param_sets),
            "wall_s": pop_wall,
            "compile_s": pop_compile_s,
            "episodes_per_s": len(param_sets) * batch / pop_wall,
            "mean_makespan_s_per_params": [
                float(m) for m in psumm.makespan.mean(axis=1)],
        },
        "parity": {
            "heap_makespan_s": h0.makespan,
            "vectorized_makespan_s": v0.makespan,
            "heap_p99_wait_s": h0.p99_wait,
            "vectorized_p99_wait_s": v0.p99_wait,
            "sweep_mean_makespan_s": float(summ.makespan.mean()),
        },
        "note": ("heap_traces_per_s replays the trained agent through "
                 "RLDispatchPolicy on the Python event heap one trace at "
                 "a time (fresh policy per trace: both engines start with "
                 "an empty profile repository); traces_per_s is one warm "
                 "vmapped sweep call with the DQN forward pass running "
                 "in-graph at the window-formation seam (compile_s "
                 "amortizes and is excluded); speedup_vs_heap is their "
                 "ratio, floored by benchmarks.bench_gate; population is "
                 "the sweep(param_sets=...) mode — params_sets x batch "
                 "agent episodes in ONE device call (row 0 is the trained "
                 "agent, the rest seed-varied random inits); decision-"
                 "level RL parity is asserted in tests/test_parity_fuzz.py"),
    }
    emit("vectorized_rl", sweep_wall * 1e6 / batch,
         f"speedup={section['sweep']['speedup_vs_heap']:.2f}x "
         f"pop={len(param_sets)}x{batch}")
    return section


def _retrain_trigger(zoo, agent, env_cfg, window, n, load, seed,
                     interval_min, retrain_episodes):
    """Clock vs drift re-training A/B on a drift-prone trace.

    The MMPP family's regime switches move the arrival mix over time —
    exactly what the :class:`~repro.online.telemetry.DriftMonitor` watches
    (class/width-mix entropy, idle-fraction rise).  Both arms serve the
    identical trace with the same frozen starting agent and the same tick
    cadence; the clock arm retrains every tick, the drift arm only on a
    drift verdict.  The committed cell records throughput and retrain
    counts — the gate (``benchmarks.bench_gate``) requires drift to hold
    throughput within ``DRIFT_RETRAIN_FLOOR`` of clock while never
    retraining more often.
    """
    trace = TRACE_FAMILIES["mmpp"](zoo, n=n, load=load, seed=seed)
    out: dict = {"family": "mmpp", "n_arrivals": n, "load": load,
                 "seed": seed, "interval_min": interval_min,
                 "retrain_episodes": retrain_episodes}
    for trig in ("clock", "drift"):
        pol = RLDispatchPolicy(agent, env_cfg)
        rt = OnlineRetrainer(
            policy=pol, train_cfg=default_retrain_train_config(
                retrain_episodes),
            interval_s=interval_min * 60.0, min_jobs=4, trigger=trig)
        cell = _simulate(pol, trace, window, retrainer=rt)
        if trig == "drift":
            cell["drift_observations"] = len(rt.monitor.history)
            cell["drift_verdicts"] = sum(
                1 for h in rt.monitor.history if h["drift"])
        out[trig] = cell
        emit(f"retrain_trigger_{trig}", cell["sim_wall_s"] * 1e6 / n,
             f"retrains={cell['retrains']} tp={cell['throughput']:.3f}")
    out["drift_vs_clock_throughput"] = (out["drift"]["throughput"]
                                        / out["clock"]["throughput"])
    out["retrains_saved"] = (out["clock"]["retrains"]
                             - out["drift"]["retrains"])
    out["note"] = (
        "identical mmpp trace, identical frozen starting agent, identical "
        "tick cadence; clock retrains every tick with enough repository "
        "jobs, drift only when the DriftMonitor fires on the interval's "
        "class/width-mix entropy or idle-fraction shift (then rebases); "
        "drift_vs_clock_throughput near 1.0 with retrains_saved > 0 means "
        "the drift signals buy back retraining compute without giving up "
        "serving quality")
    return out


def _queueing_reward(zoo, agent, env_cfg, window, n, load, seed):
    """Sim-in-the-loop refinement A/B: queueing-trained vs proxy-trained.

    ``train_online`` rolls the job zoo as serving traces through the
    vectorized training engine and optimizes the engine-accumulated
    queueing reward (negative per-window wait/turnaround + makespan
    terminal), warm-started from the committed run's proxy-trained agent.
    Both agents — the frozen proxy incumbent and the refined result — then
    serve identical held-out traces of every family on the event heap,
    and the committed cell records per-family p99 wait both ways.  A
    family is a ``win`` when the queueing-trained agent's p99 wait is at
    or below the proxy-trained agent's; the gate
    (``benchmarks.bench_gate``) requires wins on at least
    ``QUEUEING_WIN_FAMILIES_MIN`` of the five families.  The elitism
    guard inside ``train_online`` makes the refinement safe by
    construction: a refresh that does not beat the incumbent on training
    eval returns the incumbent's weights unchanged.
    """
    from repro.core.train import TrainOnlineConfig, train_online

    # train on the serving distribution: all five families at the bench's
    # arrival count and load, so the refinement optimizes the traffic the
    # A/B serves rather than a shrunken proxy of it
    cfg = TrainOnlineConfig(
        window=min(8, window), seed=seed, n_arrivals=n,
        capacity=max(128, 2 * n),
        scenarios=tuple((fam, load) for fam in sorted(TRACE_FAMILIES)),
        eval_traces=2 * len(TRACE_FAMILIES))
    t0 = time.perf_counter()
    refined, hist = train_online(zoo, env_cfg, cfg, warm_start=agent)
    train_wall = time.perf_counter() - t0
    emit("queueing_reward_train", train_wall * 1e6 / max(1, cfg.rounds),
         f"rounds={hist[-1]['round']} sel={hist[-1]['selected']}")
    families: dict = {}
    for i, fam in enumerate(sorted(TRACE_FAMILIES)):
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=load, seed=seed + 500 + i)
        px = _simulate(RLDispatchPolicy(agent, env_cfg), trace, window)
        qx = _simulate(RLDispatchPolicy(refined, env_cfg), trace, window)
        ratio = (qx["p99_wait_s"] / px["p99_wait_s"]
                 if px["p99_wait_s"] > 0.0 else 1.0)
        families[fam] = {
            "proxy_p99_wait_s": px["p99_wait_s"],
            "queueing_p99_wait_s": qx["p99_wait_s"],
            "proxy_mean_wait_s": px["mean_wait_s"],
            "queueing_mean_wait_s": qx["mean_wait_s"],
            "proxy_throughput": px["throughput"],
            "queueing_throughput": qx["throughput"],
            "queueing_vs_proxy_p99": ratio,
            "win": qx["p99_wait_s"] <= px["p99_wait_s"],
        }
        emit(f"queueing_reward_{fam}", qx["sim_wall_s"] * 1e6,
             f"q/p p99={ratio:.3f} win={families[fam]['win']}")
    wins = sum(1 for f in families.values() if f["win"])
    return {
        "n_arrivals": n, "load": load, "seed": seed,
        "train": {"rounds": hist[-1]["round"],
                  "population": cfg.population,
                  "transitions": hist[-1]["transitions"],
                  "selected": hist[-1]["selected"],
                  "train_eval_p99_wait": min(hist[-1]["final_scores"]),
                  "wall_s": train_wall},
        "families": families,
        "families_won": wins,
        "note": (
            "p99 wait of the queueing-trained agent (train_online "
            "warm-started from the committed proxy agent: PBT over "
            "scenario x exploration, reward = engine-accumulated "
            "wait/turnaround + makespan terminal) vs the frozen "
            "proxy-trained agent on identical held-out traces; win "
            "means queueing p99 <= proxy p99, and the elitism guard "
            "returns the incumbent unchanged when no trained member "
            "beats it on training eval — training on the real queueing "
            "outcome never loses to the throughput proxy"),
    }


def _telemetry_overhead(zoo, window, n, load, seed, repeats=21):
    """Telemetry-enabled vs disabled sim wall time, both engines.

    Same machine, same run, ``repeats`` alternating off/on pairs — the
    committed ``overhead_ratio`` is the median of per-pair ratios, which
    cancels slow machine drift that a best-of or median-of-each-side
    comparison picks up as phantom overhead.  The heap side pays
    per-event hook calls; the vectorized side carries the
    ``MetricsState`` through its ``lax.while_loop`` (compile time
    excluded both ways — it amortizes).  Gated at
    ``TELEMETRY_OVERHEAD_MAX`` by ``benchmarks.bench_gate``.
    """
    trace = TRACE_FAMILIES["poisson"](zoo, n=n, load=load, seed=seed)

    def heap_wall(tel_on: bool) -> float:
        tel = Telemetry() if tel_on else None
        sim = ClusterSimulator(TimeSharingPolicy(), window=window,
                               telemetry=tel)
        t0 = time.perf_counter()
        sim.run(trace)
        return time.perf_counter() - t0

    def paired(wall) -> tuple[float, float, float]:
        wall(False), wall(True)                  # warm outside timing
        pairs = [(wall(False), wall(True)) for _ in range(repeats)]
        return (statistics.median(b for b, _ in pairs),
                statistics.median(t for _, t in pairs),
                statistics.median(t / b for b, t in pairs))

    heap_base, heap_tel, heap_ratio = paired(heap_wall)

    cap = max(128, 2 * len(trace))
    engines = {
        False: VectorizedClusterSimulator(TimeSharingPolicy(), window=window,
                                          capacity=cap),
        True: VectorizedClusterSimulator(TimeSharingPolicy(), window=window,
                                         capacity=cap, telemetry=True),
    }
    for eng in engines.values():
        eng.run(trace)                       # compile outside the timed region

    def vec_wall(tel_on: bool) -> float:
        t0 = time.perf_counter()
        engines[tel_on].run(trace)
        return time.perf_counter() - t0

    vec_base, vec_tel, vec_ratio = paired(vec_wall)
    section = {
        "family": "poisson", "n_arrivals": n, "load": load, "seed": seed,
        "window": window, "repeats": repeats,
        "heap": {"base_wall_s": heap_base, "telemetry_wall_s": heap_tel,
                 "overhead_ratio": heap_ratio},
        "vectorized": {"base_wall_s": vec_base, "telemetry_wall_s": vec_tel,
                       "overhead_ratio": vec_ratio},
        "max_allowed_ratio": TELEMETRY_OVERHEAD_MAX,
        "note": ("median per-pair off/on wall ratios on one machine in one "
                 "process — cross-machine absolute times never enter the "
                 "gate; vectorized walls are warm (compile excluded, as "
                 "the engine is used); heap telemetry includes full event "
                 "recording + metrics hooks, vectorized carries "
                 "MetricsState in-graph"),
    }
    emit("telemetry_overhead_heap", heap_tel * 1e6 / n,
         f"ratio={heap_ratio:.3f}x")
    emit("telemetry_overhead_vec", vec_tel * 1e6 / n,
         f"ratio={vec_ratio:.3f}x")
    return section


def _context_agent(zoo, env_cfg, base_agent, episodes, seed=0):
    """Train the arrival-aware agent, warm-started from the profile-only one.

    ``widen_dqn_params`` zero-pads the input layer (params, target, Adam
    moments), so training starts from the exact profile-only Q-function and
    only has to learn how the context block modulates it; exploration
    restarts on a reduced ε schedule sized for adaptation, not rediscovery.
    """
    ctx_cfg = dataclasses.replace(env_cfg, obs_context=True)
    extra = context_dim(ctx_cfg)
    probe = CoScheduleEnv(ctx_cfg)
    warm = DQNAgent(probe.state_dim, probe.n_actions, base_agent.cfg, seed=seed)
    warm.params = widen_dqn_params(base_agent.params, extra)
    warm.target_params = widen_dqn_params(base_agent.target_params, extra)
    warm.opt = {"m": widen_dqn_params(base_agent.opt["m"], extra),
                "v": widen_dqn_params(base_agent.opt["v"], extra),
                "t": base_agent.opt["t"]}
    t0 = time.perf_counter()
    agent, hist = train_agent(
        zoo, ctx_cfg,
        TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                    obs_context=True, seed=seed,
                    dqn=DQNConfig(eps_start=0.5,
                                  eps_decay_steps=episodes * 6)),
        warm_start=warm)
    emit("arrival_aware_train", (time.perf_counter() - t0) * 1e6 / episodes,
         f"tp={hist[-1]['eval_throughput']:.3f}")
    return agent, ctx_cfg


def _arrival_aware(zoo, env_cfg, ctx_cfg, agent, ctx_agent, families,
                   n, load, seed, window, engine="heap"):
    """Frozen observation-mode comparison, one entry per trace family."""
    out: dict = {}
    for i, fam in enumerate(families):
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=load, seed=seed + i)
        ts = _simulate(TimeSharingPolicy(), trace, window, engine=engine)
        rl = _simulate(RLDispatchPolicy(agent, env_cfg), trace, window)
        rlc = _simulate(RLDispatchPolicy(ctx_agent, ctx_cfg), trace, window)
        out[fam] = {
            "rl_profile_only": rl,
            "rl_context": rlc,
            "time_sharing_throughput": ts["throughput"],
            "rl_context_vs_profile_only": rlc["throughput"] / rl["throughput"],
            "rl_context_vs_time_sharing": rlc["throughput"] / ts["throughput"],
            "rl_profile_only_vs_time_sharing": rl["throughput"] / ts["throughput"],
        }
        emit(f"arrival_aware_{fam}", rlc["sim_wall_s"] * 1e6,
             f"ctx/prof={out[fam]['rl_context_vs_profile_only']:.3f}")
    out["note"] = ARRIVAL_NOTE
    return out


def _bench_trace(tname, trace, agent, env_cfg, window, retrain_cfg,
                 baselines: bool, engine="heap", profile=False,
                 trace_gen_s=None):
    """All policies on one trace; fresh repositories so profiling restarts."""
    out: dict = {"arrivals": len(trace), "span_s": trace[-1].t}
    if trace_gen_s is not None:
        out["trace_gen_s"] = trace_gen_s
    out["time_sharing"] = _simulate(TimeSharingPolicy(), trace, window,
                                    engine=engine, profile=profile)
    # dispatch-mode comparison: same frozen policies, blocking pod
    out["time_sharing_blocking"] = _simulate(TimeSharingPolicy(), trace,
                                             window, mode="blocking",
                                             profile=profile)
    if baselines:
        out["greedy_packer"] = _simulate(GreedyPackerPolicy(), trace, window,
                                         engine=engine, profile=profile)
        out["mig_mps_default"] = _simulate(
            StaticPartitionPolicy("mig_mps_default"), trace, window,
            engine=engine, profile=profile)
        out["rl"] = _simulate(RLDispatchPolicy(agent, env_cfg), trace, window,
                              engine=engine, profile=profile)
        out["rl_blocking"] = _simulate(RLDispatchPolicy(agent, env_cfg),
                                       trace, window, mode="blocking",
                                       profile=profile)
    pol = RLDispatchPolicy(agent, env_cfg)
    rt = OnlineRetrainer(policy=pol, **retrain_cfg)
    out["rl_retrain"] = _simulate(pol, trace, window, retrainer=rt,
                                  profile=profile)
    ts_tp = out["time_sharing"]["throughput"]
    for name in ("greedy_packer", "mig_mps_default", "rl", "rl_retrain"):
        if name in out:
            out[f"{name}_vs_time_sharing"] = out[name]["throughput"] / ts_tp
    cvb = {"time_sharing": (out["time_sharing"]["throughput"]
                            / out["time_sharing_blocking"]["throughput"])}
    if "rl_blocking" in out:
        cvb["rl"] = out["rl"]["throughput"] / out["rl_blocking"]["throughput"]
    out["concurrent_vs_blocking"] = cvb
    emit(f"online_{tname}", out["rl_retrain"]["sim_wall_s"] * 1e6,
         f"rl_rt/ts={out['rl_retrain_vs_time_sharing']:.3f} "
         f"conc/blk={cvb['time_sharing']:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="shrink the full run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: tiny counts, ratio floors + key check")
    ap.add_argument("--ratio-floor", type=float, default=0.98,
                    help="min rl_retrain/time_sharing throughput in --smoke")
    ap.add_argument("--frag-margin", type=float, default=FRAG_MARGIN,
                    help="min concurrent/blocking throughput on the "
                         "fragmented family in --smoke (shared with "
                         "benchmarks.bench_gate)")
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--arrivals", type=int, default=None)
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--ctx-episodes", type=int, default=None,
                    help="training budget for the context agent "
                         "(default: same as --episodes)")
    ap.add_argument("--ctx-seed", type=int, default=2,
                    help="training seed for the context agent's refresh "
                         "(its own knob: the warm start pins the starting "
                         "Q-function, so this only seeds context draws and "
                         "exploration)")
    ap.add_argument("--load", type=float, default=1.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retrain-interval-min", type=float, default=None)
    ap.add_argument("--engine", choices=("heap", "vectorized"),
                    default="heap",
                    help="simulator engine for policy×family cells; "
                         "'vectorized' routes supported cells "
                         "(solo-placement, concurrent, no retrainer) "
                         "through repro.online.vecsim and leaves the rest "
                         "on the heap — each cell records which engine "
                         "served it")
    ap.add_argument("--sweep-batch", type=int, default=64,
                    help="vmapped batch size for the vectorized_sim sweep")
    ap.add_argument("--section",
                    choices=("arrival_aware", "vectorized_sim",
                             "vectorized_rl", "sim_wall",
                             "fleet_scale", "retrain_trigger",
                             "telemetry_overhead", "queueing_reward"),
                    default=None,
                    help="recompute one section and merge it into the "
                         "committed --bench-json instead of a full run")
    ap.add_argument("--profile", action="store_true",
                    help="record a per-phase wall-time breakdown (trace "
                         "gen / sim / policy / retrain) in each heap cell")
    ap.add_argument("--telemetry-artifacts", default=None, metavar="DIR",
                    help="(smoke) write a telemetry-enabled fleet cell's "
                         "Chrome trace + events/metrics JSONL into DIR "
                         "for CI artifact upload")
    ap.add_argument("--bench-json", default="BENCH_online.json",
                    help="committed trajectory checked for keys in --smoke")
    ap.add_argument("--out", default=None,
                    help="where to write results (default BENCH_online.json; "
                         "smoke mode writes nothing unless given)")
    args, _ = ap.parse_known_args()

    if args.section == "sim_wall":
        # pure derivation from the committed traces cells — no simulation
        with open(args.bench_json) as f:
            bench = json.load(f)
        bench["sim_wall"] = _sim_wall_block(bench["traces"])
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        cells = sum(len(v) for v in bench["sim_wall"].values())
        print(f"merged sim_wall into {out}: {cells} policy×family cells")
        return

    if args.section == "fleet_scale":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or 10_000
        seed = bench.get("seed", args.seed)
        episodes = args.episodes or bench["train_episodes"]
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=window, c_max=4)
        print("name,us_per_call,derived")
        # deterministic replication of the committed run's profile-only
        # agent (same replication path as --section arrival_aware)
        agent, _ = train_agent(
            zoo, env_cfg,
            TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                        seed=seed,
                        dqn=DQNConfig(eps_decay_steps=episodes * 6)))
        section = _fleet_scale(zoo, agent, env_cfg, window, n, seed)
        section["single_pod_parity"] = _single_pod_parity(zoo, bench)
        bench["fleet_scale"] = section
        frag = section["families"]["fragmented"]["ratios"]
        best = max(frag[k]["time_sharing"] for k in frag)
        acc = bench.setdefault("acceptance", {})
        acc["fleet_best_router_beats_hash_on_fragmented"] = (
            best >= FLEET_P99_FLOOR)
        acc["fleet_single_pod_parity"] = all(
            section["single_pod_parity"].values())
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged fleet_scale into {out}: best/hash p99 on fragmented "
              f"= {best:.2f}x (floor {FLEET_P99_FLOOR:.1f}), parity "
              f"{section['single_pod_parity']}")
        return

    if args.section == "telemetry_overhead":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or max(400, bench["n_arrivals"])
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        zoo = make_zoo(dryrun_dir=None)
        print("name,us_per_call,derived")
        section = _telemetry_overhead(zoo, window, n, load, seed)
        bench["telemetry_overhead"] = section
        worst = max(section["heap"]["overhead_ratio"],
                    section["vectorized"]["overhead_ratio"])
        bench.setdefault("acceptance", {})[
            "telemetry_overhead_within_max"] = worst <= TELEMETRY_OVERHEAD_MAX
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged telemetry_overhead into {out}: heap "
              f"{section['heap']['overhead_ratio']:.3f}x, vectorized "
              f"{section['vectorized']['overhead_ratio']:.3f}x "
              f"(max {TELEMETRY_OVERHEAD_MAX:.2f}x)")
        return

    if args.section == "retrain_trigger":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or bench["n_arrivals"]
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        episodes = args.episodes or bench["train_episodes"]
        interval_min = (args.retrain_interval_min
                        or bench.get("retrain", {}).get("interval_min", 30.0))
        retrain_episodes = bench.get("retrain", {}).get("episodes", 240)
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=window, c_max=4)
        print("name,us_per_call,derived")
        # deterministic replication of the committed run's profile-only agent
        agent, _ = train_agent(
            zoo, env_cfg,
            TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                        seed=seed,
                        dqn=DQNConfig(eps_decay_steps=episodes * 6)))
        section = _retrain_trigger(zoo, agent, env_cfg, window, n, load,
                                   seed, interval_min, retrain_episodes)
        bench["retrain_trigger"] = section
        bench.setdefault("acceptance", {})[
            "drift_trigger_holds_throughput_with_fewer_retrains"] = (
            section["drift_vs_clock_throughput"] >= 0.97
            and section["retrains_saved"] >= 0)
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged retrain_trigger into {out}: drift/clock throughput "
              f"{section['drift_vs_clock_throughput']:.3f}, retrains "
              f"{section['clock']['retrains']} -> "
              f"{section['drift']['retrains']}")
        return

    if args.section == "vectorized_sim":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or bench["n_arrivals"]
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        zoo = make_zoo(dryrun_dir=None)
        print("name,us_per_call,derived")
        section = _vectorized_sim(zoo, window, n, load, seed,
                                  batch=args.sweep_batch)
        bench["vectorized_sim"] = section
        bench.setdefault("acceptance", {})[
            "vectorized_sweep_speedup_ge_floor"] = (
            section["sweep"]["speedup_vs_heap"] >= VECSIM_SPEEDUP_FLOOR)
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged vectorized_sim into {out}: "
              f"{section['sweep']['speedup_vs_heap']:.2f}x over heap at "
              f"batch {section['sweep']['batch']} "
              f"({section['sweep']['traces_per_s']:.0f} traces/s, floor "
              f"{VECSIM_SPEEDUP_FLOOR:.1f}x)")
        return

    if args.section == "vectorized_rl":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or bench["n_arrivals"]
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        episodes = args.episodes or bench["train_episodes"]
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=window, c_max=4)
        print("name,us_per_call,derived")
        # deterministic replication of the committed run's profile-only agent
        agent, _ = train_agent(
            zoo, env_cfg,
            TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                        seed=seed,
                        dqn=DQNConfig(eps_decay_steps=episodes * 6)))
        section = _vectorized_rl(zoo, agent, env_cfg, window, n, load, seed,
                                 batch=args.sweep_batch)
        bench["vectorized_rl"] = section
        bench.setdefault("acceptance", {})[
            "vectorized_rl_sweep_speedup_ge_floor"] = (
            section["sweep"]["speedup_vs_heap"] >= VECRL_SPEEDUP_FLOOR)
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged vectorized_rl into {out}: "
              f"{section['sweep']['speedup_vs_heap']:.2f}x over heap RL at "
              f"batch {section['sweep']['batch']} "
              f"({section['sweep']['traces_per_s']:.0f} traces/s, floor "
              f"{VECRL_SPEEDUP_FLOOR:.1f}x); population "
              f"{section['population']['params_sets']}x"
              f"{section['sweep']['batch']} episodes in "
              f"{section['population']['wall_s']:.3f}s")
        return

    if args.section == "queueing_reward":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or bench["n_arrivals"]
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        episodes = args.episodes or bench["train_episodes"]
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=window, c_max=4)
        print("name,us_per_call,derived")
        # deterministic replication of the committed run's profile-only agent
        agent, _ = train_agent(
            zoo, env_cfg,
            TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                        seed=seed,
                        dqn=DQNConfig(eps_decay_steps=episodes * 6)))
        section = _queueing_reward(zoo, agent, env_cfg, window, n, load, seed)
        bench["queueing_reward"] = section
        bench.setdefault("acceptance", {})[
            "queueing_trained_wins_majority_families"] = (
            len(section["families"]) == len(TRACE_FAMILIES)
            and section["families_won"] >= QUEUEING_WIN_FAMILIES_MIN)
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged queueing_reward into {out}: wins "
              f"{section['families_won']}/{len(section['families'])} "
              f"(floor {QUEUEING_WIN_FAMILIES_MIN}), selected "
              f"{section['train']['selected']}, "
              + ", ".join(
                  f"{t}={section['families'][t]['queueing_vs_proxy_p99']:.3f}"
                  for t in sorted(section["families"])))
        return

    if args.section == "arrival_aware":
        with open(args.bench_json) as f:
            bench = json.load(f)
        window = args.window or bench["window"]
        n = args.arrivals or bench["n_arrivals"]
        load = bench.get("load", args.load)
        seed = bench.get("seed", args.seed)
        episodes = args.episodes or bench["train_episodes"]
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=window, c_max=4)
        print("name,us_per_call,derived")
        # deterministic replication of the committed run's profile-only agent
        agent, _ = train_agent(
            zoo, env_cfg,
            TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                        seed=seed,
                        dqn=DQNConfig(eps_decay_steps=episodes * 6)))
        ctx_agent, ctx_cfg = _context_agent(
            zoo, env_cfg, agent, args.ctx_episodes or episodes,
            seed=args.ctx_seed)
        section = _arrival_aware(zoo, env_cfg, ctx_cfg, agent, ctx_agent,
                                 tuple(TRACE_FAMILIES), n, load, seed, window)
        section["ctx_seed"] = args.ctx_seed
        bench["arrival_aware"] = section
        bench.setdefault("acceptance", {})[
            "arrival_aware_fragmented_ctx_ge_profile_only"] = (
            section["fragmented"]["rl_context_vs_profile_only"]
            >= ARRIVAL_FLOOR)
        out = args.out or args.bench_json
        with open(out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged arrival_aware into {out}: ctx/profile-only " +
              ", ".join(f"{t}={section[t]['rl_context_vs_profile_only']:.3f}"
                        for t in TRACE_FAMILIES))
        return

    if args.smoke:
        window = args.window or 6
        episodes = args.episodes or 120
        n = args.arrivals or 32
        families = ("poisson", "fragmented", "mmpp")
        interval_min = args.retrain_interval_min or 40.0
        retrain_episodes = 80
    else:
        window = args.window or 8
        episodes = args.episodes or (600 if args.fast else 1500)
        n = args.arrivals or (60 if args.fast else 120)
        families = tuple(TRACE_FAMILIES)
        interval_min = args.retrain_interval_min or 30.0
        retrain_episodes = 240

    zoo = make_zoo(dryrun_dir=None)
    env_cfg = EnvConfig(window=window, c_max=4)
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    # seed threaded so --section arrival_aware can replicate this agent
    # bit-exactly from the committed run's recorded seed
    agent, hist = train_agent(
        zoo, env_cfg,
        TrainConfig(episodes=episodes, eval_every=max(50, episodes // 4),
                    seed=args.seed,
                    dqn=DQNConfig(eps_decay_steps=episodes * 6)))
    emit("online_train_agent", (time.perf_counter() - t0) * 1e6 / episodes,
         f"tp={hist[-1]['eval_throughput']:.3f}")
    retrain_cfg = {
        "train_cfg": default_retrain_train_config(retrain_episodes),
        "interval_s": interval_min * 60.0,
        "min_jobs": 4,
    }

    traces = {}
    for i, fam in enumerate(families):
        t_gen = time.perf_counter()
        trace = TRACE_FAMILIES[fam](zoo, n=n, load=args.load,
                                    seed=args.seed + i)
        t_gen = time.perf_counter() - t_gen
        traces[fam] = _bench_trace(fam, trace, agent, env_cfg, window,
                                   retrain_cfg, baselines=not args.smoke,
                                   engine=args.engine, profile=args.profile,
                                   trace_gen_s=t_gen if args.profile else None)

    # observation-mode comparison: context-trained vs profile-only, frozen
    ctx_episodes = args.ctx_episodes or (100 if args.smoke else episodes)
    ctx_agent, ctx_cfg = _context_agent(zoo, env_cfg, agent, ctx_episodes,
                                        seed=args.ctx_seed)
    arrival = None
    ctx_smoke_tp = None
    fleet_smoke = None
    if args.smoke:
        # plumbing guard only: the context agent must serve the
        # fragmentation-stressing trace end to end (committed performance
        # floors live in benchmarks.bench_gate)
        i_frag = families.index("fragmented")
        frag_trace = TRACE_FAMILIES["fragmented"](zoo, n=n, load=args.load,
                                                  seed=args.seed + i_frag)
        ctx_smoke_tp = _simulate(RLDispatchPolicy(ctx_agent, ctx_cfg),
                                 frag_trace, window)["throughput"]
        emit("arrival_aware_smoke", 0.0, f"ctx_tp={ctx_smoke_tp:.3f}")
        # fleet plumbing guard: every router serves a heterogeneous
        # (8, 4) fleet end to end with pod-local claims, and the
        # vectorized fleet engine matches the heap on the hash cell
        fleet_trace = TRACE_FAMILIES["fragmented"](
            zoo, n=n, load=args.load, seed=args.seed, capacity=1.5)
        pods = (N_UNITS, 4)
        served, p99 = True, {}
        for router_name in FLEET_ROUTERS:
            fres = ClusterSimulator(
                TimeSharingPolicy(),
                SimConfig(window=window, pods=pods,
                          router=router_name)).run(fleet_trace)
            served &= all(s + w <= fres.pods[seg.pod]
                          for seg in fres.timeline for s, w in seg.slices)
            served &= all(r.finish == r.finish for r in fres.jobs)  # no NaN
            p99[router_name] = fres.p99_wait
        vres = VectorizedFleetSimulator(
            TimeSharingPolicy(),
            SimConfig(window=window, pods=pods, router="hash"),
            capacity=max(64, 2 * n)).run(fleet_trace)
        tol = max(1e-3 * max(p99["hash"], 1.0), 1e-2)
        fleet_smoke = {
            "pods": list(pods), "p99_wait_s": p99, "served": served,
            "vec_heap_p99_gap_s": abs(vres.p99_wait - p99["hash"]),
            "vec_parity": abs(vres.p99_wait - p99["hash"]) <= tol,
        }
        emit("fleet_smoke", 0.0,
             f"p99_hash={p99['hash']:.1f}s "
             f"gap={fleet_smoke['vec_heap_p99_gap_s']:.4f}s")
        if args.telemetry_artifacts:
            # telemetry-enabled fleet cell: Chrome trace + events/metrics
            # JSONL for CI artifact upload, with the metrics aggregates
            # cross-checked against summary() (the acceptance invariant)
            import os
            os.makedirs(args.telemetry_artifacts, exist_ok=True)
            tel = Telemetry()
            tres = ClusterSimulator(
                TimeSharingPolicy(),
                SimConfig(window=window, pods=pods, router="hash"),
                telemetry=tel).run(fleet_trace)
            summ = tres.summary()
            d = args.telemetry_artifacts
            tel.recorder.write_chrome_trace(f"{d}/smoke_trace.json", pods)
            tel.recorder.write_jsonl(f"{d}/smoke_events.jsonl")
            tel.metrics.write_jsonl(f"{d}/smoke_metrics.jsonl")
            mm = {m["name"]: m for m in tel.metrics.to_dicts()}
            busy = sum(tres.slice_busy_s)
            fleet_smoke["telemetry_matches_summary"] = (
                mm["jobs_arrived"]["value"] == summ["jobs"]
                and mm["backfills"]["value"] == summ["backfills"]
                and mm["refits"]["value"] == summ["refits"]
                and mm["windows_formed"]["value"] == summ["dispatches"]
                and mm["groups_placed"]["value"] == summ["groups"]
                and abs(mm["busy_unit_s"]["value"] - busy)
                <= 1e-6 * max(busy, 1.0))
            emit("telemetry_artifacts", 0.0,
                 f"events={len(tel.recorder.events)} "
                 f"match={fleet_smoke['telemetry_matches_summary']}")
    else:
        arrival = _arrival_aware(zoo, env_cfg, ctx_cfg, agent, ctx_agent,
                                 families, n, args.load, args.seed, window,
                                 engine=args.engine)

    # engine comparison rides the full run (smoke keeps its <60 s budget;
    # CI exercises the sweep path via tests/test_vecsim.py instead)
    vec_section = None if args.smoke else _vectorized_sim(
        zoo, window, n, args.load, args.seed, batch=args.sweep_batch)
    vecrl_section = None if args.smoke else _vectorized_rl(
        zoo, agent, env_cfg, window, n, args.load, args.seed,
        batch=args.sweep_batch)

    # fleet-scale grid rides the full run too (frozen profile-only agent)
    fleet = None if args.smoke else _fleet_scale(
        zoo, agent, env_cfg, window,
        2_000 if args.fast else 10_000, args.seed,
        n_vec=0 if args.fast else 100_000)

    rl_vs_ts = {t: traces[t]["rl_retrain_vs_time_sharing"] for t in traces}
    dispatch_cmp = {t: traces[t]["concurrent_vs_blocking"] for t in traces}
    frag = traces.get("fragmented", {})
    result = {
        "window": window,
        "n_arrivals": n,
        "load": args.load,
        "seed": args.seed,
        "train_episodes": episodes,
        "engine": args.engine,
        "retrain": {"interval_min": interval_min,
                    "episodes": retrain_episodes},
        "traces": traces,
        "rl_vs_time_sharing": rl_vs_ts,
        "dispatch_comparison": dispatch_cmp,
        "arrival_aware": arrival,
        "sim_wall": _sim_wall_block(traces),
        "vectorized_sim": vec_section,
        "vectorized_rl": vecrl_section,
        "fleet_scale": fleet,
        "acceptance": {
            "arrival_aware_fragmented_ctx_ge_profile_only": (
                arrival is not None
                and arrival["fragmented"]["rl_context_vs_profile_only"]
                >= ARRIVAL_FLOOR),
            "poisson_arrivals": traces.get("poisson", {}).get("arrivals", 0),
            "rl_retrain_beats_time_sharing_on_poisson":
                rl_vs_ts.get("poisson", 0.0) > 1.0,
            "concurrent_ge_blocking_all_families":
                all(min(r.values()) >= CONC_BLK_FLOOR
                    for r in dispatch_cmp.values()),
            "concurrent_strictly_beats_blocking_on_fragmented":
                frag.get("concurrent_vs_blocking",
                         {}).get("time_sharing", 0.0) > 1.0,
            "fragmented_backfills":
                frag.get("time_sharing", {}).get("backfills", 0),
            "vectorized_sweep_speedup_ge_floor": (
                vec_section is not None
                and vec_section["sweep"]["speedup_vs_heap"]
                >= VECSIM_SPEEDUP_FLOOR),
            "vectorized_rl_sweep_speedup_ge_floor": (
                vecrl_section is not None
                and vecrl_section["sweep"]["speedup_vs_heap"]
                >= VECRL_SPEEDUP_FLOOR),
        },
        "note": ("throughput = total solo work / makespan (time sharing ~1.0 "
                 "on a saturated pod); *_vs_time_sharing are ratios of that "
                 "metric on identical traces; rl_retrain re-trains the agent "
                 "on the live profile repository every interval_min simulated "
                 "minutes, warm-started from current params, and hot-swaps "
                 "it; all policies pay the same first-sight profiling cost "
                 "(unprofiled jobs run solo); dispatch_comparison = "
                 "concurrent-dispatch/blocking-window throughput per policy "
                 "on identical traces — 1.0 where placements are full-pod "
                 "(bit-compatible modes), >1.0 on the fragmented family "
                 "where right-sized jobs pack disjoint slices and backfill "
                 "idle gaps; slice_utilization/idle_slice_frac in each "
                 "summary are claimed-unit-seconds over N_UNITS x makespan"),
    }

    if fleet is not None:
        fleet["single_pod_parity"] = _single_pod_parity(zoo, result)
        frag_r = fleet["families"]["fragmented"]["ratios"]
        best = max(frag_r[k]["time_sharing"] for k in frag_r)
        result["acceptance"]["fleet_best_router_beats_hash_on_fragmented"] = (
            best >= FLEET_P99_FLOOR)
        result["acceptance"]["fleet_single_pod_parity"] = all(
            fleet["single_pod_parity"].values())

    if args.smoke:
        failures = []
        ratio = rl_vs_ts.get("poisson", 0.0)
        if ratio < args.ratio_floor:
            failures.append(f"rl_retrain/time_sharing {ratio:.3f} below "
                            f"floor {args.ratio_floor:.2f}")
        for fam, cmp_ in dispatch_cmp.items():
            worst = min(cmp_.values())
            if worst < CONC_BLK_FLOOR:
                failures.append(f"concurrent below blocking on {fam}: "
                                f"{worst:.3f}")
        frag_ratio = dispatch_cmp.get("fragmented", {}).get("time_sharing", 0.0)
        if frag_ratio < args.frag_margin:
            failures.append(f"fragmented concurrent/blocking {frag_ratio:.3f} "
                            f"below margin {args.frag_margin:.2f}")
        if not (ctx_smoke_tp and ctx_smoke_tp > 0):
            failures.append(f"context agent failed to serve the fragmented "
                            f"smoke trace (tp={ctx_smoke_tp})")
        if fleet_smoke is not None:
            if not fleet_smoke["served"]:
                failures.append("fleet smoke: a router produced cross-pod "
                                "or unserved work on the (8, 4) fleet")
            if not fleet_smoke["vec_parity"]:
                failures.append(
                    f"fleet smoke: vectorized fleet p99 diverges from heap "
                    f"by {fleet_smoke['vec_heap_p99_gap_s']:.4f}s on the "
                    f"hash cell")
            if not fleet_smoke.get("telemetry_matches_summary", True):
                failures.append("fleet smoke: telemetry metrics diverge "
                                "from summary() on the telemetry-enabled "
                                "cell")
        missing = missing_keys(args.bench_json, REQUIRED_KEYS)
        if missing:
            failures.append(f"{args.bench_json} missing keys: {missing}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"smoke": True, **result}, f, indent=1)
        if failures:
            print("SMOKE FAIL: " + "; ".join(failures))
            sys.exit(1)
        print(f"smoke ok: rl_retrain/ts {ratio:.3f} on poisson "
              f"(floor {args.ratio_floor:.2f}), fragmented conc/blk "
              f"{frag_ratio:.3f} (margin {args.frag_margin:.2f}), "
              f"context agent serves fragmented (tp={ctx_smoke_tp:.3f}), "
              f"fleet (8,4) served by all routers (vec/heap p99 gap "
              f"{fleet_smoke['vec_heap_p99_gap_s']:.4f}s), "
              f"{args.bench_json} keys present")
        return

    out = args.out or "BENCH_online.json"
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}: rl_retrain/ts " +
          ", ".join(f"{t}={r:.3f}" for t, r in rl_vs_ts.items()) +
          "; conc/blk " +
          ", ".join(f"{t}={r['time_sharing']:.3f}"
                    for t, r in dispatch_cmp.items()) +
          "; ctx/prof " +
          ", ".join(f"{t}={arrival[t]['rl_context_vs_profile_only']:.3f}"
                    for t in families))


if __name__ == "__main__":
    main()
