"""Training-engine throughput: seed-equivalent scalar loop vs scanned engine.

Measures episodes/sec of ``train_agent_scalar`` (the seed per-step Python
loop, 1 DQN update per transition) against the vectorized ``train_agent``
(B envs fused into one jitted ``lax.scan``) at their default configurations,
and writes ``BENCH_train.json`` so future PRs have a perf trajectory to
regress against.  Both engines are warmed first so jit compilation is not
billed to either side.  The full run also compares uniform vs prioritized
replay (``per_alpha``) at matched update work — identical update cadence
and batch size, only the sampling distribution differs — across several
seeds, recording each run's final mean eval throughput.

    PYTHONPATH=src python -m benchmarks.train_throughput [--fast] \
        [--out BENCH_train.json] [--per-seeds 3]

``--smoke`` is the CI guard: tiny episode counts (< 60 s total), fails
(exit 1) if the vectorized/scalar speedup drops below ``--speedup-floor``
or if the committed ``BENCH_train.json`` is missing required keys.  Smoke
mode does not overwrite the committed trajectory unless ``--out`` is given.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import emit, missing_keys
from repro.core import (
    EnvConfig, TrainConfig, make_zoo, train_agent, train_agent_scalar,
)

REQUIRED_KEYS = (
    "scalar", "vectorized", "vectorized_matched_updates",
    "scalar_eps_per_sec", "vectorized_eps_per_sec",
    "speedup", "speedup_matched_updates",
)


def _best_of(n: int, run) -> tuple[int, float]:
    """Best-of-n episodes/sec — damps noisy-neighbor interference on the box."""
    results = [run() for _ in range(n)]
    return max(results, key=lambda r: r[0] / r[1])


def _bench_scalar(zoo, env_cfg, episodes: int, repeats: int = 2) -> dict:
    # warm the jitted act/update paths outside the timed region
    train_agent_scalar(zoo, env_cfg, TrainConfig(episodes=3, eval_every=10**9))
    cfg = TrainConfig(episodes=episodes, eval_every=10**9)

    def run():
        t0 = time.perf_counter()
        _, hist = train_agent_scalar(zoo, env_cfg, cfg)
        return hist[-1]["episode"], time.perf_counter() - t0

    eps, dt = _best_of(repeats, run)
    return {"episodes": eps, "seconds": dt, "eps_per_sec": eps / dt,
            "updates_per_transition": 1.0}


def _bench_vectorized(zoo, env_cfg, episodes: int, update_every: int | None = None,
                      repeats: int = 2) -> dict:
    kw = {} if update_every is None else {"update_every": update_every}
    cfg = TrainConfig(episodes=episodes, eval_every=10**9, **kw)
    # warm with the *same* config: the scan's segment length is a static
    # dimension derived from (episodes, eval_every, batch_envs), so a
    # smaller warm run would leave the measured run recompiling
    train_agent(zoo, env_cfg, cfg)

    def run():
        t0 = time.perf_counter()
        _, hist = train_agent(zoo, env_cfg, cfg)
        return hist[-1]["episode"], time.perf_counter() - t0

    eps, dt = _best_of(repeats, run)
    return {"episodes": eps, "seconds": dt, "eps_per_sec": eps / dt,
            "batch_envs": cfg.batch_envs, "update_every": cfg.update_every,
            "updates_per_transition": 1.0 / cfg.update_every}


def _per_comparison(zoo, env_cfg, episodes: int, seeds: list[int],
                    alpha: float) -> dict:
    """Uniform vs prioritized replay at matched update work.

    Everything but ``per_alpha`` stays at TrainConfig defaults — same
    ``update_every``, batch size, target-sync cadence and ε schedule — so
    the two variants spend identical gradient work and differ only in which
    transitions they sample.  Two budgets are reported because that is
    where the effect lives: at the **sample-efficiency budget** (the
    ε-decay horizon, ~1/3 of the full run) prioritization front-loads the
    informative close-group transitions and the 3-seed mean eval
    throughput clears uniform; at the **converged budget** both samplers
    see the whole repository many times over and the difference washes
    into seed noise (single-record evals swing ±0.05 between seeds).  The
    first run of each (variant, budget) includes the engine's jit compile;
    ``eval_throughput`` (the quality metric) is timing-independent.
    """
    sample_eps = max(1, episodes // 3)
    out = {"seeds": list(seeds), "per_alpha": alpha,
           "matched_update_work": ("identical update_every/batch_size/"
                                   "target-sync; only replay sampling differs"),
           "note": ("mean_eval_throughput averages every history record of a "
                    "run (sample-efficiency view); final_eval_throughput is "
                    "the last record; cross-seed means are the headline — "
                    "per-seed single records carry ~±0.05 noise")}
    budgets = {f"sample_efficiency_{sample_eps}ep": sample_eps,
               f"converged_{episodes}ep": episodes}
    for bname, eps in budgets.items():
        section: dict = {"episodes": eps, "uniform": [], "prioritized": []}
        for name, a in (("uniform", 0.0), ("prioritized", alpha)):
            for s in seeds:
                cfg = TrainConfig(episodes=eps, seed=s, per_alpha=a)
                t0 = time.perf_counter()
                _, hist = train_agent(zoo, env_cfg, cfg)
                dt = time.perf_counter() - t0
                rec = {"seed": s,
                       "mean_eval_throughput": float(
                           sum(r["eval_throughput"] for r in hist) / len(hist)),
                       "final_eval_throughput": hist[-1]["eval_throughput"],
                       "episodes": hist[-1]["episode"],
                       "eps_per_sec": hist[-1]["episode"] / dt}
                section[name].append(rec)
                emit(f"train_per_{bname}_{name}_s{s}",
                     dt * 1e6 / rec["episodes"],
                     f"tp={rec['mean_eval_throughput']:.3f}")
        for name in ("uniform", "prioritized"):
            for k in ("mean_eval_throughput", "final_eval_throughput"):
                vals = [r[k] for r in section[name]]
                section[f"{name}_{k}"] = sum(vals) / len(vals)
        out[bname] = section
    return out


def _telemetry_series(zoo, env_cfg, episodes: int, seed: int = 0) -> dict:
    """One telemetry-enabled training run -> the per-record series.

    ``TrainConfig(telemetry=True)`` threads (loss, |TD|, grad-norm) out of
    the scan carry at zero extra update work (same gradients, bit-identical
    parameter trajectory — pinned by ``tests/test_telemetry.py``); ε/β ride
    along from the schedules.  Written to ``BENCH_train_telemetry.json``
    next to the throughput trajectory so training-dynamics regressions are
    visible across PRs, not just end-point eval throughput.
    """
    cfg = TrainConfig(episodes=episodes, eval_every=max(1, episodes // 12),
                      seed=seed, telemetry=True)
    t0 = time.perf_counter()
    _, hist = train_agent(zoo, env_cfg, cfg)
    dt = time.perf_counter() - t0
    series = {k: [r[k] for r in hist]
              for k in ("episode", "eps", "loss", "td_abs", "grad_norm",
                        "updates", "ep_reward", "eval_throughput")}
    return {"episodes": episodes, "seed": seed, "window": env_cfg.window,
            "wall_s": dt, "series": series,
            "note": ("loss/td_abs/grad_norm are means of the scanned "
                     "engine's per-step update samples between records; "
                     "eps is the ε schedule at the record; beta only "
                     "varies under per_alpha > 0 runs")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="shrink measured episodes")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: tiny counts, check speedup floor + keys")
    ap.add_argument("--speedup-floor", type=float, default=2.0,
                    help="min vectorized/scalar speedup accepted in --smoke")
    ap.add_argument("--window", type=int, default=12)
    ap.add_argument("--scalar-episodes", type=int, default=None)
    ap.add_argument("--vec-episodes", type=int, default=None)
    ap.add_argument("--per-seeds", type=int, default=3,
                    help="seeds for the uniform-vs-prioritized comparison "
                         "(full mode only; 0 disables)")
    ap.add_argument("--per-alpha", type=float, default=0.5)
    ap.add_argument("--per-episodes", type=int, default=3000)
    ap.add_argument("--bench-json", default="BENCH_train.json",
                    help="committed trajectory checked for keys in --smoke")
    ap.add_argument("--out", default=None,
                    help="where to write results (default BENCH_train.json; "
                         "smoke mode writes nothing unless given)")
    ap.add_argument("--telemetry-episodes", type=int, default=600,
                    help="episodes for the telemetry-series run")
    ap.add_argument("--telemetry-out", default="BENCH_train_telemetry.json")
    ap.add_argument("--telemetry-only", action="store_true",
                    help="write only the telemetry series and exit")
    args, _ = ap.parse_known_args()
    if args.telemetry_only:
        zoo = make_zoo(dryrun_dir=None)
        env_cfg = EnvConfig(window=args.window, c_max=4)
        tel = _telemetry_series(zoo, env_cfg, args.telemetry_episodes)
        with open(args.telemetry_out, "w") as f:
            json.dump(tel, f, indent=1)
        print(f"wrote {args.telemetry_out}: {len(tel['series']['episode'])} "
              f"records over {tel['episodes']} episodes")
        return
    if args.smoke:
        # scalar must run long enough to pass replay warmup (~9 episodes at
        # W=12 before batch_size transitions exist) or it measures a loop
        # that never updates and the speedup floor is meaningless
        scalar_eps = args.scalar_episodes or 15
        vec_eps = args.vec_episodes or 150
    else:
        scalar_eps = args.scalar_episodes or (15 if args.fast else 40)
        vec_eps = args.vec_episodes or (200 if args.fast else 600)
    repeats = 1 if args.smoke else 2

    zoo = make_zoo(dryrun_dir=None)
    env_cfg = EnvConfig(window=args.window, c_max=4)

    print("name,us_per_call,derived")
    scalar = _bench_scalar(zoo, env_cfg, scalar_eps, repeats)
    emit("train_scalar", scalar["seconds"] * 1e6 / scalar["episodes"],
         f"{scalar['eps_per_sec']:.2f}eps/s")
    vec = _bench_vectorized(zoo, env_cfg, vec_eps, repeats=repeats)
    emit("train_vectorized", vec["seconds"] * 1e6 / vec["episodes"],
         f"{vec['eps_per_sec']:.2f}eps/s")
    speedup = vec["eps_per_sec"] / scalar["eps_per_sec"]
    emit("train_speedup", 0.0, f"{speedup:.1f}x")

    if args.smoke:
        failures = []
        if speedup < args.speedup_floor:
            failures.append(f"speedup {speedup:.2f}x below floor "
                            f"{args.speedup_floor:.2f}x")
        missing = missing_keys(args.bench_json, REQUIRED_KEYS)
        if missing:
            failures.append(f"{args.bench_json} missing keys: {missing}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"smoke": True, "window": args.window,
                           "scalar": scalar, "vectorized": vec,
                           "speedup": speedup}, f, indent=1)
        if failures:
            print("SMOKE FAIL: " + "; ".join(failures))
            sys.exit(1)
        print(f"smoke ok: {speedup:.1f}x (floor {args.speedup_floor:.1f}x), "
              f"{args.bench_json} keys present")
        return

    # engine-only comparison: same 1-update-per-transition work as the seed
    # loop, isolating the scan/vmap/on-device-replay gain from the cadence
    matched = _bench_vectorized(zoo, env_cfg, max(20, vec_eps // 10),
                                update_every=1)
    emit("train_vectorized_matched", matched["seconds"] * 1e6 / matched["episodes"],
         f"{matched['eps_per_sec']:.2f}eps/s")
    matched_speedup = matched["eps_per_sec"] / scalar["eps_per_sec"]
    emit("train_speedup_matched_updates", 0.0, f"{matched_speedup:.1f}x")

    result = {
        "window": args.window,
        "cpus": os.cpu_count(),
        "scalar": scalar,
        "vectorized": vec,
        "vectorized_matched_updates": matched,
        "scalar_eps_per_sec": scalar["eps_per_sec"],
        "vectorized_eps_per_sec": vec["eps_per_sec"],
        "speedup": speedup,
        "speedup_matched_updates": matched_speedup,
        "note": ("scalar = seed loop (1 update/transition); vectorized = "
                 "scanned engine at default TrainConfig (1 update per "
                 "update_every transitions, target sync cadence preserved "
                 "in transitions); 'speedup' compares default configs — "
                 "see speedup_matched_updates for the engine-only gain at "
                 "equal update work; eval_throughput figures are the mean "
                 "relative throughput over the 20 train queues from the "
                 "device-resident greedy eval"),
    }
    if args.per_seeds > 0:
        result["per_comparison"] = _per_comparison(
            zoo, env_cfg, args.per_episodes, list(range(args.per_seeds)),
            args.per_alpha)
    tel = _telemetry_series(zoo, env_cfg, args.telemetry_episodes)
    with open(args.telemetry_out, "w") as f:
        json.dump(tel, f, indent=1)
    print(f"wrote {args.telemetry_out}")
    out = args.out or "BENCH_train.json"
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {out}: {speedup:.1f}x")


if __name__ == "__main__":
    main()
