"""Training-engine throughput: seed-equivalent scalar loop vs scanned engine.

Measures episodes/sec of ``train_agent_scalar`` (the seed per-step Python
loop, 1 DQN update per transition) against the vectorized ``train_agent``
(B envs fused into one jitted ``lax.scan``) at their default configurations,
and writes ``BENCH_train.json`` so future PRs have a perf trajectory to
regress against.  Both engines are warmed first so jit compilation is not
billed to either side.

    PYTHONPATH=src python -m benchmarks.train_throughput [--fast] \
        [--out BENCH_train.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import emit
from repro.core import (
    EnvConfig, TrainConfig, make_zoo, train_agent, train_agent_scalar,
)


def _best_of(n: int, run) -> tuple[int, float]:
    """Best-of-n episodes/sec — damps noisy-neighbor interference on the box."""
    results = [run() for _ in range(n)]
    return max(results, key=lambda r: r[0] / r[1])


def _bench_scalar(zoo, env_cfg, episodes: int) -> dict:
    # warm the jitted act/update paths outside the timed region
    train_agent_scalar(zoo, env_cfg, TrainConfig(episodes=3, eval_every=10**9))
    cfg = TrainConfig(episodes=episodes, eval_every=10**9)

    def run():
        t0 = time.perf_counter()
        _, hist = train_agent_scalar(zoo, env_cfg, cfg)
        return hist[-1]["episode"], time.perf_counter() - t0

    eps, dt = _best_of(2, run)
    return {"episodes": eps, "seconds": dt, "eps_per_sec": eps / dt,
            "updates_per_transition": 1.0}


def _bench_vectorized(zoo, env_cfg, episodes: int, update_every: int | None = None) -> dict:
    kw = {} if update_every is None else {"update_every": update_every}
    cfg = TrainConfig(episodes=episodes, eval_every=10**9, **kw)
    # warm with the *same* config: the scan's segment length is a static
    # dimension derived from (episodes, eval_every, batch_envs), so a
    # smaller warm run would leave the measured run recompiling
    train_agent(zoo, env_cfg, cfg)

    def run():
        t0 = time.perf_counter()
        _, hist = train_agent(zoo, env_cfg, cfg)
        return hist[-1]["episode"], time.perf_counter() - t0

    eps, dt = _best_of(2, run)
    return {"episodes": eps, "seconds": dt, "eps_per_sec": eps / dt,
            "batch_envs": cfg.batch_envs, "update_every": cfg.update_every,
            "updates_per_transition": 1.0 / cfg.update_every}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="shrink measured episodes")
    ap.add_argument("--window", type=int, default=12)
    ap.add_argument("--scalar-episodes", type=int, default=None)
    ap.add_argument("--vec-episodes", type=int, default=None)
    ap.add_argument("--out", default="BENCH_train.json")
    args, _ = ap.parse_known_args()
    scalar_eps = args.scalar_episodes or (15 if args.fast else 40)
    vec_eps = args.vec_episodes or (200 if args.fast else 600)

    zoo = make_zoo(dryrun_dir=None)
    env_cfg = EnvConfig(window=args.window, c_max=4)

    print("name,us_per_call,derived")
    scalar = _bench_scalar(zoo, env_cfg, scalar_eps)
    emit("train_scalar", scalar["seconds"] * 1e6 / scalar["episodes"],
         f"{scalar['eps_per_sec']:.2f}eps/s")
    vec = _bench_vectorized(zoo, env_cfg, vec_eps)
    emit("train_vectorized", vec["seconds"] * 1e6 / vec["episodes"],
         f"{vec['eps_per_sec']:.2f}eps/s")
    speedup = vec["eps_per_sec"] / scalar["eps_per_sec"]
    emit("train_speedup", 0.0, f"{speedup:.1f}x")
    # engine-only comparison: same 1-update-per-transition work as the seed
    # loop, isolating the scan/vmap/on-device-replay gain from the cadence
    matched = _bench_vectorized(zoo, env_cfg, max(20, vec_eps // 10),
                                update_every=1)
    emit("train_vectorized_matched", matched["seconds"] * 1e6 / matched["episodes"],
         f"{matched['eps_per_sec']:.2f}eps/s")
    matched_speedup = matched["eps_per_sec"] / scalar["eps_per_sec"]
    emit("train_speedup_matched_updates", 0.0, f"{matched_speedup:.1f}x")

    result = {
        "window": args.window,
        "cpus": os.cpu_count(),
        "scalar": scalar,
        "vectorized": vec,
        "vectorized_matched_updates": matched,
        "scalar_eps_per_sec": scalar["eps_per_sec"],
        "vectorized_eps_per_sec": vec["eps_per_sec"],
        "speedup": speedup,
        "speedup_matched_updates": matched_speedup,
        "note": ("scalar = seed loop (1 update/transition); vectorized = "
                 "scanned engine at default TrainConfig (1 update per "
                 "update_every transitions, target sync cadence preserved "
                 "in transitions); 'speedup' compares default configs — "
                 "see speedup_matched_updates for the engine-only gain at "
                 "equal update work"),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}: {speedup:.1f}x")


if __name__ == "__main__":
    main()
