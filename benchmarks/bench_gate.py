"""CI gate over the *committed* benchmark trajectories (stdlib only).

``--smoke`` re-measures on tiny budgets; this gate instead pins the
numbers already committed in ``BENCH_online.json`` / ``BENCH_train.json``
so a PR cannot silently regress the recorded trajectory (ROADMAP's CI
hardening item: gate on ``per_comparison`` and ``BENCH_online.json``
ratio regressions):

  * every trace family's recorded ``rl_retrain`` throughput stays at or
    above ``RL_TS_FLOOR`` x time sharing;
  * concurrent dispatch never records below blocking-window dispatch, and
    strictly beats it on the fragmented family;
  * the arrival-aware agent (profiles + live cluster state,
    ``docs/observation.md``) records at or above ``ARRIVAL_FLOOR`` x the
    profile-only agent on the fragmented family — the context features
    must at least recover the packing behavior the dispatch layer supplies
    by hand, and never regress it;
  * PER's recorded sample-efficiency comparison has not drifted: at the
    1000-episode budget, prioritized replay's mean eval throughput stays
    within ``PER_DRIFT`` of uniform replay's (the matched-update-work
    comparison of PR 2).

Exits 1 with a failure list; run as
``PYTHONPATH=src python -m benchmarks.bench_gate``.
"""
from __future__ import annotations

import json
import os
import sys

RL_TS_FLOOR = 0.97        # committed rl_retrain/time_sharing per family
CONC_BLK_FLOOR = 0.999    # committed concurrent/blocking per family
FRAG_MARGIN = 1.02        # fragmented family must strictly win
ARRIVAL_FLOOR = 1.0       # committed rl_context/rl_profile_only, fragmented
PER_DRIFT = 0.15          # |prioritized - uniform| / uniform at 1000 ep


def _load(path: str, failures: list[str]) -> dict | None:
    if not os.path.exists(path):
        failures.append(f"{path} missing")
        return None
    with open(path) as f:
        return json.load(f)


def gate_online(bench: dict, failures: list[str]) -> None:
    for fam, ratio in bench.get("rl_vs_time_sharing", {}).items():
        if ratio < RL_TS_FLOOR:
            failures.append(f"online: rl_retrain/ts on {fam} = {ratio:.3f} "
                            f"< floor {RL_TS_FLOOR}")
    cmp_ = bench.get("dispatch_comparison", {})
    if not cmp_:
        failures.append("online: dispatch_comparison section missing")
    for fam, ratios in cmp_.items():
        worst = min(ratios.values())
        if worst < CONC_BLK_FLOOR:
            failures.append(f"online: concurrent/blocking on {fam} = "
                            f"{worst:.3f} < floor {CONC_BLK_FLOOR}")
    frag = cmp_.get("fragmented", {}).get("time_sharing", 0.0)
    if frag < FRAG_MARGIN:
        failures.append(f"online: fragmented concurrent/blocking = "
                        f"{frag:.3f} < margin {FRAG_MARGIN}")
    aa = bench.get("arrival_aware") or {}
    if not aa:
        failures.append("online: arrival_aware section missing")
    else:
        ctx = aa.get("fragmented", {}).get("rl_context_vs_profile_only", 0.0)
        if ctx < ARRIVAL_FLOOR:
            failures.append(f"online: arrival-aware rl_context/profile_only "
                            f"on fragmented = {ctx:.3f} < floor "
                            f"{ARRIVAL_FLOOR}")


def gate_train(bench: dict, failures: list[str]) -> None:
    per = bench.get("per_comparison")
    if not per:
        failures.append("train: per_comparison section missing")
        return
    se = per.get("sample_efficiency_1000ep", {})
    uni = se.get("uniform_mean_eval_throughput")
    pri = se.get("prioritized_mean_eval_throughput")
    if uni is None or pri is None:
        failures.append("train: per_comparison sample-efficiency keys missing")
        return
    drift = abs(pri - uni) / uni
    if drift > PER_DRIFT:
        failures.append(f"train: PER vs uniform drift {drift:.3f} "
                        f"> {PER_DRIFT} (uniform {uni:.3f}, "
                        f"prioritized {pri:.3f})")


def main() -> None:
    failures: list[str] = []
    online = _load("BENCH_online.json", failures)
    if online is not None:
        gate_online(online, failures)
    train = _load("BENCH_train.json", failures)
    if train is not None:
        gate_train(train, failures)
    if failures:
        print("BENCH GATE FAIL:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("bench gate ok: committed BENCH_online.json / BENCH_train.json "
          "ratios within floors")


if __name__ == "__main__":
    main()
