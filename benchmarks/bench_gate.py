"""CI gate over the *committed* benchmark trajectories (stdlib only).

``--smoke`` re-measures on tiny budgets; this gate instead pins the
numbers already committed in ``BENCH_online.json`` / ``BENCH_train.json``
so a PR cannot silently regress the recorded trajectory (ROADMAP's CI
hardening item: gate on ``per_comparison`` and ``BENCH_online.json``
ratio regressions):

  * every trace family's recorded ``rl_retrain`` throughput stays at or
    above ``RL_TS_FLOOR`` x time sharing;
  * concurrent dispatch never records below blocking-window dispatch, and
    strictly beats it on the fragmented family;
  * the arrival-aware agent (profiles + live cluster state,
    ``docs/observation.md``) records at or above ``ARRIVAL_FLOOR`` x the
    profile-only agent on the fragmented family — the context features
    must at least recover the packing behavior the dispatch layer supplies
    by hand, and never regress it;
  * PER's recorded sample-efficiency comparison has not drifted: at the
    1000-episode budget, prioritized replay's mean eval throughput stays
    within ``PER_DRIFT`` of uniform replay's (the matched-update-work
    comparison of PR 2);
  * the vectorized engine's recorded vmapped sweep (``vectorized_sim``)
    stays at or above ``VECSIM_SPEEDUP_FLOOR`` x the Python heap's
    traces/sec at batch >= 64;
  * the in-graph RL serving sweep (``vectorized_rl`` — the same engine
    running the trained agent's episodes at the window-formation seam)
    stays at or above ``VECRL_SPEEDUP_FLOOR`` x the heap replaying the
    identical agent, also at batch >= 64;
  * the fleet grid (``fleet_scale``) is recorded at or above
    ``FLEET_MIN_ARRIVALS`` arrivals, the best router's p99 wait on the
    fragmented heterogeneous fleet stays at or above ``FLEET_P99_FLOOR``
    x hash routing's (smart placement must not lose to the stateless
    baseline), and the recorded ``single_pod_parity`` check — the
    ``pods=(8,)`` fleet bit-matching the committed single-pod cells —
    holds on every family;
  * the recorded ``telemetry_overhead`` ratio (telemetry-enabled /
    disabled sim wall, same machine, best-of-N both sides) stays at or
    below ``TELEMETRY_OVERHEAD_MAX`` on both engines — observability must
    not tax the hot path;
  * the recorded ``retrain_trigger`` A/B keeps drift-triggered serving at
    or above ``DRIFT_RETRAIN_FLOOR`` x clock-triggered throughput while
    retraining no more often;
  * the recorded ``queueing_reward`` A/B (sim-in-the-loop
    ``train_online`` refinement vs the frozen proxy-trained agent, served
    on identical traces) covers all five trace families and the
    queueing-trained agent's p99 wait is at or below the proxy-trained
    agent's on at least ``QUEUEING_WIN_FAMILIES_MIN`` of them — training
    on the real queueing outcome must not lose to the throughput proxy.

A *missing* optional section is a warning, not a failure: the trajectory
is grown incrementally via ``online_sim --section <name>`` merges, and a
PR that lands mid-series must not brick CI before its section is
committed.  Sections that are present are always gated hard.

Exits 1 with a failure list; run as
``PYTHONPATH=src python -m benchmarks.bench_gate``.
"""
from __future__ import annotations

import json
import os
import sys

RL_TS_FLOOR = 0.97        # committed rl_retrain/time_sharing per family
CONC_BLK_FLOOR = 0.999    # committed concurrent/blocking per family
FRAG_MARGIN = 1.02        # fragmented family must strictly win
ARRIVAL_FLOOR = 1.0       # committed rl_context/rl_profile_only, fragmented
PER_DRIFT = 0.15          # |prioritized - uniform| / uniform at 1000 ep
VECSIM_SPEEDUP_FLOOR = 5.0  # committed vmapped-sweep traces/sec vs heap
VECRL_SPEEDUP_FLOOR = 3.0   # committed in-graph RL sweep vs heap RL serving
VECSIM_MIN_BATCH = 64     # sweep batch the speedup must be recorded at
FLEET_P99_FLOOR = 1.0     # best router p99 vs hash, fragmented fleet
FLEET_MIN_ARRIVALS = 10_000  # committed fleet grid scale (p50/p99 regime)
TELEMETRY_OVERHEAD_MAX = 1.10  # telemetry-on/off sim wall ratio, both engines
DRIFT_RETRAIN_FLOOR = 0.97  # drift-triggered/clock-triggered throughput
QUEUEING_MIN_FAMILIES = 5   # queueing_reward A/B must cover every family
QUEUEING_WIN_FAMILIES_MIN = 3  # families where queueing p99 <= proxy p99


def _load(path: str, failures: list[str]) -> dict | None:
    if not os.path.exists(path):
        failures.append(f"{path} missing")
        return None
    with open(path) as f:
        return json.load(f)


def _warn_missing(section: str, warnings: list[str]) -> None:
    warnings.append(f"{section} section missing — gate skipped (commit it "
                    f"via the matching --section merge)")


def gate_online(bench: dict, failures: list[str],
                warnings: list[str]) -> None:
    for fam, ratio in bench.get("rl_vs_time_sharing", {}).items():
        if ratio < RL_TS_FLOOR:
            failures.append(f"online: rl_retrain/ts on {fam} = {ratio:.3f} "
                            f"< floor {RL_TS_FLOOR}")
    cmp_ = bench.get("dispatch_comparison") or {}
    if not cmp_:
        _warn_missing("online: dispatch_comparison", warnings)
    else:
        for fam, ratios in cmp_.items():
            worst = min(ratios.values())
            if worst < CONC_BLK_FLOOR:
                failures.append(f"online: concurrent/blocking on {fam} = "
                                f"{worst:.3f} < floor {CONC_BLK_FLOOR}")
        frag = cmp_.get("fragmented", {}).get("time_sharing", 0.0)
        if frag < FRAG_MARGIN:
            failures.append(f"online: fragmented concurrent/blocking = "
                            f"{frag:.3f} < margin {FRAG_MARGIN}")
    aa = bench.get("arrival_aware") or {}
    if not aa:
        _warn_missing("online: arrival_aware", warnings)
    else:
        ctx = aa.get("fragmented", {}).get("rl_context_vs_profile_only", 0.0)
        if ctx < ARRIVAL_FLOOR:
            failures.append(f"online: arrival-aware rl_context/profile_only "
                            f"on fragmented = {ctx:.3f} < floor "
                            f"{ARRIVAL_FLOOR}")
    vec = bench.get("vectorized_sim") or {}
    if not vec:
        _warn_missing("online: vectorized_sim", warnings)
    else:
        sweep = vec.get("sweep", {})
        batch = sweep.get("batch", 0)
        speedup = sweep.get("speedup_vs_heap", 0.0)
        if batch < VECSIM_MIN_BATCH:
            failures.append(f"online: vectorized_sim sweep batch {batch} "
                            f"< {VECSIM_MIN_BATCH}")
        if speedup < VECSIM_SPEEDUP_FLOOR:
            failures.append(f"online: vectorized sweep speedup vs heap = "
                            f"{speedup:.2f}x < floor "
                            f"{VECSIM_SPEEDUP_FLOOR:.1f}x")
    vecrl = bench.get("vectorized_rl") or {}
    if not vecrl:
        _warn_missing("online: vectorized_rl", warnings)
    else:
        sweep = vecrl.get("sweep", {})
        batch = sweep.get("batch", 0)
        speedup = sweep.get("speedup_vs_heap", 0.0)
        if batch < VECSIM_MIN_BATCH:
            failures.append(f"online: vectorized_rl sweep batch {batch} "
                            f"< {VECSIM_MIN_BATCH}")
        if speedup < VECRL_SPEEDUP_FLOOR:
            failures.append(f"online: in-graph RL sweep speedup vs heap RL "
                            f"= {speedup:.2f}x < floor "
                            f"{VECRL_SPEEDUP_FLOOR:.1f}x")
    fleet = bench.get("fleet_scale") or {}
    if not fleet:
        _warn_missing("online: fleet_scale", warnings)
    else:
        n_arr = fleet.get("n_arrivals", 0)
        if n_arr < FLEET_MIN_ARRIVALS:
            failures.append(f"online: fleet_scale recorded at {n_arr} "
                            f"arrivals < {FLEET_MIN_ARRIVALS}")
        frag = fleet.get("families", {}).get("fragmented", {})
        ratios = frag.get("ratios", {})
        best = max((r.get("time_sharing", 0.0) for r in ratios.values()),
                   default=0.0)
        if best < FLEET_P99_FLOOR:
            failures.append(f"online: best router p99 vs hash on the "
                            f"fragmented fleet = {best:.3f}x < floor "
                            f"{FLEET_P99_FLOOR:.2f}x")
        parity = fleet.get("single_pod_parity") or {}
        if not parity:
            failures.append("online: fleet_scale.single_pod_parity missing")
        for fam, ok in parity.items():
            if not ok:
                failures.append(f"online: pods=(8,) fleet diverges from the "
                                f"committed single-pod {fam} cell")
    tel = bench.get("telemetry_overhead") or {}
    if not tel:
        _warn_missing("online: telemetry_overhead", warnings)
    else:
        for engine in ("heap", "vectorized"):
            ratio = tel.get(engine, {}).get("overhead_ratio")
            if ratio is None:
                failures.append(f"online: telemetry_overhead.{engine}."
                                f"overhead_ratio missing")
            elif ratio > TELEMETRY_OVERHEAD_MAX:
                failures.append(f"online: {engine} telemetry overhead "
                                f"{ratio:.3f}x > max "
                                f"{TELEMETRY_OVERHEAD_MAX:.2f}x")
    rt = bench.get("retrain_trigger") or {}
    if not rt:
        _warn_missing("online: retrain_trigger", warnings)
    else:
        ratio = rt.get("drift_vs_clock_throughput", 0.0)
        if ratio < DRIFT_RETRAIN_FLOOR:
            failures.append(f"online: drift-triggered/clock-triggered "
                            f"throughput = {ratio:.3f} < floor "
                            f"{DRIFT_RETRAIN_FLOOR}")
        if rt.get("drift", {}).get("retrains", 0) > \
                rt.get("clock", {}).get("retrains", 0):
            failures.append("online: drift trigger recorded MORE retrains "
                            "than the clock — the gate is supposed to prove "
                            "it retrains less, not more")
    qr = bench.get("queueing_reward") or {}
    if not qr:
        _warn_missing("online: queueing_reward", warnings)
    else:
        fams = qr.get("families") or {}
        if len(fams) < QUEUEING_MIN_FAMILIES:
            failures.append(f"online: queueing_reward covers {len(fams)} "
                            f"families < {QUEUEING_MIN_FAMILIES}")
        wins = sum(1 for f in fams.values() if f.get("win"))
        recorded = qr.get("families_won")
        if recorded is not None and recorded != wins:
            failures.append(f"online: queueing_reward.families_won "
                            f"{recorded} disagrees with per-family win "
                            f"flags ({wins})")
        if wins < QUEUEING_WIN_FAMILIES_MIN:
            failures.append(f"online: queueing-trained agent wins p99 wait "
                            f"on {wins} families < "
                            f"{QUEUEING_WIN_FAMILIES_MIN} (vs proxy-trained)")


def gate_train(bench: dict, failures: list[str],
               warnings: list[str]) -> None:
    per = bench.get("per_comparison")
    if not per:
        _warn_missing("train: per_comparison", warnings)
        return
    se = per.get("sample_efficiency_1000ep", {})
    uni = se.get("uniform_mean_eval_throughput")
    pri = se.get("prioritized_mean_eval_throughput")
    if uni is None or pri is None:
        failures.append("train: per_comparison sample-efficiency keys missing")
        return
    drift = abs(pri - uni) / uni
    if drift > PER_DRIFT:
        failures.append(f"train: PER vs uniform drift {drift:.3f} "
                        f"> {PER_DRIFT} (uniform {uni:.3f}, "
                        f"prioritized {pri:.3f})")


def main() -> None:
    failures: list[str] = []
    warnings: list[str] = []
    online = _load("BENCH_online.json", failures)
    if online is not None:
        gate_online(online, failures, warnings)
    train = _load("BENCH_train.json", failures)
    if train is not None:
        gate_train(train, failures, warnings)
    if warnings:
        print("BENCH GATE WARN:\n  " + "\n  ".join(warnings))
    if failures:
        print("BENCH GATE FAIL:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("bench gate ok: committed BENCH_online.json / BENCH_train.json "
          "ratios within floors")


if __name__ == "__main__":
    main()
