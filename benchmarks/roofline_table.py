"""§Roofline table: per (arch x shape) baseline roofline terms from the
dry-run artifacts (single-pod mesh). Emits CSV + a markdown table for
EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

from benchmarks.common import DRYRUN_DIR, emit


def load_records(dryrun_dir: str = DRYRUN_DIR, rules: str = "baseline",
                 mesh: str = "pod") -> list[dict]:
    recs = []
    if not os.path.isdir(dryrun_dir):
        return recs
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fn)) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("rules") == rules:
            recs.append(r)
    return recs


def roofline_table(fast: bool = False) -> list[dict]:
    recs = load_records()
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"cell": f"{r['arch']} x {r['shape']}", "error": r.get("error", "?")})
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "FAILED")
            continue
        # decode cells are bandwidth-roofline jobs: report the fraction of
        # the minimal HBM traffic time too (flops fraction ~0 by nature)
        from repro.launch.roofline import HBM_BW

        frac_mem = 0.0
        if r.get("step_time_lb_s", 0) > 0:
            frac_mem = (r["model_bytes_min_total"] / r["chips"] / HBM_BW) / r["step_time_lb_s"]
        row = {
            "cell": f"{r['arch']} x {r['shape']}",
            "compute_ms": r["compute_term_s"] * 1e3,
            "memory_ms": r["memory_term_s"] * 1e3,
            "collective_ms": r["collective_term_s"] * 1e3,
            "dominant": r["dominant"],
            "model_flops": r["model_flops_total"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": max(r["roofline_fraction"], min(1.0, frac_mem)),
            "fits_hbm": r["fits_hbm"],
            "peak_gb": r["peak_bytes"] / 1e9,
        }
        rows.append(row)
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dom={row['dominant']};frac={row['roofline_frac']:.3f};useful={row['useful_ratio']:.3f}")
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| cell | compute (ms) | memory (ms) | collective (ms) | dominant | "
           "useful FLOPs ratio | roofline frac | fits HBM | peak GB/chip |")
    sep = "|---" * 9 + "|"
    lines = [hdr, sep]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['cell']} | FAILED: {r['error'][:60]} |" + " |" * 7)
            continue
        lines.append(
            f"| {r['cell']} | {r['compute_ms']:.1f} | {r['memory_ms']:.1f} | "
            f"{r['collective_ms']:.1f} | {r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_frac']:.3f} | {'Y' if r['fits_hbm'] else 'N'} | {r['peak_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = roofline_table()
    print(markdown(rows))
