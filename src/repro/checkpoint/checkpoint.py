"""Fault-tolerant checkpointing: atomic, manifest-based, auto-resume.

Layout:
    <dir>/step_<N>/manifest.json     tree structure + metadata
    <dir>/step_<N>/arrays.npz        flattened leaves keyed by path
    <dir>/step_<N>.done              commit marker (atomic rename target)

Restart protocol: `latest_step` only considers committed checkpoints (with a
.done marker), so a node failure mid-save can never be resumed from a torn
checkpoint — the previous committed step is used instead.  All pytrees here
are nested dicts of arrays/scalars (the framework's convention), so the tree
is reconstructible from path strings without pickling.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def visit(path, leaf):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _unflatten(flat: dict[str, np.ndarray]) -> dict:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None, keep_last: int = 3) -> str:
    """Atomically write checkpoint for `step`; prunes old committed steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    manifest = {
        "step": int(step),
        "extra": extra or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # commit marker written last -> crash-safe
        with open(final + ".done", "w") as f:
            f.write(str(step))
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    _prune(ckpt_dir, keep_last)
    return os.path.join(ckpt_dir, f"step_{step}")


def _prune(ckpt_dir: str, keep_last: int) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
        marker = os.path.join(ckpt_dir, f"step_{s}.done")
        if os.path.exists(marker):
            os.remove(marker)


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".done"):
            steps.append(int(name[len("step_"):-len(".done")]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int | None = None) -> tuple[dict, dict, int]:
    """Returns (tree, extra, step). Raises FileNotFoundError if none committed."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat), manifest["extra"], int(manifest["step"])
