"""Deterministic counter-based synthetic data pipeline.

Stateless-by-construction: batch(step) is a pure function of (seed, step,
row-range), so
  * checkpoint/resume needs only the integer step (no iterator state),
  * each host/slice loads exactly its row shard (`lo:hi`) with no
    coordination, and
  * elastic re-sharding after a failure is a pure re-partition of rows.

Two modes:
  * "uniform": i.i.d. tokens (throughput benchmarking).
  * "markov":  per-sequence affine recurrence t_{i+1} = a*t_i + b (mod V),
    a learnable structure so example training runs show loss decreasing.
"""
from __future__ import annotations

import numpy as np


class DataPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, mode: str = "markov"):
        assert mode in ("uniform", "markov")
        self.vocab_size = int(vocab_size)
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)
        self.seed = int(seed)
        self.mode = mode

    # -- core ---------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(key=[self.seed, step]))

    def batch(self, step: int, lo: int = 0, hi: int | None = None) -> dict:
        """Rows [lo, hi) of the global batch at `step` -> {"tokens","labels"}."""
        hi = self.global_batch if hi is None else hi
        n = hi - lo
        rng = self._rng(step)
        V, S = self.vocab_size, self.seq_len
        if self.mode == "uniform":
            all_tokens = rng.integers(0, V, size=(self.global_batch, S + 1), dtype=np.int64)
            tokens = all_tokens[lo:hi]
        else:
            # affine recurrence per row; draw per-row (a, b, t0) deterministically
            a = rng.integers(1, 8, size=(self.global_batch,))
            b = rng.integers(0, V, size=(self.global_batch,))
            t0 = rng.integers(0, V, size=(self.global_batch,))
            a, b, t0 = a[lo:hi], b[lo:hi], t0[lo:hi]
            tokens = np.empty((n, S + 1), dtype=np.int64)
            tokens[:, 0] = t0
            for i in range(S):
                tokens[:, i + 1] = (a * tokens[:, i] + b) % V
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    # -- convenience ----------------------------------------------------------
    def shard_bounds(self, shard: int, n_shards: int) -> tuple[int, int]:
        per = self.global_batch // n_shards
        rem = self.global_batch % n_shards
        lo = shard * per + min(shard, rem)
        return lo, lo + per + (1 if shard < rem else 0)
