"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, strictly sequential).

The mLSTM training path uses a *chunked* parallel form (the TPU analogue of
the fused CUDA recurrence): a lax.scan over sequence chunks carrying the
stabilized (C, n, m) state, with an intra-chunk quadratic gate matrix — the
same trick as chunked gated linear attention. A step-by-step sequential
reference (`mlstm_sequential`) backs the property tests.

Math (stabilized, per head; b = intra-chunk cumsum of log-f, g = cummax of
(log-i − b)):
    m_t   = b_t + M_t,  M_t = max(m_0, g_t)
    num_t = Σ_{s≤t} exp(li_s − b_s − M_t) (q_t·k_s) v_s + exp(m_0 − M_t) q_t C_0
    den_t = Σ_{s≤t} exp(li_s − b_s − M_t) (q_t·k_s)     + exp(m_0 − M_t) q_t n_0
    h_t   = o_t ⊙ num_t / max(|den_t|, exp(−m_t))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, conv1d_step, dense_init, pdtype, rmsnorm

NEG = -1e30


def m_inner(cfg) -> int:
    return int(cfg.xlstm.expand_m * cfg.d_model)


def s_ff(cfg) -> int:
    return int(round(cfg.xlstm.proj_factor_s * cfg.d_model))


# ===========================================================================
# mLSTM block
# ===========================================================================

def init_mlstm(key, cfg) -> dict:
    dt = pdtype(cfg)
    M, D, H = cfg.d_model, m_inner(cfg), cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((M,), jnp.float32),
        "w_up": dense_init(ks[0], (M, 2 * D), dt),
        "conv_w": dense_init(ks[1], (cfg.xlstm.d_conv, D), dt),
        "conv_b": jnp.zeros((D,), dt),
        "wq": dense_init(ks[2], (D, D), dt),
        "wk": dense_init(ks[3], (D, D), dt),
        "wv": dense_init(ks[4], (D, D), dt),
        "w_gates": dense_init(ks[5], (D, 2 * H), jnp.float32),  # i, f pre-activations
        # explicit f32: default-dtype linspace turns f64 under JAX_ENABLE_X64
        # and would poison the chunk_step scan carry
        "b_gates": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                    jnp.linspace(3.0, 6.0, H, dtype=jnp.float32)]),
        "onorm": jnp.ones((D,), jnp.float32),                   # post-memory groupnorm scale
        "w_down": dense_init(ks[6], (D, M), dt),
    }


def _mlstm_qkv_gates(p, x, cfg):
    """x: (B, S, M) -> q,k,v (B,S,H,dh), gates li/lf (B,S,H), z (B,S,D)."""
    H = cfg.n_heads
    D = m_inner(cfg)
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)                      # (B,S,D)
    c = jax.nn.silu(causal_conv1d(xm, p["conv_w"], p["conv_b"]))
    q = (c @ p["wq"]).reshape(*c.shape[:-1], H, D // H)
    k = (c @ p["wk"]).reshape(*c.shape[:-1], H, D // H) * (D // H) ** -0.5
    v = (xm @ p["wv"]).reshape(*xm.shape[:-1], H, D // H)
    gates = c.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    li, lf_pre = jnp.split(gates, 2, axis=-1)              # (B,S,H)
    lf = jax.nn.log_sigmoid(lf_pre)
    return q, k, v, li, lf, z


def _mlstm_finish(p, h, z, x, cfg):
    B, S = x.shape[:2]
    h = h.reshape(B, S, -1)
    h = rmsnorm(h, p["onorm"], cfg.norm_eps)               # per the xLSTM block's GN
    return x + (h.astype(x.dtype) * jax.nn.silu(z)) @ p["w_down"]


def mlstm_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Chunk-parallel mLSTM forward. x: (B, S, M)."""
    B, S, M = x.shape
    H = cfg.n_heads
    dh = m_inner(cfg) // H
    chunk = min(cfg.xlstm.chunk, S)
    q, k, v, li, lf, z = _mlstm_qkv_gates(p, x, cfg)

    pad = (-S) % chunk
    def pad_s(a):
        widths = [(0, 0)] * a.ndim
        widths[1] = (0, pad)
        return jnp.pad(a, widths) if pad else a
    qp, kp, vp = map(pad_s, (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)))
    lip = pad_s(li)
    lfp = pad_s(lf)
    if pad:  # padded steps: i = -inf (no contribution), f = 0 (identity decay)
        mask = (jnp.arange(S + pad) < S)[None, :, None]
        lip = jnp.where(mask, lip, NEG)
        lfp = jnp.where(mask, lfp, 0.0)
    n_chunks = (S + pad) // chunk

    def rs(a):  # (B, S, H, ...) -> (n_chunks, B, chunk, H, ...)
        return a.reshape(B, n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(rs, (qp, kp, vp, lip, lfp))

    def chunk_step(carry, inputs):
        C0, n0, m0 = carry                                 # (B,H,dh,dh), (B,H,dh), (B,H)
        qk_, kk_, vk_, lik, lfk = inputs                   # (B,c,H,...)
        b = jnp.cumsum(lfk, axis=1)                        # (B,c,H)
        a = lik - b                                        # (B,c,H)
        g = jax.lax.cummax(a, axis=1)
        Mt = jnp.maximum(m0[:, None], g)                   # (B,c,H)
        m_t = b + Mt

        # intra-chunk gate matrix: D[t,s] = exp(a_s - M_t) for s<=t
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dmat = jnp.exp(jnp.where(tri[None, :, :, None], a[:, None] - Mt[:, :, None], NEG))
        # scores
        s = jnp.einsum("bthd,bshd->btsh", qk_, kk_)        # (B,c,c,H)
        w = s * Dmat
        num_intra = jnp.einsum("btsh,bshd->bthd", w, vk_)
        den_intra = jnp.sum(w, axis=2)                     # (B,c,H) -- Σ_s w[t,s]
        carry_w = jnp.exp(m0[:, None] - Mt)                # (B,c,H)
        qC = jnp.einsum("bthd,bhde->bthe", qk_, C0)
        qn = jnp.einsum("bthd,bhd->bth", qk_, n0)
        num = num_intra + carry_w[..., None] * qC
        den = den_intra + carry_w * qn
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # carry to next chunk (reference stabilizer m_new = m at chunk end)
        M_end = jnp.maximum(m0, g[:, -1])                  # (B,H)
        kv = jnp.einsum("bshd,bshe,bsh->bhde", kk_, vk_, jnp.exp(a - M_end[:, None]))
        ksum = jnp.einsum("bshd,bsh->bhd", kk_, jnp.exp(a - M_end[:, None]))
        decay0 = jnp.exp(m0 - M_end)                       # (B,H)
        C_new = decay0[..., None, None] * C0 + kv
        n_new = decay0[..., None] * n0 + ksum
        m_new = b[:, -1] + M_end
        return (C_new, n_new, m_new), h

    from repro.models.transformer import scan_or_loop

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), NEG, jnp.float32)
    _, hs = scan_or_loop(chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc), cfg)
    h = hs.swapaxes(0, 1).reshape(B, n_chunks * chunk, H, dh)[:, :S]
    return _mlstm_finish(p, h, z, x, cfg)


def mlstm_sequential(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Step-by-step oracle for the chunked form (tests)."""
    B, S, M = x.shape
    H = cfg.n_heads
    dh = m_inner(cfg) // H
    q, k, v, li, lf, z = _mlstm_qkv_gates(p, x, cfg)
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)

    def step(carry, inputs):
        C, n, m = carry
        qt, kt, vt, lit, lft = inputs                      # (B,H,dh), (B,H)
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)
        ip = jnp.exp(lit - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), NEG, jnp.float32)
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          li.swapaxes(0, 1), lf.swapaxes(0, 1))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1)                                  # (B,S,H,dh)
    return _mlstm_finish(p, h, z, x, cfg)


def init_mlstm_state(cfg, batch: int) -> dict:
    H, dh = cfg.n_heads, m_inner(cfg) // cfg.n_heads
    K = cfg.xlstm.d_conv
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), NEG, jnp.float32),
        "conv": jnp.zeros((batch, K - 1, m_inner(cfg)), pdtype(cfg)),
    }


def mlstm_decode(p: dict, x_t: jax.Array, state: dict, cfg) -> tuple[jax.Array, dict]:
    B, M = x_t.shape
    H = cfg.n_heads
    dh = m_inner(cfg) // H
    xn = rmsnorm(x_t, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    c, conv_state = conv1d_step(xm, state["conv"], p["conv_w"], p["conv_b"])
    c = jax.nn.silu(c)
    q = (c @ p["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = ((c @ p["wk"]).reshape(B, H, dh) * dh ** -0.5).astype(jnp.float32)
    v = (xm @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = c.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    li, lf_pre = jnp.split(gates, 2, axis=-1)
    lf = jax.nn.log_sigmoid(lf_pre)

    m_new = jnp.maximum(lf + state["m"], li)
    fp = jnp.exp(lf + state["m"] - m_new)
    ip = jnp.exp(li - m_new)
    C = fp[..., None, None] * state["C"] + ip[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fp[..., None] * state["n"] + ip[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = rmsnorm(h.reshape(B, -1), p["onorm"], cfg.norm_eps)
    out = x_t + (h.astype(x_t.dtype) * jax.nn.silu(z)) @ p["w_down"]
    return out, {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ===========================================================================
# sLSTM block
# ===========================================================================

def init_slstm(key, cfg) -> dict:
    dt = pdtype(cfg)
    M, H = cfg.d_model, cfg.n_heads
    dh = M // H
    F = s_ff(cfg)
    ks = jax.random.split(key, 5)
    return {
        "norm": jnp.ones((M,), jnp.float32),
        "slstm_w": dense_init(ks[0], (M, 4 * M), jnp.float32),
        "slstm_r": dense_init(ks[1], (H, 4, dh, dh), jnp.float32, in_axis=2) * 0.5,
        # explicit f32 (see b_gates): default dtypes flip to f64 under X64
        "slstm_b": jnp.concatenate(
            [jnp.zeros((2 * M,), jnp.float32),
             jnp.linspace(3.0, 6.0, M, dtype=jnp.float32),
             jnp.zeros((M,), jnp.float32)]
        ),
        "ffn_norm": jnp.ones((M,), jnp.float32),
        "w_up": dense_init(ks[2], (M, 2 * F), dt),
        "w_down": dense_init(ks[3], (F, M), dt),
    }


def slstm_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Sequential sLSTM + gated FFN. x: (B, S, M)."""
    B, S, M = x.shape
    H = cfg.n_heads
    dh = M // H
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    wx = xn.astype(jnp.float32) @ p["slstm_w"] + p["slstm_b"]  # (B,S,4M)

    def step(carry, wx_t):
        h, c, n, m = carry                                 # h: (B,H,dh)
        rec = jnp.einsum("bhd,hgde->bhge", h, p["slstm_r"])  # (B,H,4,dh)
        pre = wx_t.reshape(B, H, 4, dh) + rec
        zt = jnp.tanh(pre[:, :, 0])
        it = pre[:, :, 1]
        ft = pre[:, :, 2]
        ot = jax.nn.sigmoid(pre[:, :, 3])
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    zeros = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H, dh), NEG, jnp.float32)
    (_, _, _, _), hs = jax.lax.scan(step, (zeros, zeros, zeros, m0), wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, M).astype(x.dtype)
    x = x + h
    # gated FFN (post-up-projection, factor 4/3)
    xn2 = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
    gu = xn2 @ p["w_up"]
    g, u = jnp.split(gu, 2, axis=-1)
    return x + (jax.nn.gelu(g, approximate=True) * u) @ p["w_down"]


def init_slstm_state(cfg, batch: int) -> dict:
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    zeros = jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": zeros, "c": zeros, "n": zeros, "m": jnp.full((batch, H, dh), NEG, jnp.float32)}


def slstm_decode(p: dict, x_t: jax.Array, state: dict, cfg) -> tuple[jax.Array, dict]:
    B, M = x_t.shape
    H, dh = cfg.n_heads, M // cfg.n_heads
    xn = rmsnorm(x_t, p["norm"], cfg.norm_eps)
    wx_t = xn.astype(jnp.float32) @ p["slstm_w"] + p["slstm_b"]
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    rec = jnp.einsum("bhd,hgde->bhge", h, p["slstm_r"])
    pre = wx_t.reshape(B, H, 4, dh) + rec
    zt = jnp.tanh(pre[:, :, 0])
    it, ft = pre[:, :, 1], pre[:, :, 2]
    ot = jax.nn.sigmoid(pre[:, :, 3])
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    x = x_t + h_new.reshape(B, M).astype(x_t.dtype)
    xn2 = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
    gu = xn2 @ p["w_up"]
    g, u = jnp.split(gu, 2, axis=-1)
    out = x + (jax.nn.gelu(g, approximate=True) * u) @ p["w_down"]
    return out, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
