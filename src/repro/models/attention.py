"""GQA attention: train/prefill (flash) and decode (KV-cache) paths."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.models.layers import apply_rope, dense_init, pdtype, qk_norm, zeros_init
from repro.sharding import constrain


def init_attn(key, cfg, cross: bool = False) -> dict:
    """cross=True: k/v projections read the encoder stream."""
    dt = pdtype(cfg)
    M, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (M, Q), dt),
        "wk": dense_init(ks[1], (M, KV), dt),
        "wv": dense_init(ks[2], (M, KV), dt),
        "wo": dense_init(ks[3], (Q, M), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = zeros_init(None, (Q,), dt)
        p["bk"] = zeros_init(None, (KV,), dt)
        p["bv"] = zeros_init(None, (KV,), dt)
    return p


def _project_q(p, x, cfg):
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    return q.reshape(*x.shape[:-1], cfg.n_heads, cfg.d_head)


def _project_kv(p, x, cfg):
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    shape = (*x.shape[:-1], cfg.n_kv_heads, cfg.d_head)
    return k.reshape(shape), v.reshape(shape)


def attn_apply(
    p: dict,
    x: jax.Array,                  # (B, S, M)
    cfg,
    positions: jax.Array,          # (B, S) or (S,)
    *,
    causal: bool = True,
    kv_src: jax.Array | None = None,   # cross-attention source (B, Skv, M)
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, kv_src if kv_src is not None else x, cfg)
    if kv_src is None and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.family == "vlm":  # Chameleon QK-norm
        q, k = qk_norm(q), qk_norm(k)
    # GQA with TP > n_kv_heads: kv stays head-replicated (projections are
    # replicated too) — attention then needs no collective at all.
    kv_axis = None if cfg.n_kv_heads < cfg.n_heads else "act_kv_heads"
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_seq", kv_axis, None))
    v = constrain(v, ("act_batch", "act_seq", kv_axis, None))
    out = flash_attention(q, k, v, causal=causal, impl=cfg.attn_impl,
                          unroll=cfg.unroll_layers)
    out = out.reshape(B, S, cfg.q_dim)
    out = constrain(out, ("act_batch", "act_seq", "act_heads"))
    return out @ p["wo"]


def init_kv_cache(cfg, batch: int, max_len: int) -> dict:
    dt = pdtype(cfg)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def attn_decode(
    p: dict,
    x_t: jax.Array,                # (B, M) current-token activations
    cache: dict,                   # {"k","v"}: (B, Smax, Hkv, D)
    pos: jax.Array,                # (B,) int32 write positions (= lengths so far)
    cfg,
    *,
    cross_kv: dict | None = None,  # precomputed {"k","v","len"} for cross-attn
) -> tuple[jax.Array, dict]:
    B, _ = x_t.shape
    q = _project_q(p, x_t[:, None, :], cfg)[:, 0]          # (B, Hq, D)
    if cross_kv is not None:
        if cfg.family == "vlm":
            q = qk_norm(q)
        out = decode_attention(
            q, cross_kv["k"], cross_kv["v"], cross_kv["len"], impl=cfg.attn_impl
        )
        return out.reshape(B, cfg.q_dim) @ p["wo"], cache

    k_t, v_t = _project_kv(p, x_t[:, None, :], cfg)
    k_t, v_t = k_t[:, 0], v_t[:, 0]                        # (B, Hkv, D)
    if cfg.rope_theta > 0:
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k_t = apply_rope(k_t[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    if cfg.family == "vlm":
        q, k_t = qk_norm(q), qk_norm(k_t)

    b_idx = jnp.arange(B)
    new_cache = {
        "k": cache["k"].at[b_idx, pos].set(k_t.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, pos].set(v_t.astype(cache["v"].dtype)),
    }
    out = decode_attention(
        q, new_cache["k"], new_cache["v"], pos + 1, impl=cfg.attn_impl
    )
    out = constrain(out, ("act_batch", "act_heads", None))
    return out.reshape(B, cfg.q_dim) @ p["wo"], new_cache


def precompute_cross_kv(p: dict, enc_out: jax.Array, enc_lens: jax.Array, cfg) -> dict:
    """Encoder-side K/V for cross-attention, computed once per session."""
    k, v = _project_kv(p, enc_out, cfg)
    return {"k": k, "v": v, "len": enc_lens}
