"""Mixture-of-experts block: shared experts + routed top-k experts.

Dispatch is *sort-based* (argsort by expert id -> capacity-bounded slot
buffer -> batched expert einsum -> weighted combine), so dispatch costs
bytes (gather/scatter) rather than the O(T*E*C) FLOPs of dense one-hot
GShard dispatch.  Routed expert weights are expert-sharded ("ep" -> mesh
"model" axis); the combine induces an all-reduce over the model axis under
GSPMD (baseline).  `impl="ep"` (shard_map + all_to_all) is the hillclimbed
variant — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, pdtype
from repro.models.mlp import init_swiglu, swiglu_apply
from repro.sharding import constrain


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    dt = pdtype(cfg)
    M, F, E = cfg.d_model, m.d_expert, m.n_routed
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (M, E), jnp.float32),
        "experts_wg": dense_init(ks[1], (E, M, F), dt, in_axis=1),
        "experts_wu": dense_init(ks[2], (E, M, F), dt, in_axis=1),
        "experts_wd": dense_init(ks[3], (E, F, M), dt, in_axis=1),
    }
    if m.n_shared > 0:
        p["shared"] = init_swiglu(ks[4], cfg, d_ff=m.n_shared * F)
    return p


def router_topk(logits: jax.Array, k: int):
    """Top-k routing with normalized combine weights. logits (T, E) fp32."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                 # (T, K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return probs, weights, ids


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e mean_assign_e * mean_prob_e."""
    T, K = ids.shape
    assign = jax.nn.one_hot(ids, n_experts, dtype=jnp.float32).sum(1)  # (T, E)
    f = assign.mean(0) / K
    p = probs.mean(0)
    return n_experts * jnp.sum(f * p)


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """x: (B, S, M) -> (out, aux) where aux has router losses + drop stats."""
    m = cfg.moe
    B, S, M = x.shape
    T = B * S
    E, K = m.n_routed, m.top_k
    xf = x.reshape(T, M)

    logits = xf.astype(jnp.float32) @ p["router"]          # (T, E)
    probs, weights, ids = router_topk(logits, K)
    aux = {
        "moe_aux": load_balance_loss(probs, ids, E) * m.aux_coef,
        "moe_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef,
    }

    # ---- sort-based dispatch ------------------------------------------------
    cap = int(math.ceil(T * K / E * m.capacity_factor))
    cap = min(cap, T)  # never more slots than tokens
    flat_ids = ids.reshape(-1)                             # (T*K,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_ids].astype(jnp.int32)
    keep = pos_in_e < cap
    tok = (order // K).astype(jnp.int32)                   # token of each sorted slot

    dst_c = jnp.where(keep, pos_in_e, cap)                 # cap = OOB -> dropped
    buf = jnp.zeros((E, cap, M), x.dtype)
    buf = buf.at[sorted_ids, dst_c].set(xf[tok], mode="drop")
    # EP when the expert count divides the model axis; TP-of-experts otherwise
    ep = E % 16 == 0
    buf = constrain(buf, ("act_expert", None, None) if ep else (None, None, None))

    # ---- expert FFN (batched over experts; weights EP-sharded) --------------
    g = jax.nn.silu(jnp.einsum("ecm,emf->ecf", buf, p["experts_wg"]))
    u = jnp.einsum("ecm,emf->ecf", buf, p["experts_wu"])
    h = constrain(g * u, ("act_expert", None, None) if ep else (None, None, "act_mlp"))
    out_slots = jnp.einsum("ecf,efm->ecm", h, p["experts_wd"])

    # ---- weighted combine ----------------------------------------------------
    w_sorted = weights.reshape(-1)[order].astype(out_slots.dtype)  # (T*K,)
    vals = out_slots[sorted_ids, jnp.minimum(dst_c, cap - 1)]
    vals = vals * (w_sorted * keep.astype(out_slots.dtype))[:, None]
    y = jnp.zeros((T, M), out_slots.dtype).at[tok].add(vals)

    aux["moe_drop_frac"] = 1.0 - keep.astype(jnp.float32).mean()
    out = y.reshape(B, S, M)
    if "shared" in p:
        out = out + swiglu_apply(p["shared"], x)
    return out, aux


def moe_decode(p: dict, x_t: jax.Array, cfg) -> jax.Array:
    """Decode path: tiny token count -> dense-gather per-token experts.

    x_t: (B, M). For B tokens it is cheaper to gather the K expert weight
    slices per token than to build the capacity buffer.
    """
    m = cfg.moe
    B, M = x_t.shape
    logits = x_t.astype(jnp.float32) @ p["router"]
    _, weights, ids = router_topk(logits, m.top_k)         # (B, K)

    wg = p["experts_wg"][ids]                              # (B, K, M, F)
    wu = p["experts_wu"][ids]
    wd = p["experts_wd"][ids]                              # (B, K, F, M)
    g = jax.nn.silu(jnp.einsum("bm,bkmf->bkf", x_t, wg))
    u = jnp.einsum("bm,bkmf->bkf", x_t, wu)
    y = jnp.einsum("bkf,bkfm->bkm", g * u, wd)
    out = jnp.einsum("bkm,bk->bm", y, weights.astype(y.dtype))
    if "shared" in p:
        out = out + swiglu_apply(p["shared"], x_t[:, None, :])[:, 0]
    return out
