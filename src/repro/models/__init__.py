# Re-exports live in repro.models.model; import submodules directly to avoid
# heavy transitive imports in tools that only need one block type.
