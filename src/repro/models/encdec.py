"""Encoder-decoder backbone (seamless-m4t): stub frontend provides precomputed
frame embeddings; encoder is bidirectional, decoder has self + cross attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attn_apply,
    attn_decode,
    init_attn,
    init_kv_cache,
    precompute_cross_kv,
)
from repro.models.layers import ones_init, rmsnorm
from repro.models.mlp import gelu_mlp_apply, init_gelu_mlp
from repro.models.transformer import ZERO_AUX, scan_or_loop
from repro.sharding import constrain


def init_enc_layer(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": ones_init(None, (cfg.d_model,), jnp.float32),
        "attn": init_attn(k1, cfg),
        "ln2": ones_init(None, (cfg.d_model,), jnp.float32),
        "mlp": init_gelu_mlp(k2, cfg),
    }


def enc_layer_apply(p, x, cfg, positions):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_apply(p["attn"], h, cfg, positions, causal=False)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + gelu_mlp_apply(p["mlp"], h)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def init_dec_layer(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": ones_init(None, (cfg.d_model,), jnp.float32),
        "attn": init_attn(k1, cfg),
        "ln_x": ones_init(None, (cfg.d_model,), jnp.float32),
        "xattn": init_attn(k2, cfg, cross=True),
        "ln2": ones_init(None, (cfg.d_model,), jnp.float32),
        "mlp": init_gelu_mlp(k3, cfg),
    }


def dec_layer_apply(p, x, enc_out, cfg, positions):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_apply(p["attn"], h, cfg, positions, causal=True)
    h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
    x = x + attn_apply(p["xattn"], h, cfg, positions, causal=False, kv_src=enc_out)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + gelu_mlp_apply(p["mlp"], h)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def init_encdec_stacks(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
    }


def encoder_apply(stacked, frames, cfg, positions):
    def body(x, layer_p):
        return enc_layer_apply(layer_p, x, cfg, positions), None

    fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = scan_or_loop(fn, frames, stacked, cfg)
    return x


def decoder_apply(stacked, x, enc_out, cfg, positions):
    def body(x, layer_p):
        return dec_layer_apply(layer_p, x, enc_out, cfg, positions), None

    fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = scan_or_loop(fn, x, stacked, cfg)
    return x, dict(ZERO_AUX)


def init_encdec_cache(params, cfg, batch: int, max_len: int, enc_out=None, enc_lens=None) -> dict:
    """Self-attn KV cache + cross-attn KV (precomputed from encoder output)."""
    self_one = init_kv_cache(cfg, batch, max_len)
    self_cache = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), self_one)
    if enc_out is None:  # abstract/zeros path (dry-run spec building)
        enc_out = jnp.zeros((batch, cfg.enc_len, cfg.d_model), self_one["k"].dtype)
        enc_lens = jnp.full((batch,), cfg.enc_len, jnp.int32)
    cross = jax.vmap(
        lambda lp: precompute_cross_kv(lp["xattn"], enc_out, enc_lens, cfg)
    )(params["dec_layers"])
    return {"self": self_cache, "cross": cross}


def decoder_decode(stacked, x_t, cache, pos, cfg):
    def body(x_t, inputs):
        layer_p, self_cache, cross_kv = inputs
        h = rmsnorm(x_t, layer_p["ln1"], cfg.norm_eps)
        a, new_self = attn_decode(layer_p["attn"], h, self_cache, pos, cfg)
        x_t = x_t + a
        h = rmsnorm(x_t, layer_p["ln_x"], cfg.norm_eps)
        a, _ = attn_decode(layer_p["xattn"], h, self_cache, pos, cfg, cross_kv=cross_kv)
        x_t = x_t + a
        h = rmsnorm(x_t, layer_p["ln2"], cfg.norm_eps)
        x_t = x_t + gelu_mlp_apply(layer_p["mlp"], h[:, None, :])[:, 0]
        return x_t, new_self

    x_t, new_self = scan_or_loop(body, x_t, (stacked, cache["self"], cache["cross"]), cfg)
    return x_t, {"self": new_self, "cross": cache["cross"]}
