"""Decoder-only stacks: dense / MoE / hybrid (Jamba) / xLSTM assemblies.

All stacks scan over *homogeneous* layer groups (params stacked via vmap'd
init) so the HLO is O(1) in depth — critical for 512-virtual-device dry-run
compile times — with optional per-block remat (`cfg.remat == "block"`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_decode, init_attn, init_kv_cache
from repro.models.layers import ones_init, rmsnorm
from repro.models.mamba import init_mamba, init_mamba_state, mamba_apply, mamba_decode
from repro.models.mlp import init_swiglu, swiglu_apply
from repro.models.moe import init_moe, moe_apply, moe_decode
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mlstm_apply,
    mlstm_decode,
    slstm_apply,
    slstm_decode,
)
from repro.sharding import constrain

ZERO_AUX = {"moe_aux": jnp.float32(0), "moe_z": jnp.float32(0), "moe_drop_frac": jnp.float32(0)}


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn


def scan_or_loop(body, carry, xs, cfg):
    """lax.scan, or a static python loop when cfg.unroll_layers (dry-run cost
    extraction: scan bodies are counted once by XLA cost analysis)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, out = body(carry, x_i)
        outs.append(out)
    if outs and outs[0] is not None:
        stacked = jax.tree.map(lambda *o: jnp.stack(o), *outs)
    else:
        stacked = None
    return carry, stacked


def _add_aux(a: dict, b: dict) -> dict:
    return {k: a[k] + b[k] for k in a}


# ===========================================================================
# Dense / MoE decoder layers (homogeneous scan)
# ===========================================================================

def init_decoder_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": ones_init(None, (cfg.d_model,), jnp.float32),
        "attn": init_attn(ks[0], cfg),
        "ln2": ones_init(None, (cfg.d_model,), jnp.float32),
    }
    if cfg.moe is not None and cfg.moe.every == 1:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_swiglu(ks[2], cfg)
    return p


def decoder_layer_apply(p, x, cfg, positions):
    aux = dict(ZERO_AUX)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_apply(p["attn"], h, cfg, positions)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_apply(p["moe"], h, cfg)
    else:
        y = swiglu_apply(p["mlp"], h)
    x = x + y
    return constrain(x, ("act_batch", "act_seq", "act_embed")), aux


def decoder_layer_decode(p, x_t, cache, pos, cfg):
    h = rmsnorm(x_t, p["ln1"], cfg.norm_eps)
    a, cache = attn_decode(p["attn"], h, cache, pos, cfg)
    x_t = x_t + a
    h = rmsnorm(x_t, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y = moe_decode(p["moe"], h, cfg)
    else:
        y = swiglu_apply(p["mlp"], h[:, None, :])[:, 0]
    return x_t + y, cache


def init_dense_stack(key, cfg) -> dict:
    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(lambda k: init_decoder_layer(k, cfg))(keys)


def dense_stack_apply(stacked, x, cfg, positions):
    def body(carry, layer_p):
        x, aux = carry
        x, a = decoder_layer_apply(layer_p, x, cfg, positions)
        return (x, _add_aux(aux, a)), None

    (x, aux), _ = scan_or_loop(_maybe_remat(body, cfg), (x, dict(ZERO_AUX)), stacked, cfg)
    return x, aux


def dense_stack_decode(stacked, x_t, cache, pos, cfg):
    def body(x_t, inputs):
        layer_p, layer_cache = inputs
        x_t, new_cache = decoder_layer_decode(layer_p, x_t, layer_cache, pos, cfg)
        return x_t, new_cache

    x_t, new_cache = scan_or_loop(body, x_t, (stacked, cache), cfg)
    return x_t, new_cache


def init_dense_cache(cfg, batch: int, max_len: int) -> dict:
    one = init_kv_cache(cfg, batch, max_len)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)


# ===========================================================================
# Jamba hybrid super-blocks (attn_every layers per block, 1 attention inside)
# ===========================================================================

def _jamba_layout(cfg):
    per = cfg.attn_every                      # sub-layers per super-block
    attn_pos = per // 2                       # attention at the middle slot
    n_blocks = cfg.n_layers // per
    moe_every = cfg.moe.every if cfg.moe else 0
    return per, attn_pos, n_blocks, moe_every


def init_jamba_block(key, cfg) -> dict:
    per, attn_pos, _, moe_every = _jamba_layout(cfg)
    ks = jax.random.split(key, 2 * per)
    sub = []
    for i in range(per):
        kp = ks[2 * i], ks[2 * i + 1]
        lp = {"ln1": ones_init(None, (cfg.d_model,), jnp.float32),
              "ln2": ones_init(None, (cfg.d_model,), jnp.float32)}
        if i == attn_pos:
            lp["attn"] = init_attn(kp[0], cfg)
        else:
            lp["mamba"] = init_mamba(kp[0], cfg)
        if moe_every and i % moe_every == 1:
            lp["moe"] = init_moe(kp[1], cfg)
        else:
            lp["mlp"] = init_swiglu(kp[1], cfg)
        sub.append(lp)
    return {f"sub{i}": sp for i, sp in enumerate(sub)}


def jamba_block_apply(p, x, cfg, positions):
    per, attn_pos, _, _ = _jamba_layout(cfg)
    aux = dict(ZERO_AUX)
    for i in range(per):
        lp = p[f"sub{i}"]
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if "attn" in lp:
            x = x + attn_apply(lp["attn"], h, cfg, positions)
        else:
            x = x + mamba_apply(lp["mamba"], h, cfg)
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, a = moe_apply(lp["moe"], h, cfg)
            aux = _add_aux(aux, a)
        else:
            y = swiglu_apply(lp["mlp"], h)
        x = x + y
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, aux


def init_jamba_stack(key, cfg) -> dict:
    _, _, n_blocks, _ = _jamba_layout(cfg)
    keys = jax.random.split(key, n_blocks)
    return jax.vmap(lambda k: init_jamba_block(k, cfg))(keys)


def jamba_stack_apply(stacked, x, cfg, positions):
    def body(carry, block_p):
        x, aux = carry
        x, a = jamba_block_apply(block_p, x, cfg, positions)
        return (x, _add_aux(aux, a)), None

    (x, aux), _ = scan_or_loop(_maybe_remat(body, cfg), (x, dict(ZERO_AUX)), stacked, cfg)
    return x, aux


def init_jamba_cache(cfg, batch: int, max_len: int) -> dict:
    per, attn_pos, n_blocks, _ = _jamba_layout(cfg)
    attn = init_kv_cache(cfg, batch, max_len)
    mamba_states = init_mamba_state(cfg, batch)
    return {
        "attn": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_blocks, *a.shape)), attn),
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_blocks, per - 1, *a.shape)), mamba_states
        ),
    }


def jamba_block_decode(p, x_t, block_cache, pos, cfg):
    per, attn_pos, _, _ = _jamba_layout(cfg)
    new_attn = block_cache["attn"]
    new_mamba = []
    mi = 0
    for i in range(per):
        lp = p[f"sub{i}"]
        h = rmsnorm(x_t, lp["ln1"], cfg.norm_eps)
        if "attn" in lp:
            a, new_attn = attn_decode(lp["attn"], h, block_cache["attn"], pos, cfg)
            x_t = x_t + a
        else:
            st = jax.tree.map(lambda s: s[mi], block_cache["mamba"])
            a, st = mamba_decode(lp["mamba"], h, st, cfg)
            new_mamba.append(st)
            x_t = x_t + a
            mi += 1
        h = rmsnorm(x_t, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y = moe_decode(lp["moe"], h, cfg)
        else:
            y = swiglu_apply(lp["mlp"], h[:, None, :])[:, 0]
        x_t = x_t + y
    stacked_mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba)
    return x_t, {"attn": new_attn, "mamba": stacked_mamba}


def jamba_stack_decode(stacked, x_t, cache, pos, cfg):
    def body(x_t, inputs):
        block_p, block_cache = inputs
        return jamba_block_decode(block_p, x_t, block_cache, pos, cfg)

    return scan_or_loop(body, x_t, (stacked, cache), cfg)


# ===========================================================================
# xLSTM pair stack (pattern "ms": one mLSTM + one sLSTM per scanned pair)
# ===========================================================================

def _xlstm_pairs(cfg) -> int:
    assert cfg.xlstm.pattern == "ms"
    return cfg.n_layers // 2


def init_xlstm_pair(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {"mlstm": init_mlstm(k1, cfg), "slstm": init_slstm(k2, cfg)}


def init_xlstm_stack(key, cfg) -> dict:
    keys = jax.random.split(key, _xlstm_pairs(cfg))
    return jax.vmap(lambda k: init_xlstm_pair(k, cfg))(keys)


def xlstm_stack_apply(stacked, x, cfg, positions=None):
    def body(carry, pair_p):
        x, aux = carry
        x = mlstm_apply(pair_p["mlstm"], x, cfg)
        x = slstm_apply(pair_p["slstm"], x, cfg)
        return (x, aux), None

    (x, aux), _ = scan_or_loop(_maybe_remat(body, cfg), (x, dict(ZERO_AUX)), stacked, cfg)
    return x, aux


def init_xlstm_cache(cfg, batch: int, max_len: int = 0) -> dict:
    n = _xlstm_pairs(cfg)
    m = init_mlstm_state(cfg, batch)
    s = init_slstm_state(cfg, batch)
    return {
        "mlstm": jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), m),
        "slstm": jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), s),
    }


def xlstm_stack_decode(stacked, x_t, cache, pos, cfg):
    def body(x_t, inputs):
        pair_p, pair_cache = inputs
        x_t, m_st = mlstm_decode(pair_p["mlstm"], x_t, pair_cache["mlstm"], cfg)
        x_t, s_st = slstm_decode(pair_p["slstm"], x_t, pair_cache["slstm"], cfg)
        return x_t, {"mlstm": m_st, "slstm": s_st}

    return scan_or_loop(body, x_t, (stacked, cache), cfg)
