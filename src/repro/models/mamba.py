"""Mamba-1 selective SSM block (Jamba's sequence mixer).

TPU adaptation note (DESIGN.md §2): the CUDA selective-scan kernel fuses the
recurrence in SRAM; the TPU-native equivalent is a *chunked* scan — a
`lax.scan` over sequence chunks (carry = (B, d_inner, N) state) with a
parallel `associative_scan` inside each chunk, so the (B, chunk, d_inner, N)
intermediate is bounded by the chunk length instead of the full sequence.
Decode is the O(1)/token recurrent step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, conv1d_step, dense_init, pdtype
from repro.sharding import constrain


def _dt_rank(cfg) -> int:
    return cfg.mamba.dt_rank or math.ceil(cfg.d_model / 16)


def d_inner(cfg) -> int:
    return cfg.mamba.expand * cfg.d_model


def init_mamba(key, cfg) -> dict:
    mc = cfg.mamba
    dt = pdtype(cfg)
    M, D, N, R = cfg.d_model, d_inner(cfg), mc.d_state, _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # dt bias: softplus(b_dt) ~ Uniform[1e-3, 0.1]  (mamba init)
    u = jax.random.uniform(ks[4], (D,), jnp.float32, 1e-3, 0.1)
    b_dt = u + jnp.log(-jnp.expm1(-u))  # inverse softplus
    return {
        "w_in": dense_init(ks[0], (M, 2 * D), dt),
        "conv_w": dense_init(ks[1], (mc.d_conv, D), dt),
        "conv_b": jnp.zeros((D,), dt),
        "w_x": dense_init(ks[2], (D, R + 2 * N), dt),
        "w_dt": dense_init(ks[3], (R, D), jnp.float32) * (R ** -0.5),
        "b_dt": b_dt,
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (D, N))),
        "D": jnp.ones((D,), jnp.float32),
        "w_out": dense_init(ks[5], (D, M), dt),
    }


def _ssm_inputs(p: dict, x1: jax.Array, cfg):
    """x1: (B, S, D) post-conv activations -> (dt, Bs, Cs)."""
    N, R = cfg.mamba.d_state, _dt_rank(cfg)
    xdb = x1 @ p["w_x"]                                    # (B, S, R+2N)
    dt_r, Bs, Cs = jnp.split(xdb.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["w_dt"] + p["b_dt"])     # (B, S, D)
    return dt, Bs, Cs


def mamba_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Training/prefill forward. x: (B, S, M) -> (B, S, M)."""
    mc = cfg.mamba
    B, S, M = x.shape
    N = mc.d_state
    chunk = min(mc.chunk, S)

    xz = x @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)                      # (B, S, D)
    x1 = constrain(x1, ("act_batch", "act_seq", "act_mlp"))
    x1 = jax.nn.silu(causal_conv1d(x1, p["conv_w"], p["conv_b"]))

    dt, Bs, Cs = _ssm_inputs(p, x1, cfg)
    A = -jnp.exp(p["A_log"])                               # (D, N)

    pad = (-S) % chunk
    def pad_s(a):
        return jnp.pad(a, ((0, 0), (0, pad), (0, 0))) if pad else a
    dt_p, Bs_p, Cs_p, x1_p = pad_s(dt), pad_s(Bs), pad_s(Cs), pad_s(x1.astype(jnp.float32))
    n_chunks = (S + pad) // chunk

    def reshape_c(a):
        return a.reshape(B, n_chunks, chunk, a.shape[-1]).swapaxes(0, 1)

    dt_c, Bs_c, Cs_c, x1_c = map(reshape_c, (dt_p, Bs_p, Cs_p, x1_p))

    def chunk_step(h, inputs):
        dtk, Bk, Ck, xk = inputs                           # (B, chunk, ...)
        da = jnp.exp(dtk[..., None] * A)                   # (B, c, D, N)
        inp = (dtk * xk)[..., None] * Bk[:, :, None, :]    # (B, c, D, N)

        def combine(a, b):
            a_d, a_i = a
            b_d, b_i = b
            return a_d * b_d, b_d * a_i + b_i

        decay_cum, h_intra = jax.lax.associative_scan(combine, (da, inp), axis=1)
        h_all = h_intra + decay_cum * h[:, None]           # (B, c, D, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Ck)
        return h_all[:, -1], y

    from repro.models.transformer import scan_or_loop

    h0 = jnp.zeros((B, d_inner(cfg), N), jnp.float32)
    _, ys = scan_or_loop(chunk_step, h0, (dt_c, Bs_c, Cs_c, x1_c), cfg)
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * chunk, -1)[:, :S]
    y = y + p["D"] * x1.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out


def init_mamba_state(cfg, batch: int) -> dict:
    D, N, K = d_inner(cfg), cfg.mamba.d_state, cfg.mamba.d_conv
    return {
        "h": jnp.zeros((batch, D, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, D), pdtype(cfg)),
    }


def mamba_decode(p: dict, x_t: jax.Array, state: dict, cfg) -> tuple[jax.Array, dict]:
    """One-token recurrent step. x_t: (B, M)."""
    xz = x_t @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)                      # (B, D)
    x1, conv_state = conv1d_step(x1, state["conv"], p["conv_w"], p["conv_b"])
    x1 = jax.nn.silu(x1)

    dt, Bs, Cs = _ssm_inputs(p, x1[:, None, :], cfg)
    dt, Bs, Cs = dt[:, 0], Bs[:, 0], Cs[:, 0]              # (B, D), (B, N), (B, N)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * A)                        # (B, D, N)
    h = da * state["h"] + (dt * x1.astype(jnp.float32))[..., None] * Bs[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cs) + p["D"] * x1.astype(jnp.float32)
    out = (y.astype(x_t.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}
