"""Dense MLP blocks: SwiGLU (LM family) and GELU (enc-dec)."""
from __future__ import annotations

import jax

from repro.models.layers import dense_init, pdtype
from repro.sharding import constrain


def init_swiglu(key, cfg, d_ff: int | None = None) -> dict:
    dt = pdtype(cfg)
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (cfg.d_model, F), dt),
        "wu": dense_init(ks[1], (cfg.d_model, F), dt),
        "wd": dense_init(ks[2], (F, cfg.d_model), dt),
    }


def swiglu_apply(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["wg"])
    u = x @ p["wu"]
    h = constrain(g * u, ("act_batch", "act_seq", "act_mlp"))
    return h @ p["wd"]


def init_gelu_mlp(key, cfg, d_ff: int | None = None) -> dict:
    dt = pdtype(cfg)
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "wu": dense_init(ks[0], (cfg.d_model, F), dt),
        "wd": dense_init(ks[1], (F, cfg.d_model), dt),
    }


def gelu_mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["wu"], approximate=True)
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return h @ p["wd"]
