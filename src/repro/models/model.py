"""Unified model API over all assigned architectures.

    init_params(cfg, key)                 -> params pytree
    loss_fn(params, batch, cfg)           -> (loss, metrics)      [train]
    prefill(params, tokens, cfg, max_len) -> (logits_last, cache) [inference]
    init_cache(params, cfg, batch, max_len) -> cache pytree
    decode_step(params, cache, token, pos, cfg) -> (logits, cache)

Families dispatch on cfg: dense/vlm -> dense stack; moe -> dense stack with
MoE MLPs; hybrid -> jamba super-blocks; ssm -> xLSTM pairs; audio -> enc-dec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer as tfm
from repro.models.layers import embed_init, ones_init, pdtype, rmsnorm
from repro.sharding import constrain


# ===========================================================================
# Init
# ===========================================================================

def init_params(cfg, key) -> dict:
    dt = pdtype(cfg)
    k_emb, k_stack, k_head = jax.random.split(key, 3)
    p: dict = {"emb": embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt)}

    if cfg.enc_dec:
        p.update(encdec.init_encdec_stacks(k_stack, cfg))
        p["enc_norm"] = ones_init(None, (cfg.d_model,), jnp.float32)
    elif cfg.family == "hybrid":
        p["blocks"] = tfm.init_jamba_stack(k_stack, cfg)
    elif cfg.family == "ssm":
        p["pairs"] = tfm.init_xlstm_stack(k_stack, cfg)
    else:  # dense / moe / vlm
        p["layers"] = tfm.init_dense_stack(k_stack, cfg)

    p["final_norm"] = ones_init(None, (cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size), dt)
    return p


# ===========================================================================
# Shared pieces
# ===========================================================================

def _embed(p, tokens, cfg):
    x = p["emb"][tokens]  # gather; emb sharded (vocab_tp, fsdp) under GSPMD
    return constrain(x.astype(pdtype(cfg)), ("act_batch", "act_seq", "act_embed"))


def _logits(p, x, cfg):
    h = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["emb"].T if cfg.tie_embeddings else p["lm_head"]
    # bf16 matmul, f32 cast *after*: with preferred_element_type=f32 the
    # backward pass propagates f32 cotangents through the whole residual
    # stack (measured: 130 x 1.07GB/chip f32 buffers on the pod dry-run).
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
    return constrain(logits, ("act_batch", "act_seq", "act_vocab") if logits.ndim == 3
                     else ("act_batch", "act_vocab"))


def _stack_apply(p, x, cfg, positions):
    if cfg.enc_dec:
        raise AssertionError("use _encdec_forward")
    if cfg.family == "hybrid":
        return tfm.jamba_stack_apply(p["blocks"], x, cfg, positions)
    if cfg.family == "ssm":
        return tfm.xlstm_stack_apply(p["pairs"], x, cfg, positions)
    return tfm.dense_stack_apply(p["layers"], x, cfg, positions)


# ===========================================================================
# Training
# ===========================================================================

def forward_train(params, batch, cfg):
    """batch: {"tokens": (B,S) int32, ...enc-dec adds "frames": (B,Se,M)}."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = _embed(params, tokens, cfg)
    if cfg.enc_dec:
        frames = batch["frames"].astype(pdtype(cfg))
        enc_pos = jnp.arange(frames.shape[1])[None, :]
        enc_out = encdec.encoder_apply(params["enc_layers"], frames, cfg, enc_pos)
        enc_out = rmsnorm(enc_out, params["enc_norm"], cfg.norm_eps)
        x, aux = encdec.decoder_apply(params["dec_layers"], x, enc_out, cfg, positions)
    else:
        x, aux = _stack_apply(params, x, cfg, positions)
    return _logits(params, x, cfg), aux


LOSS_CHUNK = 512  # sequence-chunked CE: per-chunk logits only (memory cap)


def _chunk_ce(params, x_c, labels_c, cfg):
    """CE sums for one token chunk; rematerialized so logits are transient."""
    logits = _logits(params, x_c, cfg)                     # (B, sc, V) fp32
    labels_safe = jnp.maximum(labels_c, 0)
    mask = (labels_c >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # shardable under GSPMD (a sharded-vocab gather forces an all-gather of
    # the logits — measured 33 GB/chip on the pod dry-run).
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    onehot = (vocab_iota == labels_safe[..., None]).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce_sum = jnp.sum((lse - gold) * mask)
    z_sum = jnp.sum((lse * mask) ** 2)
    return ce_sum, z_sum, jnp.sum(mask)


def loss_fn(params, batch, cfg):
    """Next-token CE (+ MoE aux losses). labels: (B,S) int32, -1 = masked.

    The unembedding + CE is *sequence-chunked* (static loop, each chunk
    rematerialized): full (B,S,V) logits are never alive, bounding the loss
    working set to (B, LOSS_CHUNK, V)/chips at the cost of one extra logits
    matmul in the backward pass.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = _embed(params, tokens, cfg)
    if cfg.enc_dec:
        frames = batch["frames"].astype(pdtype(cfg))
        enc_pos = jnp.arange(frames.shape[1])[None, :]
        enc_out = encdec.encoder_apply(params["enc_layers"], frames, cfg, enc_pos)
        enc_out = rmsnorm(enc_out, params["enc_norm"], cfg.norm_eps)
        x, aux = encdec.decoder_apply(params["dec_layers"], x, enc_out, cfg, positions)
    else:
        x, aux = _stack_apply(params, x, cfg, positions)

    labels = batch["labels"]
    sc = min(LOSS_CHUNK, S)
    ce_sum = jnp.float32(0)
    z_sum = jnp.float32(0)
    n_tok = jnp.float32(0)
    chunk_fn = jax.checkpoint(_chunk_ce, static_argnums=(3,))
    for lo in range(0, S, sc):
        c, z, n = chunk_fn(params, x[:, lo:lo + sc], labels[:, lo:lo + sc], cfg)
        ce_sum, z_sum, n_tok = ce_sum + c, z_sum + z, n_tok + n

    n_tok = jnp.maximum(n_tok, 1.0)
    loss = ce_sum / n_tok
    z_loss = 1e-4 * z_sum / n_tok
    total = loss + z_loss + aux["moe_aux"] + aux["moe_z"]
    metrics = {
        "loss": loss,
        "z_loss": z_loss,
        "moe_aux": aux["moe_aux"],
        "moe_drop_frac": aux["moe_drop_frac"],
        "tokens": n_tok,
    }
    return total, metrics


# ===========================================================================
# Inference
# ===========================================================================

def init_cache(params, cfg, batch: int, max_len: int) -> dict:
    if cfg.enc_dec:
        return encdec.init_encdec_cache(params, cfg, batch, max_len)
    if cfg.family == "hybrid":
        return tfm.init_jamba_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return tfm.init_xlstm_cache(cfg, batch, max_len)
    return tfm.init_dense_cache(cfg, batch, max_len)


def decode_step(params, cache, token, pos, cfg):
    """token: (B,) int32; pos: (B,) int32 -> (logits (B,V), new cache)."""
    x_t = _embed(params, token[:, None], cfg)[:, 0]        # (B, M)
    if cfg.enc_dec:
        x_t, cache = encdec.decoder_decode(params["dec_layers"], x_t, cache, pos, cfg)
    elif cfg.family == "hybrid":
        x_t, cache = tfm.jamba_stack_decode(params["blocks"], x_t, cache, pos, cfg)
    elif cfg.family == "ssm":
        x_t, cache = tfm.xlstm_stack_decode(params["pairs"], x_t, cache, pos, cfg)
    else:
        x_t, cache = tfm.dense_stack_decode(params["layers"], x_t, cache, pos, cfg)
    return _logits(params, x_t, cfg), cache


def prefill(params, tokens, cfg, max_len: int):
    """Full-sequence prefill: returns last-position logits + populated cache.

    For attention archs the per-layer K/V come out of the scan stacked in
    cache layout; SSM/hybrid archs roll their recurrent state forward by
    running the parallel form then one decode sweep is unnecessary — we
    recompute state via the chunked scans' final carries (cheap relative to
    the forward).  Implementation: run forward_train-like pass but also emit
    K/V (attention) / final states (ssm).  For simplicity and HLO size we
    reuse the training stacks and rebuild caches where needed.
    """
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = _embed(params, tokens, cfg)

    if cfg.enc_dec:
        raise NotImplementedError("enc-dec prefill is the encoder pass; see serve driver")

    if cfg.family in ("dense", "moe", "vlm"):
        kv_all = []

        def body(carry, layer_p):
            x, = carry
            h = rmsnorm(x, layer_p["ln1"], cfg.norm_eps)
            from repro.models.attention import _project_kv  # local to keep HLO lean

            k, v = _project_kv(layer_p["attn"], h, cfg)
            x, _ = tfm.decoder_layer_apply(layer_p, x, cfg, positions)
            return (x,), {"k": k, "v": v}

        fn = jax.checkpoint(body) if cfg.remat == "block" else body
        (x,), kv = tfm.scan_or_loop(fn, (x,), params["layers"], cfg)
        # note: k/v here are pre-rope; decode path applies rope at read time
        # against absolute positions, so we must store roped keys. Recompute:
        from repro.models.layers import apply_rope

        if cfg.rope_theta > 0:
            kv["k"] = apply_rope(kv["k"], positions[None], cfg.rope_theta)
        logits = _logits(params, x[:, -1, :], cfg)
        cache = {"k": kv["k"], "v": kv["v"]}
        return logits, cache

    # hybrid / ssm: parallel forward for logits; state caches built by the
    # serve driver via a short decode warm-up (documented limitation).
    xx, _ = _stack_apply(params, x, cfg, positions)
    logits = _logits(params, xx[:, -1, :], cfg)
    return logits, init_cache(params, cfg, B, S)


# ===========================================================================
# Analytics
# ===========================================================================

def count_params_analytic(cfg, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape; MoE active-only scales routed experts."""
    abstract = jax.eval_shape(lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0

    def visit(path, leaf):
        nonlocal total
        n = 1
        for s in leaf.shape:
            n *= s
        name = "/".join(str(getattr(q, "key", q)) for q in path)
        if active_only and "experts_" in name and cfg.moe is not None:
            n = int(n * cfg.moe.top_k / cfg.moe.n_routed)
        total += n

    jax.tree_util.tree_map_with_path(visit, abstract)
    return int(total)
