"""Shared layer primitives: init, norms, rotary embeddings, numerics policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers (explicit keys; params are plain dict pytrees)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-ish, stddev 1/sqrt(fan_in))."""
    fan_in = shape[in_axis] if shape else 1
    std = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm: f32 only inside the variance *reduction* (which fuses on TPU
    and matches the Pallas kernel); the normalizing multiply stays in the
    input dtype.  A full-tensor f32 upcast here poisons the whole residual
    stream to f32 under GSPMD (f32 activation all-reduces, 2x HBM)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def qk_norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS normalization of q/k (Chameleon-style stability)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over seq. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled taps (K is tiny, e.g. 4): avoids conv lowering differences
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    if b is not None:
        out = out + b
    return out


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array | None):
    """One decode step of causal depthwise conv.

    x_t: (B, C); conv_state: (B, K-1, C) past inputs. Returns (y_t, new_state).
    """
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:, :] if k > 1 else conv_state


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap
