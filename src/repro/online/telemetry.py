"""Fleet telemetry: lifecycle tracing, streaming metrics, drift signals.

The simulator, router, retrain loop, and training scan are decision
systems built on *measurement* (the paper's profiles; MISO's continuous
runtime monitoring) — this module gives the serving stack the same
treatment.  Three layers, all optional and zero-cost when absent:

Lifecycle tracing
-----------------
:class:`TraceRecorder` collects structured events — every job's span
chain ``arrive → (route) → queue → window → place/backfill/refit → run →
free`` with pod/slice/claim attribution — and exports them two ways:

* **JSONL** (:meth:`TraceRecorder.write_jsonl`): one event dict per
  line, the raw stream for ad-hoc analysis.
* **Chrome trace JSON** (:meth:`TraceRecorder.write_chrome_trace`):
  ``trace_event``-format ``ph="X"`` complete events, one track per
  pod×slice (``pid`` = pod, ``tid`` = slice unit), loadable in Perfetto
  (https://ui.perfetto.dev) or ``chrome://tracing``.  Arrivals, window
  formations, refits, and ticks land on a per-pod "events" track as
  instants.

The event schema is documented in ``docs/observability.md``; the
span-chain invariants (every arrival placed exactly once, every claim
freed, no overlapping spans per slice) are pinned by
``tests/test_telemetry.py``.

Streaming metrics
-----------------
:class:`MetricsRegistry` holds :class:`Counter` / :class:`Gauge` /
:class:`Histogram` instruments — pure Python for the heap engine (the
vectorized engine accumulates the same quantities as a pytree
``MetricsState`` inside its ``lax.while_loop``; see
:mod:`repro.online.vecsim`).  Histograms use fixed bucket edges so the
heap and vectorized engines aggregate identically; ``WAIT_BUCKETS_S``
is the shared wait-time layout.  Registry aggregates match
``SimResult.summary()`` (counters exactly; float accumulations to
addition-order precision).

Drift signals
-------------
:class:`DriftMonitor` turns windowed observations (arrival class/width
mix entropy, live ``idle_slice_frac``) into a binary drift verdict
against EMA baselines — the ROADMAP's drift-triggered retraining signal,
consumed by ``OnlineRetrainer(trigger="drift")``.  Per-interval
time-series come from ``SimResult.timeseries()`` (post-hoc, no recorder
needed).

:class:`PhaseTimer` is the small wall-clock helper behind
``benchmarks/online_sim.py --profile``.
"""
from __future__ import annotations

import bisect
import json
import math
import time
from dataclasses import dataclass, field

# Shared fixed wait-histogram bucket upper edges (seconds).  The heap's
# Histogram and the vectorized engine's MetricsState use the same edges,
# so their counts are directly comparable (len(edges)+1 buckets; the
# last bucket is the +inf overflow).
WAIT_BUCKETS_S: tuple[float, ...] = (
    1.0, 10.0, 60.0, 300.0, 1800.0, 7200.0, 28800.0)


def entropy_bits(counts) -> float:
    """Shannon entropy (bits) of a count distribution (dict or iterable)."""
    vals = list(counts.values()) if isinstance(counts, dict) else list(counts)
    total = float(sum(vals))
    if total <= 0:
        return 0.0
    h = 0.0
    for v in vals:
        if v > 0:
            p = v / total
            h -= p * math.log2(p)
    return h


# ---------------------------------------------------------------------------
# Metrics registry (heap-engine side; pure Python, stdlib only)
# ---------------------------------------------------------------------------


@dataclass
class Counter:
    """Monotonic accumulator (int or float increments)."""

    name: str
    value: float = 0.0

    def inc(self, n: float = 1) -> None:
        self.value += n


@dataclass
class Gauge:
    """Last-value instrument."""

    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram: ``len(edges)+1`` counts (last = overflow).

    ``edges`` are upper bucket edges: observation ``x`` lands in the
    first bucket with ``x <= edges[i]`` (``bisect_left``), matching the
    vectorized engine's ``searchsorted(..., side="left")``.
    """

    def __init__(self, name: str, edges: tuple[float, ...] = WAIT_BUCKETS_S):
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        assert list(self.edges) == sorted(self.edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.sum += x
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Bucket-interpolated percentile (uniform within a bucket).

        An approximation by construction — exact percentiles need the
        raw samples (``SimResult`` keeps those); tests bound the error
        against the numpy reference by one bucket width."""
        if not self.count:
            return 0.0
        target = q / 100.0 * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if acc + c >= target and c > 0:
                lo = 0.0 if i == 0 else self.edges[i - 1]
                hi = self.edges[i] if i < len(self.edges) else lo * 2 or 1.0
                return lo + (hi - lo) * (target - acc) / c
            acc += c
        return self.edges[-1]

    def to_dict(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Named instrument store with one-line-per-metric JSONL export."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge(name))

    def histogram(self, name: str,
                  edges: tuple[float, ...] = WAIT_BUCKETS_S) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(name, edges)
        return self._histograms[name]

    def to_dicts(self) -> list[dict]:
        out = []
        for c in self._counters.values():
            out.append({"type": "counter", "name": c.name, "value": c.value})
        for g in self._gauges.values():
            out.append({"type": "gauge", "name": g.name, "value": g.value})
        for h in self._histograms.values():
            out.append({"type": "histogram", "name": h.name, **h.to_dict()})
        return sorted(out, key=lambda d: (d["type"], d["name"]))

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for d in self.to_dicts():
                f.write(json.dumps(d) + "\n")


# ---------------------------------------------------------------------------
# Lifecycle trace recorder
# ---------------------------------------------------------------------------


class TraceRecorder:
    """Append-only structured event stream with JSONL / Chrome export.

    Events read as plain dicts ``{"kind", "t_s", "pod", ...}`` via
    :attr:`events`; the simulator emits one at each lifecycle transition
    (see ``docs/observability.md`` for the per-kind payload schema).
    Internally the hot-path :meth:`emit` appends a compact
    ``(kind, t, pod, values)`` tuple and dict materialization is
    deferred until :attr:`events` is first read — recording must not tax
    the event loop (the ``telemetry_overhead`` gate).
    """

    #: positional payload schema for :meth:`emit`, per event kind.
    #: "place" is special-cased in :attr:`events` — its raw payload is
    #: ``(recs, slices, t1_s, claim, partition, backfilled)`` and the
    #: ``jobs``/``names`` columns come from the records at read time
    _FIELDS = {
        "arrive": ("job", "name", "job_class", "units"),
        "window": ("jobs", "pending_left"),
        "refit": ("partition", "n_jobs"),
        "free": ("claim",),
        "tick": (),
    }

    def __init__(self):
        self._raw: list[tuple] = []
        self._cache: list[dict] | None = None

    def emit(self, kind: str, t: float, pod: int, values: tuple = ()) -> None:
        """Hot-path append: ``values`` are positional per
        ``_FIELDS[kind]``; callers must pass payloads whose fields are
        immutable (or never mutated) since conversion happens at read
        time.  ``place`` payloads carry the group's ``JobRecord``\\ s —
        their ``idx``/``name``/``arrival`` are fixed at construction."""
        self._raw.append((kind, t, pod, values))

    def event(self, kind: str, t: float, pod: int = 0, **attrs) -> None:
        """Generic append for ad-hoc event kinds (builds the dict now)."""
        self._raw.append((kind, t, pod, attrs))

    @property
    def events(self) -> list[dict]:
        """The event stream as dicts (materialized lazily; the cache is
        rebuilt whenever the raw stream has grown)."""
        if self._cache is None or len(self._cache) != len(self._raw):
            fields = self._FIELDS
            ev = []
            for kind, t, pod, vals in self._raw:
                d = {"kind": kind, "t_s": t, "pod": pod}
                if type(vals) is dict:
                    d.update(vals)
                elif kind == "place":
                    recs, slices, t1, claim, partition, backfilled = vals
                    d["jobs"] = [r.idx for r in recs]
                    d["names"] = [r.name for r in recs]
                    # JSON-safe: slice ranges arrive as tuples
                    d["slices"] = [list(s) for s in slices]
                    d["t1_s"] = t1
                    d["claim"] = claim
                    d["partition"] = partition
                    d["backfilled"] = backfilled
                else:
                    d.update(zip(fields[kind], vals))
                ev.append(d)
            self._cache = ev
        return self._cache

    def __len__(self) -> int:
        return len(self._raw)

    def by_kind(self, kind: str) -> list[dict]:
        return [e for e in self.events if e["kind"] == kind]

    # ------------------------------------------------------------- spans

    def job_spans(self) -> dict[int, dict]:
        """Per-job lifecycle spans reconstructed from the event stream:
        ``{job_idx: {arrive, window, place, free, pod, backfilled}}``
        (missing stages stay ``None``).  The span-chain completeness
        tests assert every arrived job reaches ``place`` and its claim
        reaches ``free``."""
        spans: dict[int, dict] = {}
        claim_free: dict[tuple[int, int], float] = {}
        for e in self.events:
            if e["kind"] == "free" and e.get("claim") is not None:
                claim_free[(e["pod"], e["claim"])] = e["t_s"]
        for e in self.events:
            k = e["kind"]
            if k == "arrive":
                spans[e["job"]] = {"arrive": e["t_s"], "window": None,
                                   "place": None, "run_end": None,
                                   "free": None, "pod": e["pod"],
                                   "backfilled": False}
            elif k == "window":
                for j in e["jobs"]:
                    if j in spans:
                        spans[j]["window"] = e["t_s"]
            elif k == "place":
                for j in e["jobs"]:
                    if j in spans:
                        spans[j]["place"] = e["t_s"]
                        spans[j]["run_end"] = e["t1_s"]
                        spans[j]["backfilled"] = e.get("backfilled", False)
                        if e.get("claim") is not None:
                            spans[j]["free"] = claim_free.get(
                                (e["pod"], e["claim"]))
        return spans

    # ----------------------------------------------------------- exports

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")

    def chrome_trace(self, pods: tuple[int, ...] = (8,)) -> dict:
        """``trace_event``-format dict: one process per pod, one thread
        per slice unit (plus an "events" thread per pod for instants).
        Each ``place`` event becomes one ``ph="X"`` complete event per
        claimed unit spanning ``[t_s, t1_s)`` — the slice-occupancy
        timeline as Perfetto tracks.  Timestamps are microseconds of
        simulated time."""
        te: list[dict] = []
        for p, w in enumerate(pods):
            te.append({"ph": "M", "pid": p, "tid": 0, "name": "process_name",
                       "args": {"name": f"pod{p} ({w} units)"}})
            for u in range(w):
                te.append({"ph": "M", "pid": p, "tid": u,
                           "name": "thread_name",
                           "args": {"name": f"unit {u}"}})
            te.append({"ph": "M", "pid": p, "tid": w, "name": "thread_name",
                       "args": {"name": "events"}})
        for e in self.events:
            p = e["pod"]
            ts = e["t_s"] * 1e6
            if e["kind"] == "place":
                dur = max(e["t1_s"] - e["t_s"], 0.0) * 1e6
                name = ",".join(e.get("names", [])) or e.get("partition", "run")
                for start, width in e["slices"]:
                    for u in range(start, start + width):
                        te.append({
                            "ph": "X", "pid": p, "tid": u, "ts": ts,
                            "dur": dur, "name": name,
                            "cat": ("backfill" if e.get("backfilled")
                                    else "run"),
                            "args": {"partition": e.get("partition", ""),
                                     "claim": e.get("claim"),
                                     "jobs": e.get("jobs", [])}})
            elif e["kind"] in ("arrive", "window", "refit", "tick"):
                tid = pods[p] if p < len(pods) else 0
                te.append({"ph": "i", "pid": p, "tid": tid, "ts": ts,
                           "s": "t", "name": e["kind"], "cat": "lifecycle",
                           "args": {k: v for k, v in e.items()
                                    if k not in ("kind", "t_s", "pod")}})
        return {"traceEvents": te, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str,
                           pods: tuple[int, ...] = (8,)) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(pods), f)


# ---------------------------------------------------------------------------
# The bundle the simulator consumes
# ---------------------------------------------------------------------------


class Telemetry:
    """Recorder + registry bundle with the simulator's emission hooks.

    Pass to ``ClusterSimulator(policy, cfg, telemetry=Telemetry())``.
    The hooks keep all metric semantics here so the simulator's hot path
    stays a handful of guarded one-line calls; with ``telemetry=None``
    (the default) the simulator pays one ``is not None`` test per event.

    Metric names (see ``docs/observability.md`` for units):

    * counters — ``jobs_arrived``, ``windows_formed``, ``groups_placed``,
      ``jobs_placed``, ``backfills``, ``refits``, ``frees``, ``ticks``,
      ``queue_depth_integral_s`` (∫ pending-depth dt),
      ``busy_unit_s`` (∫ claimed-units dt);
    * gauges — ``queue_depth``, ``busy_units`` (last event-time values);
    * histograms — ``wait_s`` (``WAIT_BUCKETS_S`` buckets).
    """

    def __init__(self, recorder: TraceRecorder | None = None,
                 metrics: MetricsRegistry | None = None):
        self.recorder = recorder if recorder is not None else TraceRecorder()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._arrived = m.counter("jobs_arrived")
        self._windows = m.counter("windows_formed")
        self._groups = m.counter("groups_placed")
        self._jobs_placed = m.counter("jobs_placed")
        self._backfills = m.counter("backfills")
        self._refits = m.counter("refits")
        self._frees = m.counter("frees")
        self._ticks = m.counter("ticks")
        self._qd_int = m.counter("queue_depth_integral_s")
        self._busy_int = m.counter("busy_unit_s")
        self._qd = m.gauge("queue_depth")
        self._busy = m.gauge("busy_units")
        self._wait = m.histogram("wait_s", WAIT_BUCKETS_S)
        # bound raw-stream append: the hooks run per simulator event, so
        # they skip the emit() call layer (the events property detects
        # growth by length, no invalidation needed)
        self._append = self.recorder._raw.append

    # ------------------------------------------------------------- hooks

    def on_clock(self, dt: float, queue_depth: int, busy_units: int) -> None:
        """Advance the time integrals over an elapsed event gap ``dt``
        during which ``queue_depth``/``busy_units`` were constant."""
        self._qd_int.value += queue_depth * dt
        self._busy_int.value += busy_units * dt
        self._qd.value = queue_depth
        self._busy.value = busy_units

    def on_clock_totals(self, qd_integral_s: float, busy_integral_s: float,
                        queue_depth: int, busy_units: int) -> None:
        """Fold whole-run integral totals in one call.  The simulator
        accumulates the event-gap integrals in loop locals (a per-pop
        hook call is measurable against the ``telemetry_overhead`` gate)
        and flushes them here when the heap drains; the gauges get the
        last event-time values."""
        self._qd_int.value += qd_integral_s
        self._busy_int.value += busy_integral_s
        self._qd.value = queue_depth
        self._busy.value = busy_units

    def on_arrive(self, t: float, pod: int, job: int, name: str,
                  job_class: str, units: int) -> None:
        self._arrived.value += 1
        self._append(("arrive", t, pod, (job, name, job_class, units)))

    def on_window(self, t: float, pod: int, jobs: list[int],
                  pending_left: int) -> None:
        self._windows.value += 1
        self._append(("window", t, pod, (jobs, pending_left)))

    def on_place(self, t: float, pod: int, recs, slices, t1: float,
                 claim, partition: str, backfilled: bool) -> None:
        """``recs`` are the placed group's ``JobRecord``\\ s — their
        ``idx``/``name`` columns materialize lazily with the event."""
        self._groups.value += 1
        self._jobs_placed.value += len(recs)
        if backfilled:
            self._backfills.value += 1
        observe = self._wait.observe
        for r in recs:
            observe(t - r.arrival)
        self._append(("place", t, pod,
                      (recs, slices, t1, claim, partition, backfilled)))

    def on_refit(self, t: float, pod: int, partition: str,
                 n_jobs: int) -> None:
        self._refits.value += 1
        self._append(("refit", t, pod, (partition, n_jobs)))

    def on_free(self, t: float, pod: int, claim) -> None:
        self._frees.value += 1
        self._append(("free", t, pod, (claim,)))

    def on_tick(self, t: float) -> None:
        self._ticks.value += 1
        self._append(("tick", t, 0, ()))


# ---------------------------------------------------------------------------
# Drift signals
# ---------------------------------------------------------------------------


@dataclass
class DriftMonitor:
    """EMA-baseline drift detector over arrival-mix and occupancy signals.

    Each :meth:`observe` call supplies one window's measurements:

    * ``class_counts`` — arrival counts per job class (CI/MI/US) since
      the last observation;
    * ``width_counts`` — arrival counts per requested slice width;
    * ``idle_slice_frac`` — the live idle-slice-time fraction.

    The monitor compares each window's class/width mix **entropy**
    (bits) and idle fraction against exponential-moving-average
    baselines; drift fires when the entropy shifts by more than
    ``entropy_threshold`` bits or the idle fraction *rises* more than
    ``idle_threshold`` above its baseline (occupancy collapsing — the
    serving agent has gone stale).  The first observation only seeds the
    baselines.  After a consumer acts on a drift verdict (e.g. a
    retraining cycle) call :meth:`rebase` so the post-action regime
    becomes the new baseline instead of re-firing every window.
    """

    entropy_threshold: float = 0.5       # bits of mix-entropy shift
    idle_threshold: float = 0.15         # idle_slice_frac rise
    alpha: float = 0.5                   # EMA smoothing
    min_arrivals: int = 4                # windows thinner than this only
                                         # update the EMA, never fire
    history: list = field(default_factory=list)

    def __post_init__(self):
        self._ema: dict[str, float] | None = None
        self._pending_rebase = False

    def observe(self, class_counts: dict, width_counts: dict,
                idle_slice_frac: float) -> dict:
        """Fold one window in; returns ``{"drift": bool, "signals": {...},
        "reasons": [...]}`` (also appended to ``history``)."""
        n = sum(class_counts.values())
        sig = {"class_entropy": entropy_bits(class_counts),
               "width_entropy": entropy_bits(width_counts),
               "idle_slice_frac": float(idle_slice_frac),
               "arrivals": int(n)}
        reasons: list[str] = []
        if self._ema is None or self._pending_rebase:
            self._ema = {k: sig[k] for k in
                         ("class_entropy", "width_entropy",
                          "idle_slice_frac")}
            self._pending_rebase = False
        elif n >= self.min_arrivals:
            if abs(sig["class_entropy"] - self._ema["class_entropy"]) \
                    > self.entropy_threshold:
                reasons.append("class_entropy")
            if abs(sig["width_entropy"] - self._ema["width_entropy"]) \
                    > self.entropy_threshold:
                reasons.append("width_entropy")
            if sig["idle_slice_frac"] - self._ema["idle_slice_frac"] \
                    > self.idle_threshold:
                reasons.append("idle_slice_frac")
        a = self.alpha
        for k in ("class_entropy", "width_entropy", "idle_slice_frac"):
            self._ema[k] = a * sig[k] + (1 - a) * self._ema[k]
        out = {"drift": bool(reasons), "signals": sig, "reasons": reasons}
        self.history.append(out)
        return out

    def rebase(self) -> None:
        """Reset the EMA baselines at the next observation (call after a
        retraining cycle: the refreshed agent defines the new normal)."""
        self._pending_rebase = True


# ---------------------------------------------------------------------------
# Wall-clock phase profiling (benchmarks --profile)
# ---------------------------------------------------------------------------


class PhaseTimer:
    """Accumulate wall time per named phase; ``as_dict`` is JSON-able."""

    def __init__(self):
        self.totals: dict[str, float] = {}

    class _Span:
        def __init__(self, timer, name):
            self.timer, self.name = timer, name

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self.timer.totals[self.name] = (
                self.timer.totals.get(self.name, 0.0)
                + time.perf_counter() - self.t0)
            return False

    def phase(self, name: str) -> "PhaseTimer._Span":
        return PhaseTimer._Span(self, name)

    def add(self, name: str, seconds: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + seconds

    def as_dict(self) -> dict[str, float]:
        return {k: round(v, 6) for k, v in sorted(self.totals.items())}
