"""Cluster-level arrival routing: which pod serves a submission.

The fleet simulator (``ClusterSimulator`` with ``SimConfig.pods`` longer
than one) keeps the whole per-pod dispatch path — FCFS windows, the
first-sight protocol, slice-level first-fit, EASY backfill — unchanged,
and adds exactly one decision above it: at the instant a submission
arrives, a :class:`Router` picks the pod whose pending queue it joins.
Everything downstream is per-pod; a routed job never migrates.

Routers see a :class:`FleetView` — an immutable snapshot of every pod's
width, free-unit mask, queue depths, and claimed units at the arrival
instant — and must be **deterministic** functions of ``(arrival, view,
seed)``: the simulator draws no randomness, so two runs of one trace
produce identical assignments.  Eligibility is width-driven: a submission
requesting ``meta["units"]`` slice units (full pod when unhinted, since
first-sight jobs run solo on a whole pod) may only be routed to pods at
least that wide, which is what keeps heterogeneous 4/8-unit fleets
deadlock-free.

Shipped policies:

    hash          — stateless tenant-affine hashing (CRC-32 of the binary
                    path mixed with the seed, modulo the eligible pods).
                    The only router computable from the trace alone, which
                    is what lets the vectorized engine pre-split a fleet
                    trace into independent per-pod lanes.
    least_loaded  — the pod with the lowest (claimed + queued units) per
                    unit of width; ties break on pod index.
    frag          — fragmentation-scored placement à la the FGD scheduler
                    (arXiv 2512.16099): hypothetically first-fit the
                    requested width onto each pod that can host it *now*
                    and pick the pod whose free space is fragmented least
                    by the placement — mice sink into already-busy or
                    narrow pods, wide aligned holes survive for elephants.
                    Falls back to least-loaded ranking when no pod fits
                    the request immediately.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

from repro.core.partition import N_UNITS, VALID_WIDTHS


@dataclass(frozen=True)
class PodView:
    """One pod at the routing instant (pod-local units: ``len(free) ==
    width``; offsets into the fleet-wide unit axis are the simulator's
    concern, not the router's)."""

    idx: int
    width: int
    free: tuple[bool, ...]
    pending: int                 # submissions queued, not yet dispatched
    ready: int                   # dispatched groups awaiting slice units
    queue_units: int             # slice units requested by queued work
    busy_units: int              # slice units currently claimed

    @property
    def load(self) -> float:
        """Claimed plus queued units per unit of width — the
        least-loaded ranking key."""
        return (self.busy_units + self.queue_units) / self.width

    @property
    def free_units(self) -> int:
        return sum(self.free)


@dataclass(frozen=True)
class FleetView:
    """Immutable fleet snapshot handed to :meth:`Router.route`."""

    pods: tuple[PodView, ...]
    now_s: float = 0.0


def _first_fit(free, width: int) -> int | None:
    """First buddy-aligned offset where ``width`` consecutive units are
    free — the same alignment rule ``find_offsets`` places with."""
    for off in range(0, len(free) - width + 1, width):
        if all(free[off:off + width]):
            return off
    return None


def aligned_free_slots(free, width: int) -> int:
    """How many aligned width-``width`` requests the mask could host."""
    return sum(1 for off in range(0, len(free) - width + 1, width)
               if all(free[off:off + width]))


def fragmentation_units(free) -> float:
    """Unusable-free measure (FGD-style, unit-denominated): averaged over
    the request widths the pod could serve, the number of free units not
    coverable by an aligned free block of that width.  0 for an empty or
    full pod; placing a mouse mid-pod raises it by stranding the units
    around it for wider requests."""
    total = sum(free)
    if total == 0:
        return 0.0
    widths = [w for w in VALID_WIDTHS if w <= len(free)]
    return sum(total - w * aligned_free_slots(free, w)
               for w in widths) / len(widths)


def _requested_units(arrival) -> int:
    prof = arrival.profile
    return prof.requested_units if prof is not None else N_UNITS


class Router:
    """Deterministic arrival -> pod assignment over a :class:`FleetView`."""

    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def eligible(self, arrival, fleet: FleetView) -> list[PodView]:
        """Pods wide enough for the submission's requested width.  A
        fleet whose widest pod matches ``N_UNITS`` (asserted by
        ``SimConfig``) always has at least one eligible pod."""
        req = _requested_units(arrival)
        pods = [p for p in fleet.pods if p.width >= req]
        assert pods, f"no pod fits a {req}-unit request"
        return pods

    def route(self, arrival, fleet: FleetView) -> int:
        raise NotImplementedError


class HashRouter(Router):
    """Stateless tenant-affine hashing: the same binary always lands on
    the same pod (given one fleet shape and seed), independent of cluster
    state — CRC-32, not Python's per-process-salted ``hash``."""

    name = "hash"

    def route(self, arrival, fleet: FleetView) -> int:
        pods = self.eligible(arrival, fleet)
        h = zlib.crc32(arrival.binary.encode("utf-8"))
        h ^= (self.seed * 0x9E3779B1) & 0xFFFFFFFF
        return pods[h % len(pods)].idx


class LeastLoadedRouter(Router):
    """Lowest (claimed + queued units) / width; ties break on pod index."""

    name = "least_loaded"

    def route(self, arrival, fleet: FleetView) -> int:
        pods = self.eligible(arrival, fleet)
        return min(pods, key=lambda p: (p.load, p.idx)).idx


class FragRouter(Router):
    """Fragmentation-scored routing (arXiv 2512.16099's fragmentation
    gradient, adapted to buddy-aligned slice units): among pods that can
    host the requested width *right now*, pick the one where the
    hypothetical first-fit placement increases
    :func:`fragmentation_units` the least (then least load, then index).
    When nothing fits immediately, rank all eligible pods least-loaded."""

    name = "frag"

    def route(self, arrival, fleet: FleetView) -> int:
        req = _requested_units(arrival)
        pods = self.eligible(arrival, fleet)
        best = None
        for p in pods:
            off = _first_fit(p.free, min(req, p.width))
            if off is None:
                continue
            after = list(p.free)
            after[off:off + req] = [False] * req
            delta = fragmentation_units(after) - fragmentation_units(p.free)
            key = (delta, p.load, p.idx)
            if best is None or key < best:
                best = key
        if best is not None:
            return best[2]
        return min(pods, key=lambda p: (p.load, p.idx)).idx


ROUTERS: dict[str, type[Router]] = {
    HashRouter.name: HashRouter,
    LeastLoadedRouter.name: LeastLoadedRouter,
    FragRouter.name: FragRouter,
}


def make_router(name: str, seed: int = 0) -> Router:
    assert name in ROUTERS, f"unknown router {name!r} (have {sorted(ROUTERS)})"
    return ROUTERS[name](seed=seed)
