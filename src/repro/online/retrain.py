"""MISO-style periodic re-training against the live profile repository.

Every ``interval_s`` of *simulated* time (driven by the simulator's TICK
events), the retrainer snapshots the profile repository — exactly the
applications the cluster has observed and profiled so far — re-trains the
DQN co-scheduler on queues drawn from that snapshot, **warm-starting** from
the serving agent's current params/target/optimizer state, and hot-swaps
the refreshed agent into the dispatch policy.  The scanned training engine
(``train_agent``) makes minute-scale refresh cycles affordable: one cycle
at the default retrain budget is a few hundred episodes, a couple of
seconds of wall clock on CPU.

Re-training waits until the repository holds at least ``min_jobs`` distinct
profiles (early ticks on a cold repository would train on one or two
applications and overfit the Q-function to them).  Queues are built with
``strict=False``, so a repository that does not yet span all three CI/MI/US
classes still trains — recipes remap onto the classes observed.

Arrival-aware serving agents re-train transparently: the retrainer derives
its environment config from the serving policy (below), so an agent whose
``EnvConfig.obs_context`` is set refreshes on the context-widened
observation — ``train_agent`` samples per-episode cluster-state contexts
inside the scanned rollout (``docs/observation.md``), and the hot-swapped
agent keeps consuming the simulator's real dispatch snapshots.  Nothing in
this module branches on the observation mode.

Wall-clock cost note: each distinct ``TrainConfig``/``EnvConfig`` shape
compiles its own engine; reusing one ``RetrainConfig`` across cycles means
the first tick pays compilation and every later tick runs from the engine
cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.agent import DQNConfig
from repro.core.train import (
    TrainConfig, TrainOnlineConfig, train_agent, train_online,
)
from repro.online.policies import RLDispatchPolicy
from repro.online.telemetry import DriftMonitor


def default_retrain_train_config(episodes: int = 240) -> TrainConfig:
    """A refresh-sized training budget: modest exploration restart (the
    warm-started Q-function needs adaptation, not rediscovery), small queue
    set, one history record per cycle."""
    return TrainConfig(
        episodes=episodes, eval_every=episodes, n_train_queues=8,
        n_heldout_queues=0, strict_classes=False, batch_envs=8,
        update_every=8,
        dqn=DQNConfig(eps_start=0.25, eps_end=0.01, eps_decay_steps=2000,
                      buffer_size=20_000),
    )


def default_retrain_online_config(rounds: int = 8) -> TrainOnlineConfig:
    """A refresh-sized sim-in-the-loop budget (``reward="queueing"``):
    a handful of collect/update rounds, no population (the warm-started
    incumbent IS the population seed and the elitism guard keeps it when
    the refresh does not improve eval p99 wait)."""
    return TrainOnlineConfig(
        rounds=rounds, traces_per_round=4, n_arrivals=32, capacity=96,
        population=1, eval_traces=4, updates_per_round=32,
        eps_start=0.25, eps_end=0.05, eps_decay_rounds=max(1, rounds - 2),
        dqn=DQNConfig(buffer_size=20_000),
    )


@dataclass
class OnlineRetrainer:
    """Tick callback for :class:`~repro.online.simulator.ClusterSimulator`.

    Attach with ``ClusterSimulator(policy, tick_interval_s=cfg.interval_s,
    on_tick=retrainer)``; ``history`` records one entry per completed
    re-training cycle (simulated time, repository size, final train eval).
    The environment config is the serving policy's own (the agent must be
    re-trained for exactly the env it schedules in), so it is derived, not
    passed.

    ``trigger`` selects when a tick actually retrains:

    * ``"clock"`` (default) — every tick, the original MISO-style periodic
      refresh.  Bit-compatible with pre-trigger behaviour.
    * ``"drift"`` — each tick feeds the interval's arrival class/width mix
      and the live idle-slice fraction to a
      :class:`~repro.online.telemetry.DriftMonitor`; re-training runs only
      on a drift verdict, and the monitor's baselines are rebased
      afterwards (the refreshed agent defines the new normal).  History
      entries gain ``trigger``/``signals``/``reasons`` fields; skipped
      ticks leave no entry (``monitor.history`` has the full verdict log).

    ``reward`` selects what the refresh optimizes:

    * ``"proxy"`` (default) — ``train_agent`` on the offline per-window
      throughput proxy, bit-compatible with pre-queueing behaviour.
    * ``"queueing"`` — ``train_online`` rolls the repository's jobs as
      serving traces through the vectorized simulator and optimizes the
      engine-accumulated wait/turnaround + makespan reward directly (the
      metric the drift monitor watches), warm-started from the incumbent;
      ``online_cfg`` sizes the refresh
      (:func:`default_retrain_online_config` when unset).  History entries
      carry ``rounds``/``train_eval_p99_wait`` instead of the proxy's
      ``episodes``/``train_eval_throughput``.
    """

    policy: RLDispatchPolicy
    train_cfg: TrainConfig = field(default_factory=default_retrain_train_config)
    interval_s: float = 1800.0           # K simulated minutes between cycles
    min_jobs: int = 4
    reseed: bool = True                  # vary queue draws across cycles
    trigger: str = "clock"               # "clock" | "drift"
    reward: str = "proxy"                # "proxy" | "queueing"
    online_cfg: TrainOnlineConfig | None = None
    monitor: DriftMonitor = field(default_factory=DriftMonitor)
    history: list = field(default_factory=list)

    def __post_init__(self):
        if self.trigger not in ("clock", "drift"):
            raise ValueError(f"unknown trigger {self.trigger!r}; "
                             f"expected 'clock' or 'drift'")
        if self.reward not in ("proxy", "queueing"):
            raise ValueError(f"unknown reward {self.reward!r}; "
                             f"expected 'proxy' or 'queueing'")
        self._last_t = 0.0

    def __call__(self, now: float, sim) -> None:
        extra: dict = {}
        if self.trigger == "drift":
            arrivals = sim.live_arrivals(self._last_t, now)
            self._last_t = now
            cc: dict[str, int] = {}
            wc: dict[int, int] = {}
            for a in arrivals:
                cc[a.profile.job_class] = cc.get(a.profile.job_class, 0) + 1
                w = a.profile.requested_units
                wc[w] = wc.get(w, 0) + 1
            verdict = self.monitor.observe(cc, wc, sim.live_idle_frac())
            if not verdict["drift"]:
                return
            extra = {"trigger": "drift", "signals": verdict["signals"],
                     "reasons": verdict["reasons"]}
        repo = self.policy.repository
        jobs = repo.jobs()
        if len(jobs) < self.min_jobs:
            return
        env_cfg = self.policy.scheduler.env_cfg
        if self.reward == "queueing":
            cfg = self.online_cfg or default_retrain_online_config()
            if cfg.window > env_cfg.window:
                # one formation must not span several RL episodes
                cfg = replace(cfg, window=env_cfg.window)
            if self.reseed:
                cfg = replace(cfg, seed=cfg.seed + len(self.history))
            agent, hist = train_online(jobs, env_cfg, cfg,
                                       warm_start=self.policy.agent)
            cycle = {"rounds": hist[-1]["round"],
                     "train_eval_p99_wait": min(hist[-1]["final_scores"]),
                     "selected": hist[-1]["selected"]}
        else:
            cfg = self.train_cfg
            if self.reseed:
                cfg = replace(cfg, seed=cfg.seed + len(self.history))
            agent, hist = train_agent(jobs, env_cfg, cfg, heldout=set(),
                                      warm_start=self.policy.agent)
            cycle = {"episodes": hist[-1]["episode"],
                     "train_eval_throughput": hist[-1]["eval_throughput"]}
        self.policy.hot_swap(agent)
        self.history.append({
            "t_s": now,
            "repository_jobs": len(jobs),
            "class_counts": repo.class_counts(),
            **cycle,
            **extra,
        })
        if self.trigger == "drift":
            self.monitor.rebase()
