"""Deterministic discrete-event cluster simulator (paper §IV-B, online phase).

Models one pod serving a stream of job submissions over *simulated* time.
Three event kinds drive the clock, popped from a single heap in
``(time, kind, seq)`` order; *all* events sharing a timestamp are drained
before any dispatch decision, so simultaneous events resolve
deterministically — coincident arrivals (batch submissions, tied burst
times) all reach the pending queue and can share one dispatch window, and
periodic ticks observe the repository state of the same instant:

    ARRIVE — a job submission joins the FCFS pending queue,
    TICK   — a periodic simulated-time hook (the re-training loop's clock),
    FREE   — a dispatched group's slice-range claim expires.

Slice-level occupancy (``mode="concurrent"``, the default)
----------------------------------------------------------
The pod is an occupancy map over its ``N_UNITS`` slice units, not a scalar
busy flag.  Whenever slice units are idle and the dispatched-group queue is
empty, the FCFS head of the pending queue (up to ``window`` submissions, as
``(binary, profile)`` pairs) is handed to the policy, which returns
:class:`~repro.core.scheduler.Placement`\\ s — co-run groups bound to
(possibly sub-pod, width-fitted) hierarchical partitions.  Each placement's
slices are then first-fitted onto disjoint aligned unit ranges
(:func:`~repro.core.partition.find_offsets`), so independent groups run
**concurrently** on disjoint slices; its FREE event is keyed by the claimed
slice ranges and releases exactly those units when the group drains.

When the head group does not fit the current free units, it reserves its
earliest feasible start (computed by replaying the outstanding claims'
expiries — no new work is admitted past a blocked head, so the reservation
is exact) and a **backfill** scan lets later groups of the already-
dispatched queue start immediately *iff* they fit the idle units now and
their predicted makespan ends by the head's reserved start — EASY-style
backfill, so jumping the queue can never delay the head.

``mode="blocking"`` recovers the PR-3 whole-pod semantics bit-compatibly:
one window's groups execute back to back on the full pod and the pod is
released only when the whole block drains.  On traces without sub-pod
width hints the two modes produce identical results (all placements are
full-pod, so concurrency never materializes) — the regression tests pin
this equivalence.

Dispatch-time context
---------------------
Every window hand-off carries a :class:`~repro.core.env.DispatchContext`
snapshot of the cluster at the dispatch instant: the live free-unit mask
(the very list placements are first-fitted against), each head
submission's age since arrival, and the pending-queue depth left behind.
Policies are free to ignore it (the heuristic baselines do); an RL policy
whose environment runs with ``EnvConfig.obs_context`` folds it into the
agent's observation, closing the loop that lets the policy *learn*
backfill-like behavior the dispatch layer otherwise supplies by hand —
see ``docs/observation.md`` for the exact feature layout and invariants.

Per-job completion times come from the phase-simulated
:func:`~repro.core.perfmodel.corun` under the fitted partition.  Every
dispatched group appends a :class:`Segment` (now carrying its claimed
slice ranges and a backfill flag) to the occupancy timeline, and
:class:`SimResult` exposes fragmentation metrics on top of it: per-slice
busy time, slice-level utilization, and the idle-slice-time fraction —
packing quality, not just makespan.

The simulator itself draws no randomness: given one trace (see
:mod:`repro.online.traces`) and one policy, two runs produce identical
:class:`SimResult`\\ s — determinism lives entirely in the trace seed.
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.env import DispatchContext
from repro.core.partition import N_UNITS, find_offsets
from repro.core.perfmodel import CoRunResult, corun
from repro.core.profiles import JobProfile
from repro.core.scheduler import to_placements

_ARRIVE, _TICK, _FREE = 0, 1, 2          # same-time resolution order


@dataclass(frozen=True)
class Arrival:
    """One submission: at time ``t`` the binary at ``binary`` is handed in.

    ``profile`` is the measurement the cluster *would* obtain by profiling
    the job during its first solo run — the policy only sees it through the
    repository protocol (first sight: solo + insert; afterwards: lookup).
    A ``meta["units"]`` hint on the profile (set by right-sized traces) is
    the slice width the submission requests from the placement layer.
    """

    t: float
    binary: str
    profile: JobProfile


@dataclass
class Segment:
    """One group's occupancy: [t0, t1) under ``partition``.

    ``slices`` holds the claimed ``(start, width)`` unit ranges (empty only
    for legacy construction); ``backfilled`` marks groups that jumped a
    blocked head into idle units via the EASY-backfill scan."""

    t0: float
    t1: float
    jobs: int
    partition: str
    slices: tuple[tuple[int, int], ...] = ()
    backfilled: bool = False

    @property
    def units(self) -> int:
        return sum(w for _, w in self.slices)


@dataclass
class JobRecord:
    """Per-submission lifecycle: arrival -> dispatch -> finish.

    ``dispatch`` is the instant the job's *group* starts executing (a
    window's groups can start at different times under slice-level
    dispatch), so ``wait`` covers all queueing delay including queueing
    behind earlier groups of the same window.  ``units`` is the slice width
    the job actually ran on; ``backfilled`` marks jobs whose group was
    started by the backfill scan."""

    binary: str
    name: str
    arrival: float
    solo_time: float
    dispatch: float = math.nan
    finish: float = math.nan
    group_size: int = 0
    partition: str = ""
    units: int = N_UNITS
    backfilled: bool = False

    @property
    def wait(self) -> float:
        return self.dispatch - self.arrival

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival


@dataclass
class SimResult:
    """Cluster-level outcome of one (trace, policy) simulation."""

    policy: str
    window: int
    jobs: list[JobRecord]
    mode: str = "concurrent"
    timeline: list[Segment] = field(default_factory=list)
    busy_time: float = 0.0
    dispatches: int = 0
    ticks: int = 0
    backfills: int = 0
    slice_busy_s: list[float] = field(default_factory=lambda: [0.0] * N_UNITS)

    @property
    def makespan(self) -> float:
        """Time the last job drains (includes arrival-limited idle gaps)."""
        return max((j.finish for j in self.jobs), default=0.0)

    @property
    def total_solo_time(self) -> float:
        return sum(j.solo_time for j in self.jobs)

    @property
    def throughput(self) -> float:
        """Makespan-derived: solo work retired per unit of wall clock.

        Pure time sharing on a saturated cluster scores ~1.0 (idle gaps pull
        it below); co-scheduling pushes it above by retiring more than one
        job's solo work per pod-second."""
        m = self.makespan
        return self.total_solo_time / m if m > 0 else 0.0

    @property
    def utilization(self) -> float:
        """Fraction of the makespan during which *any* slice was busy."""
        m = self.makespan
        return self.busy_time / m if m > 0 else 0.0

    # ---- fragmentation metrics (slice-level packing quality) --------------

    @property
    def unit_busy_s(self) -> float:
        """Total claimed unit-seconds (Σ per-slice busy time)."""
        return float(sum(self.slice_busy_s))

    @property
    def slice_utilization(self) -> float:
        """Claimed unit-seconds / (N_UNITS x makespan): how much of the
        pod's slice real estate the schedule actually occupied."""
        m = self.makespan
        return self.unit_busy_s / (N_UNITS * m) if m > 0 else 0.0

    @property
    def idle_slice_frac(self) -> float:
        """Fraction of slice-time left idle over the makespan — the
        fragmentation cost slice-level dispatch + backfill drives down."""
        m = self.makespan
        return 1.0 - self.slice_utilization if m > 0 else 0.0

    @property
    def per_slice_utilization(self) -> list[float]:
        m = self.makespan
        return [b / m if m > 0 else 0.0 for b in self.slice_busy_s]

    def slice_timeline(self) -> list[list[tuple[float, float]]]:
        """Per-unit busy intervals reconstructed from the segment timeline
        (claims release at group drain, so segment spans *are* the claims)."""
        out: list[list[tuple[float, float]]] = [[] for _ in range(N_UNITS)]
        for seg in self.timeline:
            for start, width in seg.slices:
                for u in range(start, start + width):
                    out[u].append((seg.t0, seg.t1))
        for iv in out:
            iv.sort()
        return out

    @property
    def mean_wait(self) -> float:
        return float(np.mean([j.wait for j in self.jobs])) if self.jobs else 0.0

    @property
    def mean_turnaround(self) -> float:
        return float(np.mean([j.turnaround for j in self.jobs])) if self.jobs else 0.0

    @property
    def p50_wait(self) -> float:
        return (float(np.percentile([j.wait for j in self.jobs], 50))
                if self.jobs else 0.0)

    @property
    def p99_wait(self) -> float:
        """Tail wait — the fleet-scale headline metric (see ROADMAP)."""
        return (float(np.percentile([j.wait for j in self.jobs], 99))
                if self.jobs else 0.0)

    @property
    def p95_turnaround(self) -> float:
        return (float(np.percentile([j.turnaround for j in self.jobs], 95))
                if self.jobs else 0.0)

    def summary(self) -> dict:
        """JSON-able digest for BENCH_online.json."""
        return {
            "policy": self.policy,
            "mode": self.mode,
            "jobs": len(self.jobs),
            "makespan_s": self.makespan,
            "busy_s": self.busy_time,
            "throughput": self.throughput,
            "utilization": self.utilization,
            "slice_utilization": self.slice_utilization,
            "idle_slice_frac": self.idle_slice_frac,
            "backfills": self.backfills,
            "mean_wait_s": self.mean_wait,
            "p50_wait_s": self.p50_wait,
            "p99_wait_s": self.p99_wait,
            "mean_turnaround_s": self.mean_turnaround,
            "p95_turnaround_s": self.p95_turnaround,
            "dispatches": self.dispatches,
            "groups": len(self.timeline),
            "mean_group_size": (float(np.mean([s.jobs for s in self.timeline]))
                                if self.timeline else 0.0),
        }


@dataclass
class _Run:
    """A dispatched group awaiting (or holding) slice units."""

    group: list[JobProfile]
    partition: object                    # Partition (possibly width-fitted)
    recs: list[JobRecord]
    pred: CoRunResult                    # exact times under `partition`
    window_id: int = 0                   # dispatch window this group came from


class ClusterSimulator:
    """Event-driven pod: FCFS admission windows dispatched by a policy.

    ``mode="concurrent"`` (default) places each dispatched group onto
    disjoint slice-unit ranges so independent groups run side by side;
    ``backfill=True`` additionally lets later groups of the dispatched
    queue jump a blocked head into idle units when their predicted finish
    cannot delay the head's reserved start.  ``mode="blocking"`` is the
    PR-3 whole-pod block dispatch, kept bit-compatible for regression.

    ``on_tick(now, sim)`` fires every ``tick_interval_s`` of simulated time
    while work remains — the MISO-style re-training loop hangs off it (see
    :mod:`repro.online.retrain`); ticks stop as soon as the heap, pending
    queue, and pod are all drained, so simulations always terminate.
    """

    def __init__(self, policy, window: int = 8,
                 tick_interval_s: float | None = None, on_tick=None,
                 mode: str = "concurrent", backfill: bool = True):
        assert window >= 1
        assert mode in ("concurrent", "blocking"), mode
        self.policy = policy
        self.window = window
        self.tick_interval_s = tick_interval_s
        self.on_tick = on_tick
        self.mode = mode
        self.backfill = backfill
        self.pending: deque = deque()
        self.ready: deque[_Run] = deque()
        self.busy = False                        # blocking-mode pod flag
        self._free = [True] * N_UNITS            # concurrent-mode unit map
        self._claims: dict[int, tuple[tuple[tuple[int, int], ...], float]] = {}
        self._cid = 0
        self._n_busy_units = 0
        self._busy_t0 = 0.0

    # ------------------------------------------------------------------ run

    def run(self, trace: list[Arrival]) -> SimResult:
        res = SimResult(policy=getattr(self.policy, "name", "policy"),
                        window=self.window, jobs=[], mode=self.mode)
        heap: list[tuple[float, int, int, object]] = []
        seq = 0
        # heap/pending carry the sorted-trace *index*, not the Arrival:
        # traces may legitimately reuse one Arrival object (batch
        # submissions), and identity-keyed records would alias
        order = sorted(trace, key=lambda a: a.t)
        records = [JobRecord(binary=a.binary, name=a.profile.name,
                             arrival=a.t, solo_time=a.profile.solo_time())
                   for a in order]
        res.jobs = list(records)

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, kind, seq, payload))
            seq += 1

        for i, a in enumerate(order):
            push(a.t, _ARRIVE, i)
        if self.tick_interval_s and trace:
            push(self.tick_interval_s, _TICK, None)

        self.pending.clear()
        self.ready.clear()
        self.busy = False
        self._free = [True] * N_UNITS
        self._claims.clear()
        self._n_busy_units = 0

        def handle(now, kind, payload):
            if kind == _ARRIVE:
                self.pending.append(payload)
            elif kind == _FREE:
                if self.mode == "blocking":
                    self.busy = False
                else:
                    self._release(now, payload, res)
            else:  # _TICK — only while work remains (no retrain on a drained
                # cluster), and stop rescheduling once the trace is served
                if (heap or self.pending or self.ready or self.busy
                        or self._claims):
                    if self.on_tick is not None:
                        self.on_tick(now, self)
                    res.ticks += 1
                    push(now + self.tick_interval_s, _TICK, None)

        while heap:
            now, kind, _, payload = heapq.heappop(heap)
            handle(now, kind, payload)
            # drain every coincident event before considering a dispatch:
            # same-instant arrivals (batch submissions, tied burst times)
            # must all reach the pending queue so one window sees them all
            while heap and heap[0][0] == now:
                _, kind2, _, payload2 = heapq.heappop(heap)
                handle(now, kind2, payload2)
            if self.mode == "blocking":
                self._dispatch_blocking(now, res, order, records, push)
            else:
                self._service(now, res, order, records, push)
        assert not self._claims and not self.ready, "undrained claims/groups"
        return res

    # ------------------------------------------------- blocking (PR-3) mode

    def _dispatch_blocking(self, now, res, order, records, push) -> None:
        """Whole-pod block dispatch — the PR-3 event model, verbatim (the
        dispatch context reports the idle full pod, which it is whenever a
        blocking dispatch fires)."""
        if self.busy or not self.pending:
            return
        head = [self.pending.popleft()
                for _ in range(min(self.window, len(self.pending)))]
        sched = self.policy.dispatch(
            [(order[i].binary, order[i].profile) for i in head],
            context=self._dispatch_context(now, head, order,
                                           free=(True,) * N_UNITS))
        by_name: dict[str, deque] = defaultdict(deque)
        for i in head:
            by_name[order[i].profile.name].append(records[i])
        t0 = now
        for g, p in zip(sched.groups, sched.partitions):
            block = corun(g, p)
            for job, ft in zip(g, block.finish_times):
                rec = by_name[job.name].popleft()
                # dispatch = the group's actual start, not the block
                # hand-off: jobs queued behind earlier groups of the same
                # block are still *waiting*, and a policy that forms many
                # sequential groups must not hide that queueing delay
                rec.dispatch = t0
                rec.finish = t0 + ft
                rec.group_size = len(g)
                rec.partition = p.label
            res.timeline.append(Segment(t0, t0 + block.makespan, len(g),
                                        p.label, slices=((0, N_UNITS),)))
            for u in range(N_UNITS):
                res.slice_busy_s[u] += block.makespan
            t0 += block.makespan
        leftover = [n for n, d in by_name.items() if d]
        assert not leftover, f"policy dropped submissions: {leftover}"
        res.busy_time += t0 - now
        res.dispatches += 1
        self.busy = True
        push(t0, _FREE, None)

    # --------------------------------------------- concurrent (slice) mode

    def _service(self, now, res, order, records, push) -> None:
        """Place dispatched groups onto free slice units.

        Non-backfilled groups start strictly in dispatch order; a new
        window is formed once the dispatched queue has drained (FCFS across
        windows).  With backfill enabled, a *blocked* head additionally
        admits one lookahead window while idle units exist, so small later
        arrivals become backfill candidates — on full-pod-only traces no
        units are ever free while the head is blocked, which is what keeps
        this mode bit-compatible with blocking dispatch there."""
        while True:
            progress = False
            # FCFS: place the head while it fits
            while self.ready:
                starts = find_offsets(self.ready[0].partition, self._free)
                if starts is None:
                    break
                self._place(now, self.ready.popleft(), starts, res, push)
                progress = True
            if self.ready:
                if self.backfill:
                    # bounded EASY lookahead: at most one window past the
                    # blocked head's own window may be admitted early
                    if (self.pending and any(self._free)
                            and self.ready[-1].window_id == self.ready[0].window_id):
                        self._form_window(now, res, order, records)
                        progress = True
                    if len(self.ready) > 1:
                        progress |= self._backfill_scan(now, res, push)
            elif self.pending and any(self._free):
                self._form_window(now, res, order, records)
                progress = True
            if not progress:
                return

    def _dispatch_context(self, now, head, order, free=None) -> DispatchContext:
        """Cluster-state snapshot handed to the policy with each window:
        the live free-unit mask (the same list ``find_offsets`` places
        against), each head submission's age since arrival, and the depth
        of the pending queue left behind — the arrival-aware observation
        an ``obs_context`` agent folds into its state."""
        return DispatchContext(
            free_units=tuple(self._free) if free is None else free,
            ages_s=tuple(now - order[i].t for i in head),
            queue_depth=len(self.pending),
            now_s=now)

    def _form_window(self, now, res, order, records) -> None:
        head = [self.pending.popleft()
                for _ in range(min(self.window, len(self.pending)))]
        subs = [(order[i].binary, order[i].profile) for i in head]
        ctx = self._dispatch_context(now, head, order)
        fn = getattr(self.policy, "placements", None)
        placements = (fn(subs, context=ctx) if fn is not None
                      else to_placements(self.policy.dispatch(subs,
                                                              context=ctx)))
        by_name: dict[str, deque] = defaultdict(deque)
        for i in head:
            by_name[order[i].profile.name].append(records[i])
        for pl in placements:
            recs = [by_name[j.name].popleft() for j in pl.group]
            self.ready.append(_Run(pl.group, pl.partition, recs,
                                   corun(pl.group, pl.partition),
                                   window_id=res.dispatches))
        leftover = [n for n, d in by_name.items() if d]
        assert not leftover, f"policy dropped submissions: {leftover}"
        res.dispatches += 1

    def _backfill_scan(self, now, res, push) -> bool:
        """EASY backfill: later dispatched groups may start now iff they fit
        the idle units and predictably finish by the blocked head's reserved
        start.  Backfilled claims give their units back before the head's
        reservation, so the head can never be delayed."""
        t_res = self._earliest_fit(self.ready[0].partition)
        placed = False
        for run in list(self.ready)[1:]:
            starts = find_offsets(run.partition, self._free)
            if starts is None:
                continue
            if now + run.pred.makespan <= t_res + 1e-9:
                self.ready.remove(run)
                self._place(now, run, starts, res, push, backfilled=True)
                res.backfills += 1
                placed = True
        return placed

    def _earliest_fit(self, partition) -> float:
        """Earliest time `partition` fits, replaying outstanding claim
        expiries (exact: no new non-backfill work is admitted past a
        blocked head, and backfill claims expire before this time)."""
        expiries = sorted({t1 for _, t1 in self._claims.values()})
        free = list(self._free)
        for t in expiries:
            for ranges, t1 in self._claims.values():
                if t1 <= t:
                    for start, width in ranges:
                        free[start:start + width] = [True] * width
            if find_offsets(partition, free) is not None:
                return t
        return expiries[-1] if expiries else 0.0

    def _place(self, now, run: _Run, starts, res, push,
               backfilled: bool = False) -> None:
        ranges = tuple((st, s.units)
                       for st, s in zip(starts, run.partition.slices))
        width = 0
        for st, w in ranges:
            self._free[st:st + w] = [False] * w
            width += w
        if self._n_busy_units == 0:
            self._busy_t0 = now
        self._n_busy_units += width
        t1 = now + run.pred.makespan
        for rec, ft, (si, s, _b) in zip(run.recs, run.pred.finish_times,
                                        run.partition.slots):
            rec.dispatch = now
            rec.finish = now + ft
            rec.group_size = len(run.group)
            rec.partition = run.partition.label
            rec.units = s.units
            rec.backfilled = backfilled
        res.timeline.append(Segment(now, t1, len(run.group),
                                    run.partition.label, slices=ranges,
                                    backfilled=backfilled))
        for st, w in ranges:
            for u in range(st, st + w):
                res.slice_busy_s[u] += run.pred.makespan
        cid = self._cid
        self._cid += 1
        self._claims[cid] = (ranges, t1)
        push(t1, _FREE, cid)

    def _release(self, now, cid, res) -> None:
        ranges, _t1 = self._claims.pop(cid)
        for st, w in ranges:
            self._free[st:st + w] = [True] * w
            self._n_busy_units -= w
        if self._n_busy_units == 0:
            res.busy_time += now - self._busy_t0
