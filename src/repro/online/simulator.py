"""Deterministic discrete-event cluster simulator (paper §IV-B, online phase).

Models one pod serving a stream of job submissions over *simulated* time.
Three event kinds drive the clock, popped from a single heap in
``(time, kind, seq)`` order; *all* events sharing a timestamp are drained
before any dispatch decision, so simultaneous events resolve
deterministically — coincident arrivals (batch submissions, tied burst
times) all reach the pending queue and can share one dispatch window, and
periodic ticks observe the repository state of the same instant:

    ARRIVE — a job submission joins the FCFS pending queue,
    TICK   — a periodic simulated-time hook (the re-training loop's clock),
    FREE   — the pod finishes its current dispatch block.

Whenever the pod is idle and jobs are pending, the simulator hands the FCFS
head of the queue (up to ``window`` submissions, as ``(binary, profile)``
pairs) to the dispatch policy, which returns a §IV-A :class:`Schedule` —
co-run groups with hierarchical partitions.  Groups execute back to back on
the pod; per-job completion times come from the phase-simulated
:func:`~repro.core.perfmodel.corun` (jobs inside a group finish at different
times, but the pod is released only when the whole block drains, matching
the batch semantics of the offline formulation where a window's groups run
sequentially).  Every dispatched group appends a :class:`Segment` to the
occupancy timeline, so slice utilization over time is reconstructable.

The simulator itself draws no randomness: given one trace (see
:mod:`repro.online.traces`) and one policy, two runs produce identical
:class:`SimResult`\\ s — determinism lives entirely in the trace seed.
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.perfmodel import corun
from repro.core.profiles import JobProfile

_ARRIVE, _TICK, _FREE = 0, 1, 2          # same-time resolution order


@dataclass(frozen=True)
class Arrival:
    """One submission: at time ``t`` the binary at ``binary`` is handed in.

    ``profile`` is the measurement the cluster *would* obtain by profiling
    the job during its first solo run — the policy only sees it through the
    repository protocol (first sight: solo + insert; afterwards: lookup).
    """

    t: float
    binary: str
    profile: JobProfile


@dataclass
class Segment:
    """One group's occupancy of the pod: [t0, t1) under ``partition``."""

    t0: float
    t1: float
    jobs: int
    partition: str


@dataclass
class JobRecord:
    """Per-submission lifecycle: arrival -> dispatch -> finish.

    ``dispatch`` is the instant the job's *group* starts executing (groups
    of one dispatch block run sequentially), so ``wait`` covers all
    queueing delay including in-block queueing behind earlier groups."""

    binary: str
    name: str
    arrival: float
    solo_time: float
    dispatch: float = math.nan
    finish: float = math.nan
    group_size: int = 0
    partition: str = ""

    @property
    def wait(self) -> float:
        return self.dispatch - self.arrival

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival


@dataclass
class SimResult:
    """Cluster-level outcome of one (trace, policy) simulation."""

    policy: str
    window: int
    jobs: list[JobRecord]
    timeline: list[Segment] = field(default_factory=list)
    busy_time: float = 0.0
    dispatches: int = 0
    ticks: int = 0

    @property
    def makespan(self) -> float:
        """Time the last job drains (includes arrival-limited idle gaps)."""
        return max((j.finish for j in self.jobs), default=0.0)

    @property
    def total_solo_time(self) -> float:
        return sum(j.solo_time for j in self.jobs)

    @property
    def throughput(self) -> float:
        """Makespan-derived: solo work retired per unit of wall clock.

        Pure time sharing on a saturated cluster scores ~1.0 (idle gaps pull
        it below); co-scheduling pushes it above by retiring more than one
        job's solo work per pod-second."""
        m = self.makespan
        return self.total_solo_time / m if m > 0 else 0.0

    @property
    def utilization(self) -> float:
        m = self.makespan
        return self.busy_time / m if m > 0 else 0.0

    @property
    def mean_wait(self) -> float:
        return float(np.mean([j.wait for j in self.jobs])) if self.jobs else 0.0

    @property
    def mean_turnaround(self) -> float:
        return float(np.mean([j.turnaround for j in self.jobs])) if self.jobs else 0.0

    @property
    def p95_turnaround(self) -> float:
        return (float(np.percentile([j.turnaround for j in self.jobs], 95))
                if self.jobs else 0.0)

    def summary(self) -> dict:
        """JSON-able digest for BENCH_online.json."""
        return {
            "policy": self.policy,
            "jobs": len(self.jobs),
            "makespan_s": self.makespan,
            "busy_s": self.busy_time,
            "throughput": self.throughput,
            "utilization": self.utilization,
            "mean_wait_s": self.mean_wait,
            "mean_turnaround_s": self.mean_turnaround,
            "p95_turnaround_s": self.p95_turnaround,
            "dispatches": self.dispatches,
            "groups": len(self.timeline),
            "mean_group_size": (float(np.mean([s.jobs for s in self.timeline]))
                                if self.timeline else 0.0),
        }


class ClusterSimulator:
    """Event-driven pod: FCFS admission windows dispatched by a policy.

    ``on_tick(now, sim)`` fires every ``tick_interval_s`` of simulated time
    while work remains — the MISO-style re-training loop hangs off it (see
    :mod:`repro.online.retrain`); ticks stop as soon as the heap, pending
    queue, and pod are all drained, so simulations always terminate.
    """

    def __init__(self, policy, window: int = 8,
                 tick_interval_s: float | None = None, on_tick=None):
        assert window >= 1
        self.policy = policy
        self.window = window
        self.tick_interval_s = tick_interval_s
        self.on_tick = on_tick
        self.pending: deque = deque()
        self.busy = False

    def run(self, trace: list[Arrival]) -> SimResult:
        res = SimResult(policy=getattr(self.policy, "name", "policy"),
                        window=self.window, jobs=[])
        heap: list[tuple[float, int, int, object]] = []
        seq = 0
        # heap/pending carry the sorted-trace *index*, not the Arrival:
        # traces may legitimately reuse one Arrival object (batch
        # submissions), and identity-keyed records would alias
        order = sorted(trace, key=lambda a: a.t)
        records = [JobRecord(binary=a.binary, name=a.profile.name,
                             arrival=a.t, solo_time=a.profile.solo_time())
                   for a in order]
        res.jobs = list(records)
        for i, a in enumerate(order):
            heapq.heappush(heap, (a.t, _ARRIVE, seq, i))
            seq += 1
        if self.tick_interval_s and trace:
            heapq.heappush(heap, (self.tick_interval_s, _TICK, seq, None))
            seq += 1

        self.pending.clear()
        self.busy = False

        def handle(now, kind, payload):
            nonlocal seq
            if kind == _ARRIVE:
                self.pending.append(payload)
            elif kind == _FREE:
                self.busy = False
            else:  # _TICK — only while work remains (no retrain on a drained
                # cluster), and stop rescheduling once the trace is served
                if heap or self.pending or self.busy:
                    if self.on_tick is not None:
                        self.on_tick(now, self)
                    res.ticks += 1
                    heapq.heappush(heap, (now + self.tick_interval_s, _TICK,
                                          seq, None))
                    seq += 1

        while heap:
            now, kind, _, payload = heapq.heappop(heap)
            handle(now, kind, payload)
            # drain every coincident event before considering a dispatch:
            # same-instant arrivals (batch submissions, tied burst times)
            # must all reach the pending queue so one window sees them all
            while heap and heap[0][0] == now:
                _, kind2, _, payload2 = heapq.heappop(heap)
                handle(now, kind2, payload2)
            if self.busy or not self.pending:
                continue
            # dispatch the FCFS head window through the policy
            head = [self.pending.popleft()
                    for _ in range(min(self.window, len(self.pending)))]
            sched = self.policy.dispatch(
                [(order[i].binary, order[i].profile) for i in head])
            by_name: dict[str, deque] = defaultdict(deque)
            for i in head:
                by_name[order[i].profile.name].append(records[i])
            t0 = now
            for g, p in zip(sched.groups, sched.partitions):
                block = corun(g, p)
                for job, ft in zip(g, block.finish_times):
                    rec = by_name[job.name].popleft()
                    # dispatch = the group's actual start, not the block
                    # hand-off: jobs queued behind earlier groups of the same
                    # block are still *waiting*, and a policy that forms many
                    # sequential groups must not hide that queueing delay
                    rec.dispatch = t0
                    rec.finish = t0 + ft
                    rec.group_size = len(g)
                    rec.partition = p.label
                res.timeline.append(Segment(t0, t0 + block.makespan, len(g),
                                            p.label))
                t0 += block.makespan
            leftover = [n for n, d in by_name.items() if d]
            assert not leftover, f"policy dropped submissions: {leftover}"
            res.busy_time += t0 - now
            res.dispatches += 1
            self.busy = True
            heapq.heappush(heap, (t0, _FREE, seq, None))
            seq += 1
        return res
