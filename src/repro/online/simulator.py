"""Deterministic discrete-event cluster simulator (paper §IV-B, online phase).

Models a fleet of pods serving a stream of job submissions over
*simulated* time.  Three event kinds drive the clock, popped from a single
heap in ``(time, kind, seq)`` order; *all* events sharing a timestamp are
drained before any dispatch decision, so simultaneous events resolve
deterministically — coincident arrivals (batch submissions, tied burst
times) all reach their pending queues and can share one dispatch window,
and periodic ticks observe the repository state of the same instant:

    ARRIVE — a job submission is routed to a pod's FCFS pending queue,
    TICK   — a periodic simulated-time hook (the re-training loop's clock),
    FREE   — a dispatched group's slice-range claim expires.

Fleet topology and routing
--------------------------
:class:`SimConfig` fixes the fleet shape: ``pods`` is a tuple of per-pod
slice widths (heterogeneous 4/8-unit fleets are the interesting case; the
default ``(N_UNITS,)`` is the single-pod cluster of PRs 3–6, bit-compatible
with them).  At the instant a submission arrives, the configured
:class:`~repro.online.router.Router` (hash / least-loaded /
fragmentation-scored) assigns it a pod from an immutable
:class:`~repro.online.router.FleetView` snapshot; everything downstream —
FCFS windows, the first-sight protocol, slice-level first-fit, EASY
backfill — runs per pod, exactly the single-pod path.  Claims never span
pods, and a routed job never migrates.  Pod widths narrower than
``N_UNITS`` are modeled as a full-width occupancy map whose upper units
are permanently busy, so the placement arithmetic (buddy alignment,
reservation replay) is shared verbatim; the router's width eligibility
(a job requesting ``w`` units only routes to pods at least ``w`` wide)
keeps heterogeneous fleets deadlock-free, and a placement the per-pod
policy planned wider than the pod (e.g. an 8-unit MPS pair on a 4-unit
pod) is decomposed back into right-sized solo placements — counted in
``SimResult.refits``.

Slice-level occupancy (``mode="concurrent"``, the default)
----------------------------------------------------------
Each pod is an occupancy map over its slice units, not a scalar busy
flag.  Whenever slice units are idle and the pod's dispatched-group queue
is empty, the FCFS head of its pending queue (up to ``window``
submissions, as ``(binary, profile)`` pairs) is handed to the policy via
:meth:`~repro.online.policies.DispatchPolicy.decide`, which returns a
:class:`~repro.core.scheduler.DispatchDecision` carrying
:class:`~repro.core.scheduler.Placement`\\ s — co-run groups bound to
(possibly sub-pod, width-fitted) hierarchical partitions.  Each
placement's slices are then first-fitted onto disjoint aligned unit
ranges (:func:`~repro.core.partition.find_offsets`), so independent
groups run **concurrently** on disjoint slices; its FREE event is keyed
by the claimed slice ranges and releases exactly those units when the
group drains.

When the head group does not fit the current free units, it reserves its
earliest feasible start (computed by replaying the outstanding claims'
expiries — no new work is admitted past a blocked head, so the reservation
is exact) and a **backfill** scan lets later groups of the already-
dispatched queue start immediately *iff* they fit the idle units now and
their predicted makespan ends by the head's reserved start — EASY-style
backfill, so jumping the queue can never delay the head.

``mode="blocking"`` recovers the PR-3 whole-pod semantics bit-compatibly
(it requires a fleet of full-width pods): one window's groups execute
back to back on the full pod and the pod is released only when the whole
block drains.  On traces without sub-pod width hints the two modes
produce identical results (all placements are full-pod, so concurrency
never materializes) — the regression tests pin this equivalence.

Dispatch-time context
---------------------
Every window hand-off carries a :class:`~repro.core.env.DispatchContext`
snapshot of the serving pod at the dispatch instant: the live free-unit
mask (the very list placements are first-fitted against — a narrow pod
reports its missing upper units as busy), each head submission's age
since arrival, and the pending-queue depth left behind.  Policies are
free to ignore it (the heuristic baselines do); an RL policy whose
environment runs with ``EnvConfig.obs_context`` folds it into the
agent's observation, closing the loop that lets the policy *learn*
backfill-like behavior the dispatch layer otherwise supplies by hand —
see ``docs/observation.md`` for the exact feature layout and invariants.

Per-job completion times come from the phase-simulated
:func:`~repro.core.perfmodel.corun` under the fitted partition.  Every
dispatched group appends a :class:`Segment` (carrying its pod, claimed
slice ranges, and a backfill flag) to the occupancy timeline, and
:class:`SimResult` exposes fragmentation metrics on top of it: per-slice
busy time across the fleet-wide unit axis, slice-level utilization, and
the idle-slice-time fraction — packing quality, not just makespan — plus
the wait percentiles (p50/p99) that are the fleet-scale headline.

The simulator itself draws no randomness: given one trace (see
:mod:`repro.online.traces`) and one policy, two runs produce identical
:class:`SimResult`\\ s — determinism lives entirely in the trace seed and
the router seed.
"""
from __future__ import annotations

import heapq
import math
from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.env import DispatchContext
from repro.core.partition import N_UNITS, VALID_WIDTHS, find_offsets, solo_partition
from repro.core.perfmodel import CoRunResult, corun
from repro.core.profiles import JobProfile
from repro.core.scheduler import DispatchDecision, Placement, to_placements
from repro.online.router import FleetView, PodView, Router, make_router

_ARRIVE, _TICK, _FREE = 0, 1, 2          # same-time resolution order


@dataclass(frozen=True)
class SimConfig:
    """Frozen simulation configuration — the whole ``ClusterSimulator``
    parameter surface, including the fleet topology.

    ``pods`` is the tuple of per-pod slice widths (each a MIG-valid
    power-of-two; the widest must be ``N_UNITS`` so unhinted full-pod
    submissions always have an eligible pod).  ``router``/``router_seed``
    select the arrival router (:mod:`repro.online.router`) — irrelevant,
    but still recorded, for single-pod fleets.  ``mode="blocking"``
    (the PR-3 whole-pod dispatch) requires a uniform full-width fleet."""

    window: int = 8
    mode: str = "concurrent"
    backfill: bool = True
    tick_interval_s: float | None = None
    pods: tuple[int, ...] = (N_UNITS,)
    router: str = "hash"
    router_seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "pods", tuple(self.pods))
        assert self.window >= 1
        assert self.mode in ("concurrent", "blocking"), self.mode
        assert self.pods, "fleet needs at least one pod"
        for w in self.pods:
            assert w in VALID_WIDTHS, f"invalid pod width {w}"
        assert max(self.pods) == N_UNITS, \
            "widest pod must be full-width (unhinted jobs request N_UNITS)"
        if self.mode == "blocking":
            assert all(w == N_UNITS for w in self.pods), \
                "blocking mode models whole-pod dispatch: widths must be N_UNITS"

    @property
    def n_pods(self) -> int:
        return len(self.pods)

    @property
    def total_units(self) -> int:
        return sum(self.pods)


@dataclass(frozen=True)
class Arrival:
    """One submission: at time ``t`` the binary at ``binary`` is handed in.

    ``profile`` is the measurement the cluster *would* obtain by profiling
    the job during its first solo run — the policy only sees it through the
    repository protocol (first sight: solo + insert; afterwards: lookup).
    A ``meta["units"]`` hint on the profile (set by right-sized traces) is
    the slice width the submission requests from the placement layer —
    and the width the fleet router's eligibility rule keys on.
    """

    t: float
    binary: str
    profile: JobProfile


@dataclass
class Segment:
    """One group's occupancy: [t0, t1) under ``partition`` on pod ``pod``.

    ``slices`` holds the claimed ``(start, width)`` unit ranges in
    pod-local units (empty only for legacy construction); ``backfilled``
    marks groups that jumped a blocked head into idle units via the
    EASY-backfill scan."""

    t0: float
    t1: float
    jobs: int
    partition: str
    slices: tuple[tuple[int, int], ...] = ()
    backfilled: bool = False
    pod: int = 0

    @property
    def units(self) -> int:
        return sum(w for _, w in self.slices)


@dataclass
class JobRecord:
    """Per-submission lifecycle: arrival -> route -> dispatch -> finish.

    ``dispatch`` is the instant the job's *group* starts executing (a
    window's groups can start at different times under slice-level
    dispatch), so ``wait`` covers all queueing delay including queueing
    behind earlier groups of the same window.  ``units`` is the slice width
    the job actually ran on; ``pod`` the fleet pod the router assigned it;
    ``backfilled`` marks jobs whose group was started by the backfill
    scan.  ``idx`` is the job's index in sorted-trace order (the telemetry
    event stream's job key) and ``job_class`` its profile class — both
    feed the drift/time-series signals."""

    binary: str
    name: str
    arrival: float
    solo_time: float
    dispatch: float = math.nan
    finish: float = math.nan
    group_size: int = 0
    partition: str = ""
    units: int = N_UNITS
    backfilled: bool = False
    pod: int = 0
    idx: int = -1
    job_class: str = ""

    @property
    def wait(self) -> float:
        return self.dispatch - self.arrival

    @property
    def turnaround(self) -> float:
        return self.finish - self.arrival


@dataclass
class SimResult:
    """Fleet-level outcome of one (trace, policy) simulation.

    ``slice_busy_s`` spans the fleet-wide unit axis (pod 0's units first,
    then pod 1's, …); ``busy_time`` sums each pod's any-slice-busy span,
    so ``utilization`` is the mean over pods.  ``summary()`` carries
    ``schema: 2`` — consumers detect the fleet-era layout by it."""

    policy: str
    window: int
    jobs: list[JobRecord]
    mode: str = "concurrent"
    timeline: list[Segment] = field(default_factory=list)
    busy_time: float = 0.0
    dispatches: int = 0
    ticks: int = 0
    backfills: int = 0
    slice_busy_s: list[float] = field(default_factory=lambda: [0.0] * N_UNITS)
    pods: tuple[int, ...] = (N_UNITS,)
    router: str = "hash"
    refits: int = 0

    @property
    def n_pods(self) -> int:
        return len(self.pods)

    @property
    def total_units(self) -> int:
        return sum(self.pods)

    @property
    def pod_offsets(self) -> tuple[int, ...]:
        """Each pod's first index on the fleet-wide unit axis."""
        offs, acc = [], 0
        for w in self.pods:
            offs.append(acc)
            acc += w
        return tuple(offs)

    @property
    def makespan(self) -> float:
        """Time the last job drains (includes arrival-limited idle gaps)."""
        return max((j.finish for j in self.jobs), default=0.0)

    @property
    def total_solo_time(self) -> float:
        return sum(j.solo_time for j in self.jobs)

    @property
    def throughput(self) -> float:
        """Makespan-derived: solo work retired per unit of wall clock.

        Pure time sharing on a saturated single pod scores ~1.0 (idle gaps
        pull it below); co-scheduling pushes it above by retiring more than
        one job's solo work per pod-second, and an N-pod fleet serving a
        capacity-scaled trace approaches N."""
        m = self.makespan
        return self.total_solo_time / m if m > 0 else 0.0

    @property
    def utilization(self) -> float:
        """Mean over pods of the makespan fraction that pod was busy."""
        m = self.makespan
        return self.busy_time / (self.n_pods * m) if m > 0 else 0.0

    # ---- fragmentation metrics (slice-level packing quality) --------------

    @property
    def unit_busy_s(self) -> float:
        """Total claimed unit-seconds (Σ per-slice busy time)."""
        return float(sum(self.slice_busy_s))

    @property
    def slice_utilization(self) -> float:
        """Claimed unit-seconds / (total units x makespan): how much of the
        fleet's slice real estate the schedule actually occupied."""
        m = self.makespan
        return self.unit_busy_s / (self.total_units * m) if m > 0 else 0.0

    @property
    def idle_slice_frac(self) -> float:
        """Fraction of slice-time left idle over the makespan — the
        fragmentation cost slice-level dispatch + backfill drives down."""
        m = self.makespan
        return 1.0 - self.slice_utilization if m > 0 else 0.0

    @property
    def per_slice_utilization(self) -> list[float]:
        m = self.makespan
        return [b / m if m > 0 else 0.0 for b in self.slice_busy_s]

    def slice_timeline(self) -> list[list[tuple[float, float]]]:
        """Per-unit busy intervals on the fleet-wide axis, reconstructed
        from the segment timeline (claims release at group drain, so
        segment spans *are* the claims)."""
        out: list[list[tuple[float, float]]] = [[] for _ in range(self.total_units)]
        offs = self.pod_offsets
        for seg in self.timeline:
            base = offs[seg.pod]
            for start, width in seg.slices:
                for u in range(start, start + width):
                    out[base + u].append((seg.t0, seg.t1))
        for iv in out:
            iv.sort()
        return out

    @property
    def mean_wait(self) -> float:
        return float(np.mean([j.wait for j in self.jobs])) if self.jobs else 0.0

    @property
    def mean_turnaround(self) -> float:
        return float(np.mean([j.turnaround for j in self.jobs])) if self.jobs else 0.0

    @property
    def p50_wait(self) -> float:
        return (float(np.percentile([j.wait for j in self.jobs], 50))
                if self.jobs else 0.0)

    @property
    def p99_wait(self) -> float:
        """Tail wait — the fleet-scale headline metric (see ROADMAP)."""
        return (float(np.percentile([j.wait for j in self.jobs], 99))
                if self.jobs else 0.0)

    @property
    def p95_turnaround(self) -> float:
        return (float(np.percentile([j.turnaround for j in self.jobs], 95))
                if self.jobs else 0.0)

    def summary(self) -> dict:
        """JSON-able digest for BENCH_online.json (``schema: 2``: the
        fleet-era layout — adds ``n_pods``/``pods``/``router``/``refits``
        and redefines utilization as the per-pod mean)."""
        return {
            "schema": 2,
            "policy": self.policy,
            "mode": self.mode,
            "n_pods": self.n_pods,
            "pods": list(self.pods),
            "router": self.router,
            "jobs": len(self.jobs),
            "makespan_s": self.makespan,
            "busy_s": self.busy_time,
            "throughput": self.throughput,
            "utilization": self.utilization,
            "slice_utilization": self.slice_utilization,
            "idle_slice_frac": self.idle_slice_frac,
            "backfills": self.backfills,
            "refits": self.refits,
            "mean_wait_s": self.mean_wait,
            "p50_wait_s": self.p50_wait,
            "p99_wait_s": self.p99_wait,
            "mean_turnaround_s": self.mean_turnaround,
            "p95_turnaround_s": self.p95_turnaround,
            "dispatches": self.dispatches,
            "groups": len(self.timeline),
            "mean_group_size": (float(np.mean([s.jobs for s in self.timeline]))
                                if self.timeline else 0.0),
        }

    def timeseries(self, interval_s: float | None = None,
                   n_bins: int = 48) -> dict:
        """Windowed time-series over the makespan — the drift-signal view.

        Post-hoc from the job records and segment timeline (no telemetry
        recorder needed).  ``interval_s`` fixes the bin width (default:
        makespan / ``n_bins``).  Returns parallel lists, one entry per
        interval ``[t0[i], t0[i] + interval)``:

        * ``t0`` — interval start (s);
        * ``arrivals`` — submissions arriving in the interval;
        * ``queue_depth`` — time-mean count of jobs arrived but not yet
          dispatched;
        * ``occupancy`` — claimed unit-time fraction (1 −
          ``idle_slice_frac``);
        * ``idle_slice_frac`` — its complement, the per-interval trend
          :class:`~repro.online.telemetry.DriftMonitor` watches;
        * ``p50_wait_s`` / ``p99_wait_s`` — wait percentiles of jobs
          *dispatched* in the interval (0.0 when none);
        * ``backfill_rate`` — backfilled fraction of those dispatches;
        * ``class_entropy`` / ``width_entropy`` — Shannon entropy (bits)
          of the interval's arrival class / placed-width mix.
        """
        from repro.online.telemetry import entropy_bits
        m = self.makespan
        if m <= 0 or not self.jobs:
            return {k: [] for k in (
                "t0", "arrivals", "queue_depth", "occupancy",
                "idle_slice_frac", "p50_wait_s", "p99_wait_s",
                "backfill_rate", "class_entropy", "width_entropy")}
        if interval_s is None:
            interval_s = m / n_bins
        n = max(1, int(math.ceil(m / interval_s)))
        t0s = [i * interval_s for i in range(n)]
        arrivals = [0] * n
        qd = [0.0] * n
        occ = [0.0] * n
        waits: list[list[float]] = [[] for _ in range(n)]
        bf = [0] * n
        disp = [0] * n
        cls: list[dict] = [defaultdict(int) for _ in range(n)]
        wid: list[dict] = [defaultdict(int) for _ in range(n)]

        def overlap(a0, a1, b):
            return max(0.0, min(a1, t0s[b] + interval_s) - max(a0, t0s[b]))

        for j in self.jobs:
            b = min(int(j.arrival / interval_s), n - 1)
            arrivals[b] += 1
            cls[b][j.job_class or "?"] += 1
            wid[b][j.units] += 1
            if not math.isnan(j.dispatch):
                d = min(int(j.dispatch / interval_s), n - 1)
                waits[d].append(j.wait)
                disp[d] += 1
                bf[d] += int(j.backfilled)
                lo = int(j.arrival / interval_s)
                for b2 in range(lo, min(d, n - 1) + 1):
                    qd[b2] += overlap(j.arrival, j.dispatch, b2) / interval_s
        for seg in self.timeline:
            lo = int(seg.t0 / interval_s)
            hi = min(int(seg.t1 / interval_s), n - 1)
            for b2 in range(lo, hi + 1):
                occ[b2] += seg.units * overlap(seg.t0, seg.t1, b2)
        denom = self.total_units * interval_s
        occupancy = [min(o / denom, 1.0) for o in occ]
        return {
            "t0": t0s,
            "arrivals": arrivals,
            "queue_depth": qd,
            "occupancy": occupancy,
            "idle_slice_frac": [1.0 - o for o in occupancy],
            "p50_wait_s": [float(np.percentile(w, 50)) if w else 0.0
                           for w in waits],
            "p99_wait_s": [float(np.percentile(w, 99)) if w else 0.0
                           for w in waits],
            "backfill_rate": [b / d if d else 0.0 for b, d in zip(bf, disp)],
            "class_entropy": [entropy_bits(c) for c in cls],
            "width_entropy": [entropy_bits(w) for w in wid],
        }


@dataclass
class _Run:
    """A dispatched group awaiting (or holding) slice units on its pod."""

    group: list[JobProfile]
    partition: object                    # Partition (possibly width-fitted)
    recs: list[JobRecord]
    pred: CoRunResult                    # exact times under `partition`
    window_id: int = 0                   # dispatch window this group came from


class _Pod:
    """One pod's mutable serving state (everything the single-pod
    simulator used to keep on ``self``).  A pod narrower than ``N_UNITS``
    is a full-width occupancy map whose upper units start — and stay —
    busy, so the shared placement arithmetic needs no width parameter."""

    __slots__ = ("idx", "width", "offset", "pending", "ready", "busy",
                 "free", "claims", "cid", "n_busy_units", "busy_t0")

    def __init__(self, idx: int, width: int, offset: int):
        self.idx = idx
        self.width = width
        self.offset = offset             # first index on the fleet unit axis
        self.pending: deque = deque()
        self.ready: deque[_Run] = deque()
        self.busy = False                # blocking-mode pod flag
        self.free = [u < width for u in range(N_UNITS)]
        self.claims: dict[int, tuple[tuple[tuple[int, int], ...], float]] = {}
        self.cid = 0
        self.n_busy_units = 0
        self.busy_t0 = 0.0


class ClusterSimulator:
    """Event-driven fleet: routed FCFS admission windows dispatched by a
    policy, one occupancy map per pod.

    Configuration lives in a frozen :class:`SimConfig` (pass ``config=``;
    the historical keyword arguments remain as a legacy construction path
    and simply populate one).  ``mode="concurrent"`` (default) places each
    dispatched group onto disjoint slice-unit ranges so independent groups
    run side by side; ``backfill=True`` additionally lets later groups of
    a pod's dispatched queue jump a blocked head into idle units when
    their predicted finish cannot delay the head's reserved start.
    ``mode="blocking"`` is the PR-3 whole-pod block dispatch, kept
    bit-compatible for regression.  Fleets longer than one pod route each
    arrival through ``config.router`` at its arrival instant.

    ``on_tick(now, sim)`` fires every ``tick_interval_s`` of simulated time
    while work remains — the MISO-style re-training loop hangs off it (see
    :mod:`repro.online.retrain`); ticks stop as soon as the heap, pending
    queues, and pods are all drained, so simulations always terminate.

    ``telemetry`` (a :class:`~repro.online.telemetry.Telemetry` bundle)
    turns on lifecycle tracing + streaming metrics: every event emits a
    structured record with pod/slice/claim attribution and updates the
    metrics registry (``docs/observability.md``).  ``None`` (the default)
    is the no-op path — one ``is not None`` test per event, results
    bit-identical either way (telemetry observes, never steers).
    """

    def __init__(self, policy, config: SimConfig | None = None, *,
                 window: int = 8, tick_interval_s: float | None = None,
                 on_tick=None, mode: str = "concurrent",
                 backfill: bool = True, pods: tuple[int, ...] | None = None,
                 router: str = "hash", router_seed: int = 0,
                 telemetry=None):
        if config is None:
            config = SimConfig(
                window=window, mode=mode, backfill=backfill,
                tick_interval_s=tick_interval_s,
                pods=tuple(pods) if pods is not None else (N_UNITS,),
                router=router, router_seed=router_seed)
        self.config = config
        self.policy = policy
        self.on_tick = on_tick
        self.telemetry = telemetry
        self._live_res: SimResult | None = None
        self._live_order: list[Arrival] = []
        # legacy attribute mirrors (config is the source of truth)
        self.window = config.window
        self.tick_interval_s = config.tick_interval_s
        self.mode = config.mode
        self.backfill = config.backfill
        self._router: Router = make_router(config.router, config.router_seed)
        self._pods: list[_Pod] = []
        self._reset_pods()

    def _reset_pods(self) -> None:
        self._pods = []
        off = 0
        for i, w in enumerate(self.config.pods):
            self._pods.append(_Pod(i, w, off))
            off += w

    # ------------------------------------------------------------------ run

    def run(self, trace: list[Arrival]) -> SimResult:
        cfg = self.config
        res = SimResult(policy=getattr(self.policy, "name", "policy"),
                        window=cfg.window, jobs=[], mode=cfg.mode,
                        slice_busy_s=[0.0] * cfg.total_units,
                        pods=cfg.pods, router=cfg.router)
        heap: list[tuple[float, int, int, object]] = []
        seq = 0
        # heap/pending carry the sorted-trace *index*, not the Arrival:
        # traces may legitimately reuse one Arrival object (batch
        # submissions), and identity-keyed records would alias
        order = sorted(trace, key=lambda a: a.t)
        records = [JobRecord(binary=a.binary, name=a.profile.name,
                             arrival=a.t, solo_time=a.profile.solo_time(),
                             idx=i, job_class=a.profile.job_class)
                   for i, a in enumerate(order)]
        res.jobs = list(records)
        # live references: tick callbacks (drift-triggered retraining) read
        # the in-progress result/trace through live_result/live_arrivals
        self._live_res, self._live_order = res, order

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, kind, seq, payload))
            seq += 1

        for i, a in enumerate(order):
            push(a.t, _ARRIVE, i)
        if cfg.tick_interval_s and trace:
            push(cfg.tick_interval_s, _TICK, None)

        self._reset_pods()
        n_pods = cfg.n_pods

        def work_left():
            return any(p.pending or p.ready or p.busy or p.claims
                       for p in self._pods)

        tel = self.telemetry

        def handle(now, kind, payload):
            if kind == _ARRIVE:
                i = payload
                pidx = (0 if n_pods == 1
                        else self._router.route(order[i],
                                                self._fleet_view(now, order)))
                records[i].pod = pidx
                self._pods[pidx].pending.append(i)
                if tel is not None:
                    # job_class re-derives the perf model on every access
                    # — reuse the value already computed into the record
                    rec = records[i]
                    tel.on_arrive(now, pidx, i, rec.name, rec.job_class,
                                  order[i].profile.requested_units)
            elif kind == _FREE:
                pidx, cid = payload
                pod = self._pods[pidx]
                if cfg.mode == "blocking":
                    pod.busy = False
                else:
                    self._release(now, pod, cid, res)
                if tel is not None:
                    tel.on_free(now, pidx, cid)
            else:  # _TICK — only while work remains (no retrain on a drained
                # cluster), and stop rescheduling once the trace is served
                if heap or work_left():
                    if self.on_tick is not None:
                        self.on_tick(now, self)
                    res.ticks += 1
                    if tel is not None:
                        tel.on_tick(now)
                    push(now + cfg.tick_interval_s, _TICK, None)

        prev_t = 0.0
        qd = bu = 0
        qd_int = bu_int = 0.0
        pods = self._pods
        pod0 = pods[0] if len(pods) == 1 else None   # single-pod fast path
        blocking = cfg.mode == "blocking"
        while heap:
            now, kind, _, payload = heapq.heappop(heap)
            if tel is not None and now > prev_t:
                # event-gap integrals: depth/busy were constant since
                # prev_t.  Accumulated in locals and flushed once after
                # the loop — a per-pop hook call is measurable against
                # the telemetry_overhead gate
                dt = now - prev_t
                if pod0 is not None:
                    qd = len(pod0.pending)
                    bu = (pod0.width if pod0.busy else 0) if blocking \
                        else pod0.n_busy_units
                else:
                    qd = bu = 0
                    for p in pods:
                        qd += len(p.pending)
                        bu += (p.width if p.busy else 0) if blocking \
                            else p.n_busy_units
                qd_int += qd * dt
                bu_int += bu * dt
                prev_t = now
            handle(now, kind, payload)
            # drain every coincident event before considering a dispatch:
            # same-instant arrivals (batch submissions, tied burst times)
            # must all reach the pending queues so one window sees them all
            while heap and heap[0][0] == now:
                _, kind2, _, payload2 = heapq.heappop(heap)
                handle(now, kind2, payload2)
            for pod in self._pods:
                if cfg.mode == "blocking":
                    self._dispatch_blocking(now, pod, res, order, records,
                                            push)
                else:
                    self._service(now, pod, res, order, records, push)
        if tel is not None:
            tel.on_clock_totals(qd_int, bu_int, qd, bu)
        for pod in self._pods:
            assert not pod.claims and not pod.ready, "undrained claims/groups"
        return res

    # ------------------------------------------------------ live snapshots

    @property
    def live_result(self) -> SimResult | None:
        """The in-progress :class:`SimResult` of the current ``run()`` —
        tick callbacks (drift monitoring) read occupancy through it."""
        return self._live_res

    def live_arrivals(self, t0: float, t1: float) -> list[Arrival]:
        """Arrivals with ``t0 < t <= t1`` of the trace being served —
        the drift monitor's per-window class/width sample."""
        return [a for a in self._live_order if t0 < a.t <= t1]

    def live_idle_frac(self) -> float:
        """Instantaneous fraction of fleet units unclaimed — the drift
        monitor's occupancy signal at tick time."""
        if self.config.mode == "blocking":
            busy = sum(p.width if p.busy else 0 for p in self._pods)
        else:
            busy = sum(p.n_busy_units for p in self._pods)
        return 1.0 - busy / self.config.total_units

    # --------------------------------------------------------- fleet view

    def _fleet_view(self, now, order) -> FleetView:
        """Immutable routing snapshot: every pod's width, pod-local free
        mask, queue depths, and claimed/queued units at the arrival
        instant — the router's whole world."""
        views = []
        for p in self._pods:
            if self.config.mode == "blocking":
                free = tuple([not p.busy] * p.width)
                busy_units = p.width if p.busy else 0
            else:
                free = tuple(p.free[:p.width])
                busy_units = p.n_busy_units
            queue_units = sum(r.partition.total_units for r in p.ready)
            queue_units += sum(
                min(order[i].profile.requested_units, p.width)
                for i in p.pending)
            views.append(PodView(idx=p.idx, width=p.width, free=free,
                                 pending=len(p.pending), ready=len(p.ready),
                                 queue_units=queue_units,
                                 busy_units=busy_units))
        return FleetView(pods=tuple(views), now_s=now)

    # ------------------------------------------------ policy entry point

    def _decide(self, subs, ctx) -> DispatchDecision:
        """One call site for the policy: the unified ``decide`` API, with
        a duck-typing adapter for external policies that still only
        implement the legacy ``placements``/``dispatch`` surface."""
        pol = self.policy
        if hasattr(pol, "decide"):
            return pol.decide(subs, context=ctx)
        if hasattr(pol, "placements"):
            return DispatchDecision(
                schedule=None,
                placements=tuple(pol.placements(subs, context=ctx)))
        sched = pol.dispatch(subs, context=ctx)
        return DispatchDecision(schedule=sched,
                                placements=tuple(to_placements(sched)))

    # ------------------------------------------------- blocking (PR-3) mode

    def _dispatch_blocking(self, now, pod: _Pod, res, order, records,
                           push) -> None:
        """Whole-pod block dispatch — the PR-3 event model, verbatim (the
        dispatch context reports the idle full pod, which it is whenever a
        blocking dispatch fires)."""
        if pod.busy or not pod.pending:
            return
        head = [pod.pending.popleft()
                for _ in range(min(self.window, len(pod.pending)))]
        decision = self._decide(
            [(order[i].binary, order[i].profile) for i in head],
            self._dispatch_context(now, pod, head, order,
                                   free=(True,) * N_UNITS))
        sched = decision.schedule
        assert sched is not None, \
            "blocking mode needs a schedule-producing policy"
        by_name: dict[str, deque] = defaultdict(deque)
        for i in head:
            by_name[order[i].profile.name].append(records[i])
        tel = self.telemetry
        if tel is not None:
            tel.on_window(now, pod.idx, head, len(pod.pending))
        t0 = now
        for g, p in zip(sched.groups, sched.partitions):
            block = corun(g, p)
            grecs = []
            for job, ft in zip(g, block.finish_times):
                rec = by_name[job.name].popleft()
                # dispatch = the group's actual start, not the block
                # hand-off: jobs queued behind earlier groups of the same
                # block are still *waiting*, and a policy that forms many
                # sequential groups must not hide that queueing delay
                rec.dispatch = t0
                rec.finish = t0 + ft
                rec.group_size = len(g)
                rec.partition = p.label
                grecs.append(rec)
            res.timeline.append(Segment(t0, t0 + block.makespan, len(g),
                                        p.label, slices=((0, N_UNITS),),
                                        pod=pod.idx))
            for u in range(N_UNITS):
                res.slice_busy_s[pod.offset + u] += block.makespan
            if tel is not None:
                tel.on_place(t0, pod.idx, grecs, ((0, N_UNITS),),
                             t0 + block.makespan, None, p.label, False)
            t0 += block.makespan
        leftover = [n for n, d in by_name.items() if d]
        assert not leftover, f"policy dropped submissions: {leftover}"
        res.busy_time += t0 - now
        res.dispatches += 1
        pod.busy = True
        push(t0, _FREE, (pod.idx, None))

    # --------------------------------------------- concurrent (slice) mode

    def _service(self, now, pod: _Pod, res, order, records, push) -> None:
        """Place one pod's dispatched groups onto its free slice units.

        Non-backfilled groups start strictly in dispatch order; a new
        window is formed once the dispatched queue has drained (FCFS across
        windows).  With backfill enabled, a *blocked* head additionally
        admits one lookahead window while idle units exist, so small later
        arrivals become backfill candidates — on full-pod-only traces no
        units are ever free while the head is blocked, which is what keeps
        this mode bit-compatible with blocking dispatch there."""
        while True:
            progress = False
            # FCFS: place the head while it fits
            while pod.ready:
                starts = find_offsets(pod.ready[0].partition, pod.free)
                if starts is None:
                    break
                self._place(now, pod, pod.ready.popleft(), starts, res, push)
                progress = True
            if pod.ready:
                if self.backfill:
                    # bounded EASY lookahead: at most one window past the
                    # blocked head's own window may be admitted early
                    if (pod.pending and any(pod.free)
                            and pod.ready[-1].window_id == pod.ready[0].window_id):
                        self._form_window(now, pod, res, order, records)
                        progress = True
                    if len(pod.ready) > 1:
                        progress |= self._backfill_scan(now, pod, res, push)
            elif pod.pending and any(pod.free):
                self._form_window(now, pod, res, order, records)
                progress = True
            if not progress:
                return

    def _dispatch_context(self, now, pod: _Pod, head, order,
                          free=None) -> DispatchContext:
        """Pod-state snapshot handed to the policy with each window: the
        live free-unit mask (the same list ``find_offsets`` places
        against — a narrow pod's missing upper units read busy), each head
        submission's age since arrival, and the depth of the pod's pending
        queue left behind — the arrival-aware observation an
        ``obs_context`` agent folds into its state."""
        return DispatchContext(
            free_units=tuple(pod.free) if free is None else free,
            ages_s=tuple(now - order[i].t for i in head),
            queue_depth=len(pod.pending),
            now_s=now)

    def _fit_to_pod(self, pl: Placement, pod: _Pod, res,
                    now: float = 0.0) -> list[Placement]:
        """Pod-width guard: a placement planned wider than the pod (the
        per-pod policy plans against the full partition table — e.g. an
        8-unit MPS pair routed onto a 4-unit pod) can never first-fit, so
        decompose it into right-sized solo placements.  Buddy packing of
        power-of-two slices totaling <= width always fits an empty pod,
        so ``total_units <= width`` is exact.  Router eligibility keeps
        each individual job's request within the pod, making the
        decomposition always placeable; ``SimResult.refits`` counts
        decompositions."""
        if pl.partition.total_units <= pod.width:
            return [pl]
        res.refits += 1
        if self.telemetry is not None:
            self.telemetry.on_refit(now, pod.idx, pl.partition.label,
                                    len(pl.group))
        return [Placement([j], solo_partition(min(j.requested_units,
                                                  pod.width)))
                for j in pl.group]

    def _form_window(self, now, pod: _Pod, res, order, records) -> None:
        head = [pod.pending.popleft()
                for _ in range(min(self.window, len(pod.pending)))]
        subs = [(order[i].binary, order[i].profile) for i in head]
        ctx = self._dispatch_context(now, pod, head, order)
        decision = self._decide(subs, ctx)
        by_name: dict[str, deque] = defaultdict(deque)
        for i in head:
            by_name[order[i].profile.name].append(records[i])
        for pl in decision.placements:
            for fitted in self._fit_to_pod(pl, pod, res, now):
                recs = [by_name[j.name].popleft() for j in fitted.group]
                pod.ready.append(_Run(fitted.group, fitted.partition, recs,
                                      corun(fitted.group, fitted.partition),
                                      window_id=res.dispatches))
        leftover = [n for n, d in by_name.items() if d]
        assert not leftover, f"policy dropped submissions: {leftover}"
        res.dispatches += 1
        if self.telemetry is not None:
            self.telemetry.on_window(now, pod.idx, head, len(pod.pending))

    def _backfill_scan(self, now, pod: _Pod, res, push) -> bool:
        """EASY backfill: later dispatched groups may start now iff they fit
        the idle units and predictably finish by the blocked head's reserved
        start.  Backfilled claims give their units back before the head's
        reservation, so the head can never be delayed."""
        t_res = self._earliest_fit(pod, pod.ready[0].partition)
        placed = False
        for run in list(pod.ready)[1:]:
            starts = find_offsets(run.partition, pod.free)
            if starts is None:
                continue
            if now + run.pred.makespan <= t_res + 1e-9:
                pod.ready.remove(run)
                self._place(now, pod, run, starts, res, push, backfilled=True)
                res.backfills += 1
                placed = True
        return placed

    def _earliest_fit(self, pod: _Pod, partition) -> float:
        """Earliest time `partition` fits the pod, replaying outstanding
        claim expiries (exact: no new non-backfill work is admitted past a
        blocked head, and backfill claims expire before this time)."""
        expiries = sorted({t1 for _, t1 in pod.claims.values()})
        free = list(pod.free)
        for t in expiries:
            for ranges, t1 in pod.claims.values():
                if t1 <= t:
                    for start, width in ranges:
                        free[start:start + width] = [True] * width
            if find_offsets(partition, free) is not None:
                return t
        return expiries[-1] if expiries else 0.0

    def _place(self, now, pod: _Pod, run: _Run, starts, res, push,
               backfilled: bool = False) -> None:
        ranges = tuple((st, s.units)
                       for st, s in zip(starts, run.partition.slices))
        width = 0
        for st, w in ranges:
            pod.free[st:st + w] = [False] * w
            width += w
        if pod.n_busy_units == 0:
            pod.busy_t0 = now
        pod.n_busy_units += width
        t1 = now + run.pred.makespan
        for rec, ft, (si, s, _b) in zip(run.recs, run.pred.finish_times,
                                        run.partition.slots):
            rec.dispatch = now
            rec.finish = now + ft
            rec.group_size = len(run.group)
            rec.partition = run.partition.label
            rec.units = s.units
            rec.backfilled = backfilled
        res.timeline.append(Segment(now, t1, len(run.group),
                                    run.partition.label, slices=ranges,
                                    backfilled=backfilled, pod=pod.idx))
        for st, w in ranges:
            for u in range(st, st + w):
                res.slice_busy_s[pod.offset + u] += run.pred.makespan
        cid = pod.cid
        pod.cid += 1
        pod.claims[cid] = (ranges, t1)
        push(t1, _FREE, (pod.idx, cid))
        if self.telemetry is not None:
            self.telemetry.on_place(now, pod.idx, run.recs, ranges, t1, cid,
                                    run.partition.label, backfilled)

    def _release(self, now, pod: _Pod, cid, res) -> None:
        ranges, _t1 = pod.claims.pop(cid)
        for st, w in ranges:
            pod.free[st:st + w] = [True] * w
            pod.n_busy_units -= w
        if pod.n_busy_units == 0:
            res.busy_time += now - pod.busy_t0
