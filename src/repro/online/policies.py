"""Dispatch policies for the online cluster simulator.

Every policy speaks the paper's §IV-B online protocol: submissions arrive
as ``(binary, fresh_profile)`` pairs; a binary the repository has never
seen runs **solo** on the full pod (being profiled as it runs) and its
profile enters the repository, while previously-profiled jobs are
co-scheduled by the policy's planner.  All policies therefore pay the same
first-sight profiling cost — comparisons across policies on one trace are
apples to apples.

Every dispatch additionally receives the simulator's
:class:`~repro.core.env.DispatchContext` — the free-unit occupancy mask,
per-submission queueing ages, and pending-queue depth at the dispatch
instant.  The base protocol accepts it uniformly so the simulator can pass
it unconditionally; only the RL policy consumes it (an ``obs_context``
agent folds it into its observation — the arrival-aware state of
``docs/observation.md``), while the heuristic baselines plan from profiles
alone, exactly as before.

    RLDispatchPolicy      — the trained agent via
                            ``RLScheduler.schedule_submissions`` (constraint
                            guard included); ``hot_swap`` lets the periodic
                            re-training loop replace the agent mid-trace.
    TimeSharingPolicy     — everything solo on the full pod (the 1.0
                            baseline the paper normalizes against).
    GreedyPackerPolicy    — first-fit complementary packing: anchor the
                            longest-waiting job, greedily add the partner
                            whose best partition minimizes the co-run/solo
                            ratio, stop when adding stops helping.
    StaticPartitionPolicy — the exhaustive static baselines of
                            :mod:`repro.core.baselines` (``mig_only``,
                            ``mps_only``, ``mig_mps_default``, ``oracle``)
                            applied per dispatch window.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

from repro.core.baselines import POLICIES, _best_for_group, time_sharing
from repro.core.env import EnvConfig
from repro.core.partition import enumerate_partitions, solo_partition
from repro.core.perfmodel import solo_run_time
from repro.core.problem import Schedule
from repro.core.profiles import JobProfile, ProfileRepository
from repro.core.scheduler import (
    DispatchDecision, Placement, RLScheduler, submission_protocol,
    to_placements,
)


@dataclass
class PolicyStats:
    unprofiled_jobs: int = 0
    planned_jobs: int = 0


class DispatchPolicy:
    """Repository protocol + a planner hook (:meth:`plan`) for subclasses.

    :meth:`decide` is the **single dispatch entry point**: it runs the
    shared :func:`~repro.core.scheduler.submission_protocol` (first sight:
    solo + insert; afterwards: plan) with this policy's planner — so every
    policy pays the identical first-sight profiling cost the RL scheduler
    does — and returns one
    :class:`~repro.core.scheduler.DispatchDecision` carrying the planned
    schedule, the width-fitted placements the slice-level simulator
    consumes, and this window's first-sight/planned counts.  The
    historical ``dispatch()`` / ``placements()`` methods survive as thin
    deprecation shims over the same protocol; subclasses that still
    override them (the pre-decide extension points) are honored —
    :meth:`decide` detects the override and routes through it.

    ``plan_window`` caps how many profiled jobs reach one :meth:`plan` call
    (chunked like the RL window); ``None`` plans the whole batch at once.
    """

    name = "base"

    def __init__(self, repository: ProfileRepository | None = None,
                 plan_window: int | None = None):
        # `is not None`: an empty repository is falsy (len 0) but still the
        # caller's shared store — never replace it
        self.repository = repository if repository is not None else ProfileRepository()
        self.plan_window = plan_window
        self.stats = PolicyStats()
        self._last_schedule: Schedule | None = None

    # ------------------------------------------------ the one entry point

    def decide(self, submissions: list[tuple[str, JobProfile | None]],
               context=None) -> DispatchDecision:
        """Plan one dispatch window.  ``context`` (a
        :class:`~repro.core.env.DispatchContext`) is accepted by every
        policy so the simulator can pass its snapshot unconditionally;
        the base planner contract ``plan(queue)`` is context-blind, so it
        is *not* forwarded — the RL delegate consumes it."""
        before = (self.stats.unprofiled_jobs, self.stats.planned_jobs)
        cls = type(self)
        if cls.dispatch is not DispatchPolicy.dispatch:
            # legacy subclass extension point: honor the override (its
            # super() chain lands back in the shim below)
            sched = self.dispatch(submissions, context=context)
            pls = to_placements(sched)
        elif cls.placements is not DispatchPolicy.placements:
            self._last_schedule = None
            pls = self.placements(submissions, context=context)
            sched = self._last_schedule
        else:
            sched = self._plan_schedule(submissions, context=context)
            pls = to_placements(sched)
        return DispatchDecision(
            schedule=sched, placements=tuple(pls),
            first_sight=self.stats.unprofiled_jobs - before[0],
            planned=self.stats.planned_jobs - before[1])

    def _plan_schedule(self, submissions, context=None) -> Schedule:
        """The shared protocol body (the RL policy swaps in its delegate)."""
        def on_unprofiled(path, fresh):
            self.stats.unprofiled_jobs += 1

        def on_window(chunk):
            self.stats.planned_jobs += len(chunk)

        return submission_protocol(self.repository, submissions, self.plan,
                                   window=self.plan_window,
                                   on_unprofiled=on_unprofiled,
                                   on_window=on_window)

    # ------------------------------------------------- deprecation shims

    def dispatch(self, submissions: list[tuple[str, JobProfile | None]],
                 context=None) -> Schedule:
        """Deprecated: ``decide(...).schedule`` replaces this."""
        warnings.warn(
            "DispatchPolicy.dispatch() is deprecated; use "
            "decide(submissions, context).schedule",
            DeprecationWarning, stacklevel=2)
        sched = self._plan_schedule(submissions, context=context)
        self._last_schedule = sched
        return sched

    def placements(self, submissions: list[tuple[str, JobProfile | None]],
                   context=None) -> list[Placement]:
        """Deprecated: ``decide(...).placements`` replaces this."""
        warnings.warn(
            "DispatchPolicy.placements() is deprecated; use "
            "decide(submissions, context).placements",
            DeprecationWarning, stacklevel=2)
        return to_placements(self.dispatch(submissions, context=context))

    def plan(self, queue: list[JobProfile]) -> Schedule:
        raise NotImplementedError


class TimeSharingPolicy(DispatchPolicy):
    name = "time_sharing"

    def plan(self, queue):
        return time_sharing(queue)


class GreedyPackerPolicy(DispatchPolicy):
    """Greedy complementary packing under the constraint-1 guard.

    Groups only form while the best partition's co-run time stays *below*
    the group's summed solo time, so — like the RL scheduler's fallback —
    no dispatch is ever worse than time sharing.  ``max_perms`` caps the
    slot-ordering sweep (this is an explicitly approximate policy).
    """

    name = "greedy_packer"

    def __init__(self, repository=None, c_max: int = 4, max_group: int = 2,
                 max_perms: int | None = 4):
        super().__init__(repository)
        self.max_group = min(max_group, c_max)
        self.max_perms = max_perms
        self.partitions = enumerate_partitions(c_max)

    def plan(self, queue):
        remaining = list(queue)
        sched = Schedule()
        solo = solo_partition()
        while remaining:
            group = [remaining.pop(0)]
            chosen = None                     # (partition, perm) of the group
            while len(group) < self.max_group and remaining:
                best = None
                for cand in remaining:
                    trial = group + [cand]
                    t, p, perm = _best_for_group(trial, self.partitions,
                                                 self.max_perms)
                    if p is None:
                        continue
                    ratio = t / solo_run_time(trial)
                    if ratio < 1.0 and (best is None or ratio < best[0]):
                        best = (ratio, cand, p, perm)
                if best is None:
                    break
                group.append(best[1])
                remaining.remove(best[1])
                chosen = (best[2], best[3])
            if chosen is None:
                sched.add(group, solo)
            else:
                p, perm = chosen
                sched.add([group[i] for i in perm], p)
        return sched


class StaticPartitionPolicy(DispatchPolicy):
    """Per-window exhaustive baseline (``mig_only`` / ``mps_only`` /
    ``mig_mps_default`` / ``oracle``) from :mod:`repro.core.baselines`."""

    def __init__(self, baseline: str = "mig_mps_default", repository=None,
                 c_max: int = 4):
        super().__init__(repository)
        assert baseline in POLICIES, baseline
        self.name = baseline
        self._fn = POLICIES[baseline]
        self.c_max = c_max

    def plan(self, queue):
        return self._fn(queue, self.c_max)


class RLDispatchPolicy(DispatchPolicy):
    """The trained agent, online: delegates the whole protocol (including
    first-sight solo runs and the constraint guard) to
    :meth:`RLScheduler.schedule_submissions`; ``hot_swap`` installs freshly
    re-trained agents between dispatches.  The only context-aware policy:
    the dispatch snapshot flows into the agent's observation when its env
    runs with ``obs_context`` (and is harmlessly ignored otherwise)."""

    name = "rl"

    def __init__(self, agent, env_cfg: EnvConfig | None = None,
                 repository: ProfileRepository | None = None):
        super().__init__(repository)
        self.scheduler = RLScheduler(agent, env_cfg, self.repository)

    def _plan_schedule(self, submissions, context=None):
        # keep PolicyStats live even though the protocol is delegated:
        # cross-policy analyses read .stats uniformly.  Derived from the
        # scheduler's own counter delta so there is exactly one protocol
        # implementation to stay in sync with.
        before = self.scheduler.stats.unprofiled_jobs
        sched = self.scheduler.schedule_submissions(submissions,
                                                    context=context)
        fresh = self.scheduler.stats.unprofiled_jobs - before
        self.stats.unprofiled_jobs += fresh
        self.stats.planned_jobs += len(submissions) - fresh
        return sched

    def plan(self, queue):
        return self.scheduler.schedule(queue)

    def hot_swap(self, agent) -> None:
        self.scheduler.agent = agent

    @property
    def agent(self):
        return self.scheduler.agent
