"""In-graph vectorized cluster simulator: the event heap as pytree arrays.

The Python :class:`~repro.online.simulator.ClusterSimulator` is a per-event
Python loop — exact, but one trace at a time.  This module applies the same
transformation PR 1 applied to the training loop (scalar Python loop ->
donated ``lax.scan``/``while_loop`` over fixed-shape pytree state) to the
*simulator* itself, so a whole batch of traces runs in one device call
under ``vmap`` (and across host devices under ``pmap`` — the
``--xla_force_host_platform_device_count`` idiom gives cheap CPU
parallelism in CI).

Event-table layout (the heap, flattened)
----------------------------------------
The heap's three event kinds become bounded array lanes with active masks;
"pop the heap" becomes an argmin:

* **ARRIVE** — the trace itself *is* the event table: arrival times are a
  sorted ``(capacity,)`` lane and two cursors replace the FCFS pending
  deque (``pend_lo``..``pend_hi`` index the admitted-but-undispatched
  span).  The next arrival event is ``t[pend_hi]``.
* **FREE** — outstanding slice claims live in ``N_UNITS`` fixed slots
  (each claim holds >= 1 of the 8 units, so 8 slots can never overflow):
  expiry time, claimed-unit mask, active flag.  The next free event is the
  masked min over expiries.
* **TICK** — not represented: re-training is a host-side callback, so the
  heap engine remains the only path with ``on_tick`` (documented below).

One event step takes ``now = min(next arrival, next expiry)``, drains
*every* event with ``t <= now`` (the heap's coincident-event drain), then
runs the same service fixpoint the Python ``_service`` loop runs: place
the FCFS head while it first-fits, admit one bounded lookahead window past
a blocked head, EASY-backfill later groups that provably finish before the
head's earliest feasible start (replayed claim expiries, in-graph).  The
per-unit occupancy map is an ``(N_UNITS,)`` mask and first-fit
aligned-buddy placement is a masked scan over the 8 candidate offsets.
A full trace is one ``lax.while_loop`` (each step retires >= 1 event, so
``2 * capacity + 4`` bounds it); ``vmap`` over a leading trace axis
evaluates hundreds of scenarios per call.

Scope and the plan seam
-----------------------
The engine executes two plan families through one dispatch machinery:

* **Solo-placement plans** (:class:`~repro.online.policies.\
TimeSharingPolicy` / ``policy=None``): every submission becomes its own
  single-slice group at its ``requested_units`` width, through the same
  first-sight protocol the heap runs (unprofiled binaries are scheduled
  ahead of the planned remainder of their window, and enter the in-graph
  profiled bitmap).  Group durations are *precomputed* per (job, width)
  by the float64 reference model (:func:`~repro.core.perfmodel_jax.\
solo_duration_table`, bit-equal to the heap's per-group ``corun``
  predictions for solo placements), so the two engines make identical
  discrete decisions and differ only by float32 rounding of the clock.
* **RL grouped plans** (:class:`~repro.online.policies.\
RLDispatchPolicy`): the agent's greedy co-scheduling episode runs
  in-graph at the same window-formation seam (``_build_run_rl``'s
  ``form_and_plan`` — the single place a plan is materialized into group
  slots).  The popped chunk is assembled into the ``CoScheduleEnv``
  observation layout (profile rows + status flags, plus the live
  ``ObsContext`` block under ``EnvConfig.obs_context``), scored by
  :func:`~repro.core.network.greedy_q_action` with the env's validity
  mask, and the closed groups pass through the heap's §IV-A fallback
  guard, pod-width refit, and dedicated-slice shrink before dispatching
  on the shared predicated place/backfill path.  Params are a
  closed-over pytree argument: ``hot_swap`` never recompiles, and
  ``sweep(param_sets=...)`` vmaps a population of agents.  Solo entries
  (first-sight and single-member groups) keep the exact f64 duration
  table; only true co-run groups carry the f32 in-graph model's
  clock-level drift.

Parity guarantee
----------------
For any concurrent-mode trace, :class:`VectorizedClusterSimulator` and the
Python heap produce matching :class:`~repro.online.simulator.SimResult`
job records: **identical decisions** (placement order, groups,
partitions, slice ranges, units, backfill flags, fallback/refit
outcomes, window/dispatch counts) and times equal up to float32
resolution of the clock (the heap is the float64 reference, exactly as
``train_agent_scalar`` is for the training engine).  Record attribution
for duplicate-tenant windows follows the heap's name-keyed FIFO.
``tests/test_vecsim.py`` pins the time-sharing side on randomized
traces; ``tests/test_parity_fuzz.py`` fuzzes the RL side (single-pod and
fleet) on shared ``tests/strategies.py`` generators.  Context-aware
agents (``obs_context=True``) see an f32 context block in-graph vs the
heap's f64 snapshot, so a near-tie action can legitimately flip;
profile-only agents are parity-exact at the decision level.

Capacity limits raise eagerly: a trace longer than ``capacity`` raises
``ValueError`` before the device call, and the engine carries an error
lane (ready-ring / event-step overflow) that the wrapper turns into
``RuntimeError`` — never silent truncation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import greedy_q_action
from repro.core.partition import (
    N_UNITS, Partition, Slice, enumerate_partitions, slice_label,
    solo_partition,
)
from repro.core.perfmodel import corun
from repro.core.perfmodel_jax import (
    UNIT_SIZES, JobTermsTable, QueueArrays, build_partition_table,
    group_metrics, job_terms_table, solo_duration_table,
)
from repro.online.policies import RLDispatchPolicy, TimeSharingPolicy
from repro.online.router import FleetView, PodView, make_router
from repro.online.simulator import (
    Arrival, JobRecord, Segment, SimConfig, SimResult,
)
from repro.online.telemetry import WAIT_BUCKETS_S

_WAIT_EDGES = jnp.asarray(np.array(WAIT_BUCKETS_S, np.float32))

_INF = jnp.float32(jnp.inf)
_BIG_SEQ = jnp.int32(2**30)
_UNIT_IDX = jnp.arange(N_UNITS, dtype=jnp.int32)

# constant aligned-buddy fit tensors, indexed by width-index into
# UNIT_SIZES: _COVERED[u, s, :] = units a width-u slice at offset s spans;
# _ALIGNED[u, s] = offset s is buddy-aligned and in range.  Precomputing
# these keeps the per-iteration fit query a gather + reduce instead of
# rebuilding an 8x8 mask from a traced width.
_COVERED = jnp.asarray(np.stack([
    (np.arange(N_UNITS)[None, :] >= np.arange(N_UNITS)[:, None])
    & (np.arange(N_UNITS)[None, :] < np.arange(N_UNITS)[:, None] + w)
    for w in UNIT_SIZES]))                    # (U, 8, 8) bool
_ALIGNED = jnp.asarray(np.stack([
    (np.arange(N_UNITS) % w == 0) & (np.arange(N_UNITS) + w <= N_UNITS)
    for w in UNIT_SIZES]))                    # (U, 8) bool

# error lanes (bitwise-OR'd): the wrapper raises RuntimeError on any
ERR_READY_OVERFLOW = 1          # ready ring out of slots (cannot happen at
                                # R = 2*window + 2; kept as an eager guard)
ERR_EVENT_OVERFLOW = 2          # while_loop exceeded 2*capacity+4 events
ERR_EPISODE = 4                 # RL co-schedule episode failed to terminate
                                # (cannot happen: 2*W steps bound any
                                # masked-greedy episode; eager guard)


class TraceArrays(NamedTuple):
    """One compiled trace: sorted arrival lanes, padded to ``capacity``."""

    t: jnp.ndarray               # (A,) f32 — sorted arrival times
    job: jnp.ndarray             # (A,) i32 — row into the job table
    n: jnp.ndarray               # ()   i32 — live arrivals (rest padding)


class JobTable(NamedTuple):
    """Distinct-job lanes shared by every trace of a sweep."""

    width: jnp.ndarray           # (J,) i32 — requested slice width (units)
    widx: jnp.ndarray            # (J,) i32 — index into UNIT_SIZES
    dur: jnp.ndarray             # (J,) f32 — solo makespan at that width
                                 #           (float64 corun, cast once)
    solo8: jnp.ndarray           # (J,) f32 — full-pod solo time (throughput)


class _State(NamedTuple):
    """The whole simulation as fixed-shape lanes (A = capacity, R = ring)."""

    now: jnp.ndarray             # () f32
    pend_lo: jnp.ndarray         # () i32 — first undispatched admitted arrival
    pend_hi: jnp.ndarray         # () i32 — first un-admitted arrival
    profiled: jnp.ndarray        # (J,) bool — repository bitmap (first sight)
    free: jnp.ndarray            # (N_UNITS,) bool — idle slice units
    # ready ring: dispatched groups waiting for units (FCFS by seq)
    r_active: jnp.ndarray        # (R,) bool
    r_seq: jnp.ndarray           # (R,) i32 — global FCFS order
    r_win: jnp.ndarray           # (R,) i32 — dispatch window id
    r_grp: jnp.ndarray           # (R,) i32 — row into the group log
    next_seq: jnp.ndarray        # () i32
    # claim table: outstanding FREE events
    c_active: jnp.ndarray        # (N_UNITS,) bool
    c_t1: jnp.ndarray            # (N_UNITS,) f32 — expiry
    c_mask: jnp.ndarray          # (N_UNITS, N_UNITS) bool — claimed units
    # busy-span accounting (union over units, like the heap)
    n_busy: jnp.ndarray          # () i32
    busy_t0: jnp.ndarray         # () f32
    busy_time: jnp.ndarray       # () f32
    slice_busy: jnp.ndarray      # (N_UNITS,) f32
    # counters
    dispatches: jnp.ndarray      # () i32
    backfills: jnp.ndarray       # () i32
    n_groups: jnp.ndarray        # () i32
    place_seq: jnp.ndarray       # () i32 — placement order (timeline)
    steps: jnp.ndarray           # () i32 — event steps retired
    err: jnp.ndarray             # () i32 — ERR_* lanes
    # group log (one row per dispatched solo group; <= A rows).  Kept to
    # the minimum the host cannot rederive — width/duration live in the
    # job table via g_job, and placement seq/start/backfill pack into one
    # int lane — because every lane here is a (batch, A) while-loop carry.
    g_arr: jnp.ndarray           # (A,) i32 — arrival index (A = unused)
    g_job: jnp.ndarray           # (A,) i32 — row into the job table
    g_t0: jnp.ndarray            # (A,) f32 — placement time
    g_pack: jnp.ndarray          # (A,) i32 — (pseq << 4)|(start << 1)|bf


class MetricsState(NamedTuple):
    """In-graph streaming metrics, accumulated inside the ``while_loop``
    carry when the engine is built with ``telemetry=True`` — the pytree
    mirror of the heap :class:`~repro.online.telemetry.Telemetry`
    aggregates (same fixed ``WAIT_BUCKETS_S`` histogram layout, same
    event-gap integrals), so vmapped sweeps return per-lane metric
    tensors with zero extra device syncs."""

    wait_hist: jnp.ndarray       # (len(WAIT_BUCKETS_S)+1,) i32 counts
    wait_sum: jnp.ndarray        # () f32 — Σ wait at placement
    queue_depth_int: jnp.ndarray  # () f32 — ∫ pending-depth dt
    busy_unit_int: jnp.ndarray   # () f32 — ∫ claimed-units dt
    places: jnp.ndarray          # () i32 — groups placed


def _metrics_init() -> MetricsState:
    return MetricsState(
        wait_hist=jnp.zeros(len(WAIT_BUCKETS_S) + 1, jnp.int32),
        wait_sum=jnp.float32(0.0), queue_depth_int=jnp.float32(0.0),
        busy_unit_int=jnp.float32(0.0), places=jnp.int32(0))


class SweepSummary(NamedTuple):
    """Per-trace metrics of a vmapped sweep (leading batch axis)."""

    makespan: jnp.ndarray
    throughput: jnp.ndarray
    mean_wait: jnp.ndarray
    p50_wait: jnp.ndarray
    p99_wait: jnp.ndarray
    mean_turnaround: jnp.ndarray
    p95_turnaround: jnp.ndarray
    utilization: jnp.ndarray
    slice_utilization: jnp.ndarray
    backfills: jnp.ndarray
    dispatches: jnp.ndarray
    err: jnp.ndarray


# --------------------------------------------------------------- primitives

def _fit_table(free):
    """Per-width first-fit table on ``free``: ``(U, N_UNITS)`` bool.

    The masked mirror of :func:`~repro.core.partition.find_offsets` for a
    single slice (solo plans place exactly one): candidate starts are the
    8 unit offsets, valid iff buddy-aligned (``start % width == 0``) and
    every covered unit is idle.  Row ``u`` answers every fit query for
    width ``UNIT_SIZES[u]`` this iteration; first-fit = argmax.
    """
    return _ALIGNED & jnp.all(free[None, None, :] | ~_COVERED, axis=2)


def _claim_units(start, width):
    return (_UNIT_IDX >= start) & (_UNIT_IDX < start + width)


def _head(st: _State):
    """FCFS head of the ready ring: min seq among active slots."""
    seqs = jnp.where(st.r_active, st.r_seq, _BIG_SEQ)
    return jnp.argmin(seqs).astype(jnp.int32), jnp.any(st.r_active)


def _percentile(x, valid, q):
    """Masked ``np.percentile(x[valid], q)`` (linear interpolation)."""
    n = jnp.sum(valid)
    s = jnp.sort(jnp.where(valid, x, _INF))
    pos = jnp.float32(q / 100.0) * jnp.maximum(n - 1, 0).astype(jnp.float32)
    lo = jnp.floor(pos).astype(jnp.int32)
    hi = jnp.minimum(lo + 1, jnp.maximum(n - 1, 0))
    frac = pos - lo.astype(jnp.float32)
    lo = jnp.clip(lo, 0, x.shape[0] - 1)
    hi = jnp.clip(hi, 0, x.shape[0] - 1)
    out = s[lo] * (1.0 - frac) + s[hi] * frac
    return jnp.where(n > 0, out, jnp.float32(0.0))


# ------------------------------------------------------------ state updates
#
# Every update below is *predicated* on a ``do`` flag instead of wrapped in
# ``lax.cond``: under ``vmap`` a batched cond lowers to a select that runs
# BOTH branches for the whole batch, so masked single-path updates (scatter
# to an out-of-bounds row with ``mode="drop"`` when ``do`` is False) are
# what keep the lockstep body small.

def _place(st: _State, jobs: JobTable, slot, start, backfilled, do) -> _State:
    """Claim the first-fit range for ready slot ``slot`` (heap ``_place``),
    iff ``do``."""
    g = st.r_grp[slot]
    j = st.g_job[g]
    w = jobs.width[j]
    dur = jobs.dur[j]
    mask = _claim_units(start, w) & do
    doi = jnp.where(do, jnp.int32(1), jnp.int32(0))
    A = st.g_arr.shape[0]
    gt = jnp.where(do, g, A)                 # drop target when masked off
    ct = jnp.where(do, jnp.argmin(st.c_active).astype(jnp.int32), N_UNITS)
    rt = jnp.where(do, slot, st.r_active.shape[0])
    pack = ((st.place_seq << 4) | (start << 1)
            | jnp.where(backfilled, jnp.int32(1), jnp.int32(0)))
    return st._replace(
        free=st.free & ~mask,
        busy_t0=jnp.where(do & (st.n_busy == 0), st.now, st.busy_t0),
        n_busy=st.n_busy + doi * w,
        c_active=st.c_active.at[ct].set(True, mode="drop"),
        c_t1=st.c_t1.at[ct].set(st.now + dur, mode="drop"),
        c_mask=st.c_mask.at[ct].set(mask, mode="drop"),
        slice_busy=st.slice_busy + jnp.where(mask, dur, 0.0),
        g_t0=st.g_t0.at[gt].set(st.now, mode="drop"),
        g_pack=st.g_pack.at[gt].set(pack, mode="drop"),
        place_seq=st.place_seq + doi,
        r_active=st.r_active.at[rt].set(False, mode="drop"),
        backfills=st.backfills + jnp.where(do & backfilled, jnp.int32(1),
                                           jnp.int32(0)),
    )


def _earliest_fit(st: _State, widx):
    """Earliest time a width-``UNIT_SIZES[widx]`` slice fits, replaying
    claim expiries — the in-graph mirror of the heap's ``_earliest_fit``
    reservation.  Candidate times are the claim expiries themselves;
    "first fit" is the min over fitting candidates, so no sort is needed
    (availability at time t depends only on which claims expired by t)."""
    # freed[i] = unit availability once every claim expiring by c_t1[i]
    # has released; fits[i] = the head width first-fits there
    rel = (st.c_active[None, :] & st.c_active[:, None]
           & (st.c_t1[None, :] <= st.c_t1[:, None]))
    freed = st.free[None, :] | jnp.any(rel[:, :, None] & st.c_mask[None],
                                       axis=1)
    fits = st.c_active & jnp.any(
        _ALIGNED[widx][None, :]
        & jnp.all(freed[:, None, :] | ~_COVERED[widx][None], axis=2), axis=1)
    first = jnp.min(jnp.where(fits, st.c_t1, _INF))
    last = jnp.max(jnp.where(st.c_active, st.c_t1, -_INF))
    return jnp.where(jnp.any(fits), first,
                     jnp.where(jnp.any(st.c_active), last, jnp.float32(0.0)))


def _make_form_window(trace: TraceArrays, jobs: JobTable, window: int):
    """Build the window-formation step (the plan seam): pop <= ``window``
    pending submissions, run the first-sight protocol over the profiled
    bitmap, and materialize the solo plan — first-sight groups ahead of
    the planned remainder, both in submission order, exactly the schedule
    order ``submission_protocol`` + ``to_placements`` produce."""

    def form_window(st: _State, do) -> _State:
        A = trace.t.shape[0]
        J = st.profiled.shape[0]
        k = jnp.where(do, jnp.minimum(jnp.int32(window),
                                      st.pend_hi - st.pend_lo), jnp.int32(0))
        i_w = jnp.arange(window, dtype=jnp.int32)
        on = i_w < k
        arr = jnp.clip(st.pend_lo + i_w, 0, A - 1)
        jrow = trace.job[arr]

        # first-sight marking, loop-free: a submission profiles iff its
        # binary is new to the repository AND it is the first occurrence
        # inside this window (duplicates see their predecessor's insert)
        earlier_same = ((jrow[None, :] == jrow[:, None])
                        & (i_w[None, :] < i_w[:, None]) & on[None, :])
        fs = on & ~jnp.any(earlier_same, axis=1) & ~st.profiled[jrow]
        profiled = st.profiled.at[jnp.where(on, jrow, J)].set(
            True, mode="drop")

        # placement order: first-sight solos first, then the planned
        # remainder — each in submission order (stable two-pass ranks)
        n_fs = jnp.sum(fs, dtype=jnp.int32)
        rank_fs = jnp.cumsum(fs, dtype=jnp.int32) - 1
        rank_pl = jnp.cumsum(~fs & on, dtype=jnp.int32) - 1
        pos = jnp.where(fs, rank_fs, n_fs + rank_pl)

        # group log rows n_groups .. n_groups+k-1, ordered by `pos`
        grow = jnp.where(on, st.n_groups + pos, A)

        # append k ready slots in group order: group q claims the q-th
        # inactive ring slot in index order (seq follows placement order)
        free_rank = jnp.cumsum(~st.r_active, dtype=jnp.int32) - 1
        q = jnp.where(~st.r_active & (free_rank < k), free_rank,
                      jnp.int32(-1))
        sel = q >= 0
        err = st.err | jnp.where(
            jnp.sum(~st.r_active, dtype=jnp.int32) < k,
            jnp.int32(ERR_READY_OVERFLOW), jnp.int32(0))

        return st._replace(
            profiled=profiled,
            g_arr=st.g_arr.at[grow].set(arr, mode="drop"),
            g_job=st.g_job.at[grow].set(jrow, mode="drop"),
            r_active=st.r_active | sel,
            r_seq=jnp.where(sel, st.next_seq + q, st.r_seq),
            r_win=jnp.where(sel, st.dispatches, st.r_win),
            r_grp=jnp.where(sel, st.n_groups + q, st.r_grp),
            err=err, next_seq=st.next_seq + k, n_groups=st.n_groups + k,
            pend_lo=st.pend_lo + k,
            dispatches=st.dispatches + jnp.where(do, jnp.int32(1),
                                                 jnp.int32(0)))

    return form_window


# -------------------------------------------------------------- trace runs

def _build_run(window: int, backfill: bool, capacity: int,
               telemetry: bool = False):
    """The jitted single-trace engine: ONE flat ``lax.while_loop``.

    Each iteration performs exactly one micro-action of the heap's
    event/service interleaving — place the FCFS head if it fits, else
    (blocked head) admit the bounded EASY lookahead window, place the
    lowest-seq eligible backfill candidate, form a window onto an idle
    pod, or (no service progress) advance the clock to the next event and
    drain everything coincident with it.  Flat-with-masked-updates is the
    shape ``vmap`` wants: a batched nested ``while_loop`` runs every level
    to the slowest lane's trip count (multiplicative lockstep), while a
    single loop pays only the max of per-lane totals.

    One-candidate-per-iteration backfill is *exactly* the heap's
    multi-placement scan: a claim added by a backfill placement expires by
    ``t_res`` and occupies units that were free when the scan started, so
    replaying expiries after it yields the same ``t_res``, and a candidate
    skipped for lack of space stays unplaceable once ``free`` shrinks —
    re-scanning from the lowest seq is the same sequence of placements.

    ``telemetry=True`` threads a :class:`MetricsState` alongside the
    engine state (``run`` then returns ``(state, metrics)``): the wait
    histogram fills at each placement, the queue-depth/busy-unit
    integrals advance at each clock step — all predicated updates on the
    existing flags, so the ``_State`` trajectory is **bit-identical**
    with the flag on or off, and with the flag off (the default) the
    compiled program is the exact pre-telemetry engine.
    """
    max_steps = 2 * capacity + 4

    def run(trace: TraceArrays, jobs: JobTable,
            width=jnp.int32(N_UNITS)):
        # `width` is the pod's slice width (traced, so a fleet can vmap a
        # pod axis over it): a narrower pod is the same engine with the
        # upper units born busy — they are never claimed, never freed, and
        # every fit query sees them occupied, mirroring the heap's _Pod.
        form_window = _make_form_window(trace, jobs, window)
        A = capacity
        R = 2 * window + 2
        J = jobs.width.shape[0]
        f32, i32 = jnp.float32, jnp.int32
        st = _State(
            now=f32(0.0), pend_lo=i32(0), pend_hi=i32(0),
            profiled=jnp.zeros(J, dtype=bool),
            free=_UNIT_IDX < width,
            r_active=jnp.zeros(R, dtype=bool),
            r_seq=jnp.zeros(R, i32), r_win=jnp.zeros(R, i32),
            r_grp=jnp.zeros(R, i32), next_seq=i32(0),
            c_active=jnp.zeros(N_UNITS, dtype=bool),
            c_t1=jnp.zeros(N_UNITS, f32),
            c_mask=jnp.zeros((N_UNITS, N_UNITS), dtype=bool),
            n_busy=i32(0), busy_t0=f32(0.0), busy_time=f32(0.0),
            slice_busy=jnp.zeros(N_UNITS, f32),
            dispatches=i32(0), backfills=i32(0), n_groups=i32(0),
            place_seq=i32(0), steps=i32(0), err=i32(0),
            g_arr=jnp.full(A, A, i32), g_job=jnp.zeros(A, i32),
            g_t0=jnp.zeros(A, f32), g_pack=jnp.zeros(A, i32),
        )

        def live(st: _State):
            return ((st.pend_hi < trace.n) | jnp.any(st.c_active)
                    | (st.pend_lo < st.pend_hi) | jnp.any(st.r_active))

        def body(carry):
            if telemetry:
                st, ms = carry
            else:
                st, ms = carry, None
            # The four service rules are mutually exclusive by their gates
            # (rule 1 needs a fitting head; 2-3 a blocked head; 4 no head),
            # so one merged form_window and one merged _place execute
            # whichever rule fired — halving the per-iteration scatter
            # count vs. one call per rule.
            # --- rule 1: place the FCFS head if it first-fits
            head, head_exists = _head(st)
            hwidx = jobs.widx[st.g_job[st.r_grp[head]]]
            ftab = _fit_table(st.free)
            fh = ftab[hwidx]
            start = jnp.argmax(fh).astype(jnp.int32)
            place_head = head_exists & jnp.any(fh)
            blocked = head_exists & ~place_head
            pending = st.pend_hi > st.pend_lo
            anyfree = jnp.any(st.free)
            # rule 4 — the heap's `elif`: idle pod, no ready head
            can_form = ~head_exists & pending & anyfree
            slot, sstart, do_bf = head, start, jnp.bool_(False)
            if backfill:
                # rule 2 — bounded EASY lookahead: a blocked head admits at
                # most one window past its own (all ready share its window)
                max_win = jnp.max(jnp.where(st.r_active, st.r_win,
                                            jnp.int32(-1)))
                can_look = (blocked & pending & anyfree
                            & (max_win == st.r_win[head]))
            else:
                can_look = jnp.bool_(False)
            st = form_window(st, can_look | can_form)
            if backfill:
                # rule 3 — EASY backfill: lowest-seq non-head candidate
                # that fits now and drains by the head's reserved start
                # (free is untouched on the blocked path, so `ftab` holds)
                can_scan = blocked & (jnp.sum(st.r_active,
                                              dtype=jnp.int32) > 1)
                t_res = _earliest_fit(st, hwidx)
                jr = st.g_job[st.r_grp]
                fr = ftab[jobs.widx[jr]]                  # (R, N_UNITS)
                starts = jnp.argmax(fr, axis=1).astype(jnp.int32)
                oks = jnp.any(fr, axis=1)
                durs = jobs.dur[jr]
                elig = (st.r_active & oks
                        & (jnp.arange(R, dtype=jnp.int32) != head)
                        & (st.now + durs <= t_res + 1e-9) & can_scan)
                cand = jnp.argmin(jnp.where(elig, st.r_seq,
                                            _BIG_SEQ)).astype(jnp.int32)
                do_bf = can_scan & jnp.any(elig)
                slot = jnp.where(place_head, head, cand)
                sstart = jnp.where(place_head, start, starts[cand])
            do_place = place_head | do_bf
            if telemetry:
                # wait histogram at placement: the placed group's arrival
                # index lives in the (post-form_window) group log
                arr = jnp.clip(st.g_arr[st.r_grp[slot]], 0, A - 1)
                wait = st.now - trace.t[arr]
                b = jnp.searchsorted(_WAIT_EDGES, wait,
                                     side="left").astype(jnp.int32)
                nb = ms.wait_hist.shape[0]
                ms = ms._replace(
                    wait_hist=ms.wait_hist.at[
                        jnp.where(do_place, b, nb)].add(1, mode="drop"),
                    wait_sum=ms.wait_sum + jnp.where(do_place, wait, 0.0),
                    places=ms.places + jnp.where(do_place, jnp.int32(1),
                                                 jnp.int32(0)))
            st = _place(st, jobs, slot, sstart, do_bf, do_place)
            progress = place_head | can_look | do_bf | can_form

            # --- no service progress: advance the clock one event batch
            adv = ~progress
            t_arr = jnp.where(st.pend_hi < trace.n,
                              trace.t[jnp.clip(st.pend_hi, 0, A - 1)], _INF)
            t_free = jnp.min(jnp.where(st.c_active, st.c_t1, _INF))
            now = jnp.where(adv, jnp.minimum(t_arr, t_free), st.now)
            # drain every coincident event: admit all arrivals with t<=now.
            # The trace is sorted and everything <= the old clock is already
            # admitted, so the new cursor is just the count of t <= now
            # (padding lanes are +inf and never admit).
            pend_hi = jnp.where(
                adv, jnp.sum(trace.t <= now, dtype=jnp.int32), st.pend_hi)
            # ... and release every claim with t1 <= now
            rel = adv & st.c_active & (st.c_t1 <= now)
            freed = jnp.any(rel[:, None] & st.c_mask, axis=0)
            w_rel = jnp.sum(jnp.where(rel[:, None], st.c_mask, False),
                            dtype=jnp.int32)
            n_busy = st.n_busy - w_rel
            busy_time = st.busy_time + jnp.where(
                (n_busy == 0) & (w_rel > 0), now - st.busy_t0, 0.0)
            steps = st.steps + jnp.where(adv, jnp.int32(1), jnp.int32(0))
            if telemetry:
                # event-gap integrals: depth/busy constant over [st.now, now)
                dt = now - st.now
                ms = ms._replace(
                    queue_depth_int=ms.queue_depth_int
                    + (st.pend_hi - st.pend_lo).astype(jnp.float32) * dt,
                    busy_unit_int=ms.busy_unit_int
                    + st.n_busy.astype(jnp.float32) * dt)
            st = st._replace(
                now=now, pend_hi=pend_hi, free=st.free | freed,
                c_active=st.c_active & ~rel, n_busy=n_busy,
                busy_time=busy_time, steps=steps,
                err=st.err | jnp.where(steps > max_steps,
                                       jnp.int32(ERR_EVENT_OVERFLOW),
                                       jnp.int32(0)))
            return (st, ms) if telemetry else st

        if telemetry:
            return jax.lax.while_loop(
                lambda c: live(c[0]) & (c[0].err == 0), body,
                (st, _metrics_init()))
        return jax.lax.while_loop(lambda s: live(s) & (s.err == 0), body, st)

    return run


def _records(st: _State, trace: TraceArrays, jobs: JobTable):
    """Per-arrival dispatch/finish lanes scattered from the group log."""
    A = trace.t.shape[0]
    dur = jobs.dur[st.g_job]                  # junk on unused rows; dropped
    dispatch = jnp.zeros(A, jnp.float32).at[st.g_arr].set(
        st.g_t0, mode="drop")
    finish = jnp.zeros(A, jnp.float32).at[st.g_arr].set(
        st.g_t0 + dur, mode="drop")
    return dispatch, finish


def _summarize(st, trace: TraceArrays, dispatch, finish,
               solo8) -> SweepSummary:
    """Shared summary tail over per-arrival dispatch/finish lanes — ``st``
    is either engine's state (both carry the busy/backfill/err lanes)."""
    A = trace.t.shape[0]
    valid = jnp.arange(A) < trace.n
    wait = dispatch - trace.t
    turnaround = finish - trace.t
    makespan = jnp.max(jnp.where(valid, finish, 0.0))
    solo = jnp.sum(jnp.where(valid, solo8, 0.0))
    nz = makespan > 0
    n = jnp.maximum(jnp.sum(valid), 1)
    return SweepSummary(
        makespan=makespan,
        throughput=jnp.where(nz, solo / makespan, 0.0),
        mean_wait=jnp.sum(jnp.where(valid, wait, 0.0)) / n,
        p50_wait=_percentile(wait, valid, 50.0),
        p99_wait=_percentile(wait, valid, 99.0),
        mean_turnaround=jnp.sum(jnp.where(valid, turnaround, 0.0)) / n,
        p95_turnaround=_percentile(turnaround, valid, 95.0),
        utilization=jnp.where(nz, st.busy_time / makespan, 0.0),
        slice_utilization=jnp.where(
            nz, jnp.sum(st.slice_busy) / (N_UNITS * makespan), 0.0),
        backfills=st.backfills,
        dispatches=st.dispatches,
        err=st.err,
    )


def _summary(st: _State, trace: TraceArrays, jobs: JobTable) -> SweepSummary:
    dispatch, finish = _records(st, trace, jobs)
    return _summarize(st, trace, dispatch, finish, jobs.solo8[trace.job])


# ------------------------------------------------------------ host wrapper

def metrics_dict(ms: MetricsState) -> dict:
    """Host-side dict of one (or a pod-summed) :class:`MetricsState` —
    keyed like the heap registry (``docs/observability.md``) so parity
    tests and exporters read both engines uniformly."""
    counts = np.asarray(ms.wait_hist)
    return {
        "wait_s": {"edges": list(WAIT_BUCKETS_S),
                   "counts": counts.tolist(),
                   "sum": float(ms.wait_sum),
                   "count": int(counts.sum())},
        "queue_depth_integral_s": float(ms.queue_depth_int),
        "busy_unit_s": float(ms.busy_unit_int),
        "groups_placed": int(ms.places),
    }


def compile_trace(trace: list[Arrival], capacity: int,
                  names: dict[str, int] | None = None,
                  jobs: list | None = None) -> tuple[TraceArrays, list]:
    """Sort + pad one trace into :class:`TraceArrays`.

    ``names``/``jobs`` accumulate the distinct-job table across traces of a
    sweep (keyed by profile name, 1:1 with the repository's binary key), so
    a whole batch shares one :class:`JobTable`.  Returns the sorted
    arrival list alongside (the wrapper builds ``JobRecord``\\ s from it).
    """
    if len(trace) > capacity:
        raise ValueError(
            f"trace has {len(trace)} arrivals > capacity {capacity}; "
            f"the event table is fixed-size — raise `capacity`")
    order = sorted(trace, key=lambda a: a.t)
    names = {} if names is None else names
    jobs = [] if jobs is None else jobs
    rows = []
    for a in order:
        r = names.setdefault(a.profile.name, len(names))
        if r == len(jobs):
            jobs.append(a.profile)
        rows.append(r)
    t = np.full(capacity, np.inf, np.float32)
    t[:len(order)] = [a.t for a in order]
    job = np.zeros(capacity, np.int32)
    job[:len(rows)] = rows
    return TraceArrays(t=jnp.asarray(t), job=jnp.asarray(job),
                       n=jnp.int32(len(order))), order


def build_job_table(jobs: list) -> JobTable:
    """Float64 per-job solo durations at the requested width, cast once —
    the heap's per-group ``corun`` predictions for solo placements."""
    table = solo_duration_table(jobs)                 # (J, U) float64
    width = np.array([j.requested_units for j in jobs], np.int32)
    widx = np.searchsorted(np.asarray(UNIT_SIZES), width).astype(np.int32)
    dur = table[np.arange(len(jobs)), widx]
    solo8 = np.array([j.solo_time() for j in jobs], np.float64)
    return JobTable(width=jnp.asarray(width), widx=jnp.asarray(widx),
                    dur=jnp.asarray(dur, jnp.float32),
                    solo8=jnp.asarray(solo8, jnp.float32))


def _emit_lane(st: _State, jt: JobTable, records: list[JobRecord],
               pod: int = 0) -> list[Segment]:
    """Scatter one engine lane's group log into its (sorted-subtrace-
    indexed) ``JobRecord``\\ s and return the lane's :class:`Segment`\\ s
    in placement order — the reconstruction shared by the single-pod and
    fleet wrappers."""
    g_n = int(st.n_groups)
    g_arr = np.asarray(st.g_arr)[:g_n]
    g_t0 = np.asarray(st.g_t0)[:g_n]
    g_job = np.asarray(st.g_job)[:g_n]
    g_dur = np.asarray(jt.dur)[g_job]
    g_w = np.asarray(jt.width)[g_job]
    pack = np.asarray(st.g_pack)[:g_n]
    g_pseq, g_start, g_bf = pack >> 4, (pack >> 1) & 7, (pack & 1) == 1
    labels = {w: solo_partition(int(w)).label for w in set(g_w.tolist())}
    for g in range(g_n):
        rec = records[int(g_arr[g])]
        rec.dispatch = float(g_t0[g])
        rec.finish = float(g_t0[g] + g_dur[g])
        rec.group_size = 1
        rec.partition = labels[int(g_w[g])]
        rec.units = int(g_w[g])
        rec.backfilled = bool(g_bf[g])
        rec.pod = pod
    return [Segment(t0=float(g_t0[g]), t1=float(g_t0[g] + g_dur[g]), jobs=1,
                    partition=labels[int(g_w[g])],
                    slices=((int(g_start[g]), int(g_w[g])),),
                    backfilled=bool(g_bf[g]), pod=pod)
            for g in np.argsort(g_pseq)]


# ------------------------------------------------------------- RL serving
#
# The in-graph policy seam: the same engine skeleton, but the plan chosen
# at window formation comes from the DQN's greedy co-schedule episode
# (CoScheduleEnv, run as a lax.scan of masked dqn_apply forward passes)
# instead of the static solo plan.  The flat while_loop splits in two —
# an *inner* service/clock loop (cheap, every event) and an *outer*
# window loop whose body runs the episode (expensive, ~n/window times):
# under vmap a frozen lane skips neither, so hoisting the network out of
# the per-event loop is what makes batched RL serving fast.

class RLJobTable(NamedTuple):
    """Distinct-job lanes for the RL engine (row ``J`` = padding).

    The job list is padded to a power-of-two row count (repeating job 0)
    before the table is built, so sweeps over many randomized traces
    retrace the jitted engine at most ``log2`` times.
    """

    widx: jnp.ndarray            # (J+1,) i32 — requested width index
    dur_wu: jnp.ndarray          # (J+1, U) f32 — solo makespan per width
                                 #            (float64 corun, cast once)
    solo8: jnp.ndarray           # (J+1,) f32 — full-pod solo time
    terms: JobTermsTable         # (J+1, ...) — roofline terms + features


def build_rl_job_table(jobs: list) -> RLJobTable:
    J = max(8, 1 << max(0, len(jobs) - 1).bit_length())
    padded = list(jobs) + [jobs[0]] * (J - len(jobs))
    tab = solo_duration_table(padded)                 # (J, U) float64
    width = np.array([j.requested_units for j in padded], np.int32)
    widx = np.searchsorted(np.asarray(UNIT_SIZES), width).astype(np.int32)
    U = len(UNIT_SIZES)
    return RLJobTable(
        widx=jnp.asarray(np.concatenate([widx, [U - 1]]).astype(np.int32)),
        dur_wu=jnp.asarray(np.concatenate([tab, np.zeros((1, U))]),
                           jnp.float32),
        solo8=jnp.asarray(
            np.concatenate([[j.solo_time() for j in padded], [0.0]]),
            jnp.float32),
        terms=job_terms_table(padded))


class TrainRollout(NamedTuple):
    """Per-trace training logs emitted by the ``train=True`` RL engine.

    The window-formation seam is the decision surface: row ``w`` of the
    ``(A, T_EP, ...)`` lanes holds window ``w``'s episode — the exact
    observations the agent saw, the (ε-greedy) actions it took, the env
    validity masks, and a per-step ``valid`` flag (False once the episode
    is done or the window never formed).  ``w_wait`` / ``w_turn`` are the
    queueing outcome attributed back to the deciding window: at every
    placement the placed entry's member waits (``now - arrival``) and
    turnarounds (``now + finish_offset - arrival``) are scatter-added into
    the bucket of the window that *formed* the entry (``r_win``), so
    summing the buckets reproduces the serving engine's per-record
    wait/turnaround totals exactly (f32) — the invariant
    ``tests/test_queueing_reward.py`` fuzzes against the heap.
    """

    obs: jnp.ndarray             # (A, T_EP, D) f32 — episode observations
    act: jnp.ndarray             # (A, T_EP) i32 — actions taken
    mask: jnp.ndarray            # (A, T_EP, W+P) bool — validity masks
    valid: jnp.ndarray           # (A, T_EP) bool — real decision steps
    w_wait: jnp.ndarray          # (A,) f32 — Σ member waits per window
    w_turn: jnp.ndarray          # (A,) f32 — Σ member turnarounds per window


class _RLState(NamedTuple):
    """RL-engine lanes: the TS state plus the grouped-entry log.

    Entries (one ready-queue unit = one heap ``Placement``) carry up to
    ``C = c_max`` members; solo entries use partition row 0 (the full-pod
    solo — ``enumerate_partitions`` puts it first) with the fitted width
    in ``g_uidx``, so one layout covers first-sight runs, kept groups,
    and fallback/refit decompositions alike."""

    now: jnp.ndarray             # () f32
    pend_lo: jnp.ndarray         # () i32
    pend_hi: jnp.ndarray         # () i32
    profiled: jnp.ndarray        # (J,) bool
    free: jnp.ndarray            # (N_UNITS,) bool
    r_active: jnp.ndarray        # (R,) bool
    r_seq: jnp.ndarray           # (R,) i32
    r_win: jnp.ndarray           # (R,) i32
    r_grp: jnp.ndarray           # (R,) i32
    next_seq: jnp.ndarray        # () i32
    c_active: jnp.ndarray        # (N_UNITS,) bool
    c_t1: jnp.ndarray            # (N_UNITS,) f32
    c_mask: jnp.ndarray          # (N_UNITS, N_UNITS) bool
    n_busy: jnp.ndarray          # () i32
    busy_t0: jnp.ndarray         # () f32
    busy_time: jnp.ndarray       # () f32
    slice_busy: jnp.ndarray      # (N_UNITS,) f32
    dispatches: jnp.ndarray      # () i32
    backfills: jnp.ndarray       # () i32
    refits: jnp.ndarray          # () i32 — pod-width decompositions
    n_groups: jnp.ndarray        # () i32
    place_seq: jnp.ndarray       # () i32
    steps: jnp.ndarray           # () i32
    err: jnp.ndarray             # () i32
    # entry log (A rows; C = c_max member slots each)
    g_arr: jnp.ndarray           # (A, C) i32 — member arrival index
    g_job: jnp.ndarray           # (A, C) i32 — member job row
    g_size: jnp.ndarray         # (A,) i32 — member count
    g_pidx: jnp.ndarray          # (A,) i32 — planned partition row
    g_uidx: jnp.ndarray          # (A, C) i32 — fitted per-slot width index
    g_dur: jnp.ndarray           # (A,) f32 — claim horizon (makespan)
    g_ft: jnp.ndarray            # (A, C) f32 — per-slot finish offsets
    g_start: jnp.ndarray         # (A, C) i32 — per-slice start offsets
    g_t0: jnp.ndarray            # (A,) f32 — placement time
    g_pack: jnp.ndarray          # (A,) i32 — (pseq << 1) | backfilled


def _build_run_rl(window: int, backfill: bool, capacity: int,
                  telemetry: bool, env_cfg, train: bool = False):
    """The jitted RL single-trace engine.

    Two nested ``lax.while_loop``\\ s: the inner loop is the TS engine's
    service/clock body generalized to multi-slice entries, and *exits*
    (``want``) where the TS engine would form a window; the outer body
    then runs the window-formation seam — observation assembly, the
    greedy DQN episode, the §IV-A fallback guard, and pod-width fitting —
    once per window.  Scheduling semantics (formation gates, EASY
    backfill, claim replay) are unchanged from ``_build_run``; only the
    plan materialized at the seam differs.

    ``train=True`` adds the sim-in-the-loop training surface: ``run``
    takes a PRNG ``key`` and a *traced* exploration rate ``eps`` (so the
    ε schedule never recompiles), the episode acts ε-greedily over the
    same validity mask, and the returned :class:`TrainRollout` carries
    per-step (obs, act, mask, valid) logs plus per-window wait/turnaround
    buckets scatter-added at placement.  Step keys derive from
    ``fold_in(fold_in(key, window), step)`` so the stream is independent
    of vmap lockstep, and ``eps == 0`` reproduces the serving engine's
    greedy decisions bit-for-bit.
    """
    assert window <= env_cfg.window, (window, env_cfg.window)
    W = env_cfg.window
    C = env_cfg.c_max
    obs_ctx = env_cfg.obs_context
    parts = enumerate_partitions(C)
    P = len(parts)
    ptable = build_partition_table(parts, C)
    # static per-(partition, slot) masks: dedicated slice (single share ->
    # shrinks to the member's requested width) and first-slot-of-slice
    # (per-slice reductions over slot lanes)
    ded = np.zeros((P, C), bool)
    first = np.zeros((P, C), bool)
    for p_i, p in enumerate(parts):
        seen: set[int] = set()
        for s_i, (si, s, _b) in enumerate(p.slots):
            ded[p_i, s_i] = len(s.shares) == 1
            if si not in seen:
                first[p_i, s_i] = True
                seen.add(si)
    dedj = jnp.asarray(ded)
    firstj = jnp.asarray(first)
    units_arr = jnp.asarray(np.array(UNIT_SIZES, np.int32))
    U = len(UNIT_SIZES)
    A = capacity
    R = 2 * window + 2
    T_EP = 2 * W                 # selects + closes bound any episode
    max_steps = 2 * capacity + 4
    i32, f32 = jnp.int32, jnp.float32
    c_rng = jnp.arange(C, dtype=jnp.int32)
    w_rng = jnp.arange(W, dtype=jnp.int32)

    def slice_widths(p, uidx):
        """Per-slice (width index, validity) of partition row ``p`` under
        fitted per-slot widths ``uidx`` -> ((C,), (C,))."""
        eq = ((ptable.slot_slice[p][None, :] == c_rng[:, None])
              & ptable.slot_valid[p][None, :])
        svalid = jnp.any(eq, axis=1)
        svec = jnp.max(jnp.where(eq, uidx[None, :], -1), axis=1).astype(i32)
        return svec, svalid

    def fit_multi(free, svec, svalid):
        """In-graph ``find_offsets``: first-fit-decreasing placement of the
        partition's slices onto ``free``.  Python's stable sort breaks
        width ties by slice index; ``-units * C + index`` reproduces that
        order exactly.  Returns (all-fit, per-slice starts, claimed
        union mask)."""
        units = units_arr[jnp.clip(svec, 0, U - 1)]
        key = jnp.where(svalid, -units * C + c_rng, jnp.int32(2 ** 15))
        order = jnp.argsort(key)
        starts = jnp.zeros(C, i32)
        ok = jnp.bool_(True)
        cur = free
        union = jnp.zeros(N_UNITS, dtype=bool)
        for step in range(C):                  # static: C slices max
            sid = order[step]
            act = svalid[sid]
            w_i = jnp.clip(svec[sid], 0, U - 1)
            cand = _ALIGNED[w_i] & jnp.all(cur[None, :] | ~_COVERED[w_i],
                                           axis=1)
            has = jnp.any(cand)
            s0 = jnp.argmax(cand).astype(i32)
            ok = ok & (has | ~act)
            m = _claim_units(s0, units_arr[w_i]) & act & has
            cur = cur & ~m
            union = union | m
            starts = starts.at[sid].set(jnp.where(act, s0, 0))
        return ok, starts, union

    def earliest_fit_multi(st: _RLState, svec, svalid):
        """Multi-slice ``_earliest_fit``: replay claim expiries, earliest
        fitting one wins (same candidate argument as the TS engine)."""
        rel = (st.c_active[None, :] & st.c_active[:, None]
               & (st.c_t1[None, :] <= st.c_t1[:, None]))
        freed = st.free[None, :] | jnp.any(rel[:, :, None] & st.c_mask[None],
                                           axis=1)
        oks = jax.vmap(lambda f: fit_multi(f, svec, svalid)[0])(freed)
        fits = st.c_active & oks
        first_t = jnp.min(jnp.where(fits, st.c_t1, _INF))
        last = jnp.max(jnp.where(st.c_active, st.c_t1, -_INF))
        return jnp.where(jnp.any(fits), first_t,
                         jnp.where(jnp.any(st.c_active), last, f32(0.0)))

    def place_rl(st: _RLState, slot, starts, union, backfilled,
                 do) -> _RLState:
        g = st.r_grp[slot]
        dur = st.g_dur[g]
        mask = union & do
        w = jnp.sum(mask, dtype=i32)
        doi = jnp.where(do, i32(1), i32(0))
        gt = jnp.where(do, g, A)
        ct = jnp.where(do, jnp.argmin(st.c_active).astype(i32), N_UNITS)
        rt = jnp.where(do, slot, R)
        pack = (st.place_seq << 1) | jnp.where(backfilled, i32(1), i32(0))
        return st._replace(
            free=st.free & ~mask,
            busy_t0=jnp.where(do & (st.n_busy == 0), st.now, st.busy_t0),
            n_busy=st.n_busy + w,
            c_active=st.c_active.at[ct].set(True, mode="drop"),
            c_t1=st.c_t1.at[ct].set(st.now + dur, mode="drop"),
            c_mask=st.c_mask.at[ct].set(mask, mode="drop"),
            slice_busy=st.slice_busy + jnp.where(mask, dur, 0.0),
            g_t0=st.g_t0.at[gt].set(st.now, mode="drop"),
            g_start=st.g_start.at[gt].set(starts, mode="drop"),
            g_pack=st.g_pack.at[gt].set(pack, mode="drop"),
            place_seq=st.place_seq + doi,
            r_active=st.r_active.at[rt].set(False, mode="drop"),
            backfills=st.backfills + jnp.where(do & backfilled, i32(1),
                                               i32(0)))

    def run(trace: TraceArrays, rjt: RLJobTable, params,
            width=jnp.int32(N_UNITS), key=None, eps=None):
        Jp = rjt.widx.shape[0] - 1               # padding row index
        pod_widx = jnp.searchsorted(units_arr, width).astype(i32)
        tt = rjt.terms
        if train:
            n_feat = tt.features.shape[1]
            D = W * (n_feat + 5) + (N_UNITS + W + 1 if obs_ctx else 0)
            roll0 = TrainRollout(
                obs=jnp.zeros((A, T_EP, D), f32),
                act=jnp.zeros((A, T_EP), i32),
                mask=jnp.zeros((A, T_EP, W + P), dtype=bool),
                valid=jnp.zeros((A, T_EP), dtype=bool),
                w_wait=jnp.zeros(A, f32),
                w_turn=jnp.zeros(A, f32))
        else:
            roll0 = ()
        st0 = _RLState(
            now=f32(0.0), pend_lo=i32(0), pend_hi=i32(0),
            profiled=jnp.zeros(Jp, dtype=bool),
            free=_UNIT_IDX < width,
            r_active=jnp.zeros(R, dtype=bool),
            r_seq=jnp.zeros(R, i32), r_win=jnp.zeros(R, i32),
            r_grp=jnp.zeros(R, i32), next_seq=i32(0),
            c_active=jnp.zeros(N_UNITS, dtype=bool),
            c_t1=jnp.zeros(N_UNITS, f32),
            c_mask=jnp.zeros((N_UNITS, N_UNITS), dtype=bool),
            n_busy=i32(0), busy_t0=f32(0.0), busy_time=f32(0.0),
            slice_busy=jnp.zeros(N_UNITS, f32),
            dispatches=i32(0), backfills=i32(0), refits=i32(0),
            n_groups=i32(0), place_seq=i32(0), steps=i32(0), err=i32(0),
            g_arr=jnp.full((A, C), A, i32), g_job=jnp.full((A, C), Jp, i32),
            g_size=jnp.zeros(A, i32), g_pidx=jnp.zeros(A, i32),
            g_uidx=jnp.zeros((A, C), i32), g_dur=jnp.zeros(A, f32),
            g_ft=jnp.zeros((A, C), f32), g_start=jnp.zeros((A, C), i32),
            g_t0=jnp.zeros(A, f32), g_pack=jnp.zeros(A, i32))

        def live(st):
            return ((st.pend_hi < trace.n) | jnp.any(st.c_active)
                    | (st.pend_lo < st.pend_hi) | jnp.any(st.r_active))

        def form_and_plan(st: _RLState, roll, do):
            if train:
                # one episode key per window; independent of how many
                # outer iterations frozen sibling lanes burn under vmap
                ep_key = jax.random.fold_in(key, st.dispatches)
            # ---- pop & first-sight protocol (same as _make_form_window)
            k = jnp.where(do, jnp.minimum(jnp.int32(window),
                                          st.pend_hi - st.pend_lo), i32(0))
            i_w = jnp.arange(window, dtype=jnp.int32)
            on = i_w < k
            arr = jnp.clip(st.pend_lo + i_w, 0, A - 1)
            jrow = trace.job[arr]
            earlier_same = ((jrow[None, :] == jrow[:, None])
                            & (i_w[None, :] < i_w[:, None]) & on[None, :])
            fs = on & ~jnp.any(earlier_same, axis=1) & ~st.profiled[jrow]
            profiled = st.profiled.at[jnp.where(on, jrow, Jp)].set(
                True, mode="drop")
            n_fs = jnp.sum(fs, dtype=i32)
            rank_fs = jnp.cumsum(fs, dtype=i32) - 1
            rank_pl = jnp.cumsum(~fs & on, dtype=i32) - 1
            n_pl = jnp.sum(~fs & on, dtype=i32)

            # ---- the profiled chunk as env-window queue rows (<= W)
            pt = jnp.where(~fs & on, rank_pl, W)
            pl_job = jnp.full(W, Jp, i32).at[pt].set(jrow, mode="drop")
            pl_arr = jnp.full(W, A, i32).at[pt].set(arr, mode="drop")
            pl_valid = w_rng < n_pl
            qa = QueueArrays(
                features=tt.features[pl_job], valid=pl_valid,
                comp=tt.comp[pl_job], mem=tt.mem[pl_job],
                collb=tt.collb[pl_job], colll=tt.colll[pl_job],
                fixedt=tt.fixedt[pl_job], steps=tt.steps[pl_job],
                solo=tt.solo[pl_job], cpct=tt.cpct[pl_job],
                mpct=tt.mpct[pl_job],
                mean_c=f32(1.0), mean_m=f32(1.0), mean_d=f32(1.0))
            if obs_ctx:
                # dispatch_obs_context in-graph: busy mask, per-slot ages,
                # pending depth left behind (float32 mirror of the heap's
                # float64 snapshot — context parity is approximate)
                busy_f = (~st.free).astype(jnp.float32)
                age = st.now - trace.t[jnp.clip(pl_arr, 0, A - 1)]
                ages_f = jnp.where(
                    pl_valid,
                    jnp.log10(1.0 + jnp.maximum(age, 0.0)) / 6.0, 0.0)
                depth = jnp.minimum(
                    (st.pend_hi - st.pend_lo - k).astype(jnp.float32)
                    / (4.0 * W), 1.0)
                ctx_vec = jnp.concatenate(
                    [busy_f, ages_f.astype(jnp.float32), depth[None]])

            # ---- greedy co-schedule episode (CoScheduleEnv in-graph)
            def ep_step(carry, t):
                sched, gidx, gsize, pm, psize, ppidx, nplan = carry
                member = jnp.zeros(W, dtype=bool).at[
                    jnp.where(c_rng < gsize, gidx, W)].set(True, mode="drop")
                avail = pl_valid & ~sched & ~member
                prog = gsize.astype(jnp.float32) / jnp.float32(max(1, C))
                flags = jnp.stack([
                    jnp.where(avail, 1.0, 0.0),
                    jnp.where(member, 1.0, 0.0),
                    jnp.where(sched, 1.0, 0.0),
                    jnp.where(~pl_valid, 1.0, 0.0),
                    jnp.where(pl_valid, prog, 0.0)],
                    axis=1).astype(jnp.float32)
                obs = jnp.concatenate([qa.features, flags],
                                      axis=1).reshape(-1)
                if obs_ctx:
                    obs = jnp.concatenate([obs, ctx_vec])
                mask = jnp.concatenate([avail & (gsize < C),
                                        (gsize >= 1)
                                        & (ptable.arity == gsize)])
                done = jnp.all(sched | ~pl_valid) & (gsize == 0)
                act = greedy_q_action(params, obs, mask)
                if train:
                    # ε-greedy over the same mask (act_batch's idiom):
                    # uniform scores, invalid lanes at -1, argmax wins
                    ka, kb = jax.random.split(jax.random.fold_in(ep_key, t))
                    explore = jax.random.uniform(ka, ()) < eps
                    scores = jax.random.uniform(kb, mask.shape)
                    rand = jnp.argmax(
                        jnp.where(mask, scores, -1.0)).astype(i32)
                    act = jnp.where(explore, rand, act)
                do_sel = ~done & (act < W)
                do_close = ~done & (act >= W)
                row = jnp.where(do_close, nplan, W)
                pm = pm.at[row].set(gidx, mode="drop")
                psize = psize.at[row].set(gsize, mode="drop")
                ppidx = ppidx.at[row].set(jnp.clip(act - W, 0, P - 1),
                                          mode="drop")
                sched = sched | (member & do_close)
                gidx = gidx.at[jnp.where(do_sel, jnp.clip(gsize, 0, C - 1),
                                         C)].set(act, mode="drop")
                gidx = jnp.where(do_close, jnp.full(C, -1, i32), gidx)
                gsize = jnp.where(do_close, i32(0),
                                  gsize + jnp.where(do_sel, i32(1), i32(0)))
                nplan = nplan + jnp.where(do_close, i32(1), i32(0))
                ys = (obs, act, mask, ~done) if train else None
                return (sched, gidx, gsize, pm, psize, ppidx, nplan), ys

            init = (jnp.zeros(W, dtype=bool), jnp.full(C, -1, i32), i32(0),
                    jnp.full((W, C), -1, i32), jnp.zeros(W, i32),
                    jnp.zeros(W, i32), i32(0))
            (e_sched, _, e_gsize, pm, psize, ppidx, nplan), ep_ys = \
                jax.lax.scan(ep_step, init,
                             jnp.arange(T_EP, dtype=i32) if train else None,
                             length=T_EP)
            done_f = jnp.all(e_sched | ~pl_valid) & (e_gsize == 0)
            err_ep = jnp.where(do & ~done_f, i32(ERR_EPISODE), i32(0))
            if train:
                o_y, a_y, m_y, v_y = ep_ys
                wrow = jnp.where(do, st.dispatches, A)
                roll = roll._replace(
                    obs=roll.obs.at[wrow].set(o_y, mode="drop"),
                    act=roll.act.at[wrow].set(a_y, mode="drop"),
                    mask=roll.mask.at[wrow].set(m_y, mode="drop"),
                    valid=roll.valid.at[wrow].set(v_y, mode="drop"))

            # ---- §IV-A fallback + pod-width fitting, over planned rows
            row_on = w_rng < nplan
            mvalid = (c_rng[None, :] < psize[:, None]) & row_on[:, None]
            mslot = jnp.clip(pm, 0, W - 1)
            mjob = jnp.where(mvalid, pl_job[mslot], Jp)
            mwidx = rjt.widx[mjob]
            uplan = ptable.slot_units_idx[ppidx]
            uidx_fit = jnp.where(dedj[ppidx],
                                 jnp.minimum(uplan, mwidx), uplan)
            mk_plan, solo_sum, _ri = jax.vmap(
                lambda m, s, p: group_metrics(ptable, qa, m, s, p))(
                    pm, psize, ppidx)
            _mk, _s2, _r2, ft_fit = jax.vmap(
                lambda m, s, p, u: group_metrics(
                    ptable, qa, m, s, p, units_idx=u, with_finish=True))(
                    pm, psize, ppidx, uidx_fit)
            mk_fit = jnp.max(ft_fit, axis=1)
            fallback = row_on & (psize > 1) & (mk_plan > solo_sum)
            wfit = units_arr[uidx_fit]
            ftot = jnp.sum(jnp.where(firstj[ppidx] & ptable.slot_valid[ppidx],
                                     wfit, 0), axis=1)
            refit = row_on & ~fallback & (ftot > width)
            split = fallback | refit
            solo_widx = jnp.minimum(mwidx, pod_widx)
            solo_dur = rjt.dur_wu[mjob, solo_widx]
            fs_widx = jnp.minimum(rjt.widx[jrow], pod_widx)
            fs_dur = rjt.dur_wu[jrow, fs_widx]
            refits_add = (
                jnp.sum(jnp.where(refit, 1, 0))
                + jnp.sum(jnp.where(fallback[:, None] & mvalid
                                    & (mwidx > pod_widx), 1, 0))
                + jnp.sum(jnp.where(fs & (rjt.widx[jrow] > pod_widx), 1, 0)))

            # ---- entry expansion, in schedule order: first-sight solos,
            # then plan rows (split rows decompose to members in place)
            E = jnp.where(row_on, jnp.where(split, psize, 1), 0)
            off = n_fs + jnp.cumsum(E) - E
            n_ent = n_fs + jnp.sum(E)
            EN = window
            e_rng = jnp.arange(EN, dtype=jnp.int32)
            ent_job = jnp.full((EN, C), Jp, i32)
            ent_size = jnp.zeros(EN, i32)
            ent_pidx = jnp.zeros(EN, i32)      # row 0 = the full-pod solo
            ent_uidx = jnp.zeros((EN, C), i32)
            ent_dur = jnp.zeros(EN, f32)
            ent_ft = jnp.zeros((EN, C), f32)
            tfs = jnp.where(fs, rank_fs, EN)
            ent_job = ent_job.at[tfs, 0].set(jrow, mode="drop")
            ent_size = ent_size.at[tfs].set(1, mode="drop")
            ent_uidx = ent_uidx.at[tfs, 0].set(fs_widx, mode="drop")
            ent_dur = ent_dur.at[tfs].set(fs_dur, mode="drop")
            ent_ft = ent_ft.at[tfs, 0].set(fs_dur, mode="drop")
            # kept plan rows: single-member groups take the exact float64
            # solo duration (bit-equal to the heap's corun); true co-run
            # groups take the float32 in-graph model (clock-only drift)
            one = psize == 1
            grp_dur = jnp.where(one, rjt.dur_wu[mjob[:, 0], uidx_fit[:, 0]],
                                mk_fit)
            grp_ft = jnp.where(one[:, None],
                               jnp.where(c_rng[None, :] == 0,
                                         grp_dur[:, None], 0.0),
                               ft_fit)
            tg = jnp.where(row_on & ~split, off, EN)
            ent_job = ent_job.at[tg].set(mjob, mode="drop")
            ent_size = ent_size.at[tg].set(psize, mode="drop")
            ent_pidx = ent_pidx.at[tg].set(ppidx, mode="drop")
            ent_uidx = ent_uidx.at[tg].set(uidx_fit, mode="drop")
            ent_dur = ent_dur.at[tg].set(grp_dur, mode="drop")
            ent_ft = ent_ft.at[tg].set(grp_ft, mode="drop")
            # split rows: member solos, submission slots preserved in place
            tsp = jnp.where(split[:, None] & mvalid,
                            off[:, None] + c_rng[None, :], EN).reshape(-1)
            ent_job = ent_job.at[tsp, 0].set(mjob.reshape(-1), mode="drop")
            ent_size = ent_size.at[tsp].set(1, mode="drop")
            ent_pidx = ent_pidx.at[tsp].set(0, mode="drop")
            ent_uidx = ent_uidx.at[tsp, 0].set(solo_widx.reshape(-1),
                                               mode="drop")
            ent_dur = ent_dur.at[tsp].set(solo_dur.reshape(-1), mode="drop")
            ent_ft = ent_ft.at[tsp, 0].set(solo_dur.reshape(-1), mode="drop")
            # submission attribution is name-keyed FIFO in schedule-entry
            # order (the heap's _form_window by_name deques): when one
            # binary is popped twice into a window, the *entry* order — not
            # the agent's row choice — decides which arrival each entry
            # serves.  The o-th entry member of a job row takes the o-th
            # popped arrival of that row.
            flat_job = ent_job.reshape(-1)
            p_rng = jnp.arange(EN * C, dtype=i32)
            occ_ent = jnp.sum((flat_job[None, :] == flat_job[:, None])
                              & (p_rng[None, :] < p_rng[:, None]),
                              axis=1, dtype=i32)
            occ_pop = jnp.sum(earlier_same, axis=1, dtype=i32)
            amatch = ((jrow[None, :] == flat_job[:, None])
                      & (occ_pop[None, :] == occ_ent[:, None]) & on[None, :])
            ent_arr = jnp.where(
                jnp.any(amatch, axis=1),
                jnp.max(jnp.where(amatch, arr[None, :], 0), axis=1),
                A).reshape(EN, C).astype(i32)

            # ---- ring append (n_ent entries) + group-log scatter
            free_rank = jnp.cumsum(~st.r_active, dtype=i32) - 1
            q = jnp.where(~st.r_active & (free_rank < n_ent), free_rank,
                          i32(-1))
            sel = q >= 0
            err_ring = jnp.where(
                jnp.sum(~st.r_active, dtype=i32) < n_ent,
                i32(ERR_READY_OVERFLOW), i32(0))
            grow = jnp.where(e_rng < n_ent, st.n_groups + e_rng, A)
            st = st._replace(
                profiled=profiled,
                g_arr=st.g_arr.at[grow].set(ent_arr, mode="drop"),
                g_job=st.g_job.at[grow].set(ent_job, mode="drop"),
                g_size=st.g_size.at[grow].set(ent_size, mode="drop"),
                g_pidx=st.g_pidx.at[grow].set(ent_pidx, mode="drop"),
                g_uidx=st.g_uidx.at[grow].set(ent_uidx, mode="drop"),
                g_dur=st.g_dur.at[grow].set(ent_dur, mode="drop"),
                g_ft=st.g_ft.at[grow].set(ent_ft, mode="drop"),
                r_active=st.r_active | sel,
                r_seq=jnp.where(sel, st.next_seq + q, st.r_seq),
                r_win=jnp.where(sel, st.dispatches, st.r_win),
                r_grp=jnp.where(sel, st.n_groups + q, st.r_grp),
                next_seq=st.next_seq + n_ent,
                n_groups=st.n_groups + n_ent,
                pend_lo=st.pend_lo + k,
                refits=st.refits + refits_add,
                err=st.err | err_ep | err_ring,
                dispatches=st.dispatches + jnp.where(do, i32(1), i32(0)))
            return st, roll

        def inner_body(carry):
            st, ms, roll, _w = carry
            head, head_exists = _head(st)
            hg = st.r_grp[head]
            hsvec, hsvalid = slice_widths(st.g_pidx[hg], st.g_uidx[hg])
            ok_h, starts_h, union_h = fit_multi(st.free, hsvec, hsvalid)
            place_head = head_exists & ok_h
            blocked = head_exists & ~place_head
            pending = st.pend_hi > st.pend_lo
            anyfree = jnp.any(st.free)
            can_form = ~head_exists & pending & anyfree
            if backfill:
                max_win = jnp.max(jnp.where(st.r_active, st.r_win,
                                            jnp.int32(-1)))
                can_look = (blocked & pending & anyfree
                            & (max_win == st.r_win[head]))
            else:
                can_look = jnp.bool_(False)
            want = can_look | can_form       # exit: the outer body forms
            slot, sstarts, sunion = head, starts_h, union_h
            do_bf = jnp.bool_(False)
            if backfill:
                # the heap scans in the same pass it forms; here the scan
                # waits one iteration (~want) so it sees the formed ring
                can_scan = blocked & ~want & (jnp.sum(st.r_active,
                                                      dtype=i32) > 1)
                t_res = earliest_fit_multi(st, hsvec, hsvalid)
                svecs, svalids = jax.vmap(
                    lambda g: slice_widths(st.g_pidx[g], st.g_uidx[g]))(
                        st.r_grp)
                oks, starts_r, unions = jax.vmap(
                    lambda sv, sva: fit_multi(st.free, sv, sva))(
                        svecs, svalids)
                durs = st.g_dur[st.r_grp]
                elig = (st.r_active & oks
                        & (jnp.arange(R, dtype=i32) != head)
                        & (st.now + durs <= t_res + 1e-9) & can_scan)
                cand = jnp.argmin(jnp.where(elig, st.r_seq,
                                            _BIG_SEQ)).astype(i32)
                do_bf = can_scan & jnp.any(elig)
                slot = jnp.where(place_head, head, cand)
                sstarts = jnp.where(place_head, starts_h, starts_r[cand])
                sunion = jnp.where(place_head, union_h, unions[cand])
            do_place = place_head | do_bf
            if telemetry:
                g2 = st.r_grp[slot]
                arrm = jnp.clip(st.g_arr[g2], 0, A - 1)
                memv = c_rng < st.g_size[g2]
                waits = st.now - trace.t[arrm]
                b = jnp.searchsorted(_WAIT_EDGES, waits,
                                     side="left").astype(i32)
                nb = ms.wait_hist.shape[0]
                ms = ms._replace(
                    wait_hist=ms.wait_hist.at[
                        jnp.where(do_place & memv, b, nb)].add(
                            1, mode="drop"),
                    wait_sum=ms.wait_sum + jnp.sum(
                        jnp.where(do_place & memv, waits, 0.0)),
                    places=ms.places + jnp.where(do_place, i32(1), i32(0)))
            if train:
                # queueing-reward attribution: the placed entry's member
                # waits/turnarounds land in the bucket of the window that
                # FORMED it (r_win), i.e. the decision that grouped these
                # jobs — not the wall-clock window of the placement
                gq = st.r_grp[slot]
                arrq = jnp.clip(st.g_arr[gq], 0, A - 1)
                memq = c_rng < st.g_size[gq]
                wq = st.now - trace.t[arrq]
                tq = st.now + st.g_ft[gq] - trace.t[arrq]
                brow = jnp.where(do_place, st.r_win[slot], A)
                roll = roll._replace(
                    w_wait=roll.w_wait.at[brow].add(
                        jnp.sum(jnp.where(memq, wq, 0.0)), mode="drop"),
                    w_turn=roll.w_turn.at[brow].add(
                        jnp.sum(jnp.where(memq, tq, 0.0)), mode="drop"))
            st = place_rl(st, slot, sstarts, sunion, do_bf, do_place)

            adv = ~do_place & ~want
            t_arr = jnp.where(st.pend_hi < trace.n,
                              trace.t[jnp.clip(st.pend_hi, 0, A - 1)], _INF)
            t_free = jnp.min(jnp.where(st.c_active, st.c_t1, _INF))
            now = jnp.where(adv, jnp.minimum(t_arr, t_free), st.now)
            pend_hi = jnp.where(
                adv, jnp.sum(trace.t <= now, dtype=i32), st.pend_hi)
            rel = adv & st.c_active & (st.c_t1 <= now)
            freed = jnp.any(rel[:, None] & st.c_mask, axis=0)
            w_rel = jnp.sum(jnp.where(rel[:, None], st.c_mask, False),
                            dtype=i32)
            n_busy = st.n_busy - w_rel
            busy_time = st.busy_time + jnp.where(
                (n_busy == 0) & (w_rel > 0), now - st.busy_t0, 0.0)
            steps = st.steps + jnp.where(adv, i32(1), i32(0))
            if telemetry:
                dt = now - st.now
                ms = ms._replace(
                    queue_depth_int=ms.queue_depth_int
                    + (st.pend_hi - st.pend_lo).astype(jnp.float32) * dt,
                    busy_unit_int=ms.busy_unit_int
                    + st.n_busy.astype(jnp.float32) * dt)
            st = st._replace(
                now=now, pend_hi=pend_hi, free=st.free | freed,
                c_active=st.c_active & ~rel, n_busy=n_busy,
                busy_time=busy_time, steps=steps,
                err=st.err | jnp.where(steps > max_steps,
                                       i32(ERR_EVENT_OVERFLOW), i32(0)))
            return st, ms, roll, want

        def outer_body(carry):
            st, ms, roll = carry
            st, ms, roll, want = jax.lax.while_loop(
                lambda c: live(c[0]) & (c[0].err == 0) & ~c[3],
                inner_body, (st, ms, roll, jnp.bool_(False)))
            st, roll = form_and_plan(st, roll, want)
            return st, ms, roll

        st, ms, roll = jax.lax.while_loop(
            lambda c: live(c[0]) & (c[0].err == 0), outer_body,
            (st0, _metrics_init(), roll0))
        if train:
            return (st, ms, roll) if telemetry else (st, roll)
        return (st, ms) if telemetry else st

    return run


def _records_rl(st: _RLState, trace: TraceArrays):
    A = trace.t.shape[0]
    C = st.g_arr.shape[1]
    memv = jnp.arange(C)[None, :] < st.g_size[:, None]
    tgt = jnp.where(memv, st.g_arr, A).reshape(-1)
    dispatch = jnp.zeros(A, jnp.float32).at[tgt].set(
        jnp.broadcast_to(st.g_t0[:, None], st.g_arr.shape).reshape(-1),
        mode="drop")
    finish = jnp.zeros(A, jnp.float32).at[tgt].set(
        (st.g_t0[:, None] + st.g_ft).reshape(-1), mode="drop")
    return dispatch, finish


def _summary_rl(st: _RLState, trace: TraceArrays,
                rjt: RLJobTable) -> SweepSummary:
    dispatch, finish = _records_rl(st, trace)
    return _summarize(st, trace, dispatch, finish, rjt.solo8[trace.job])


def make_rollout_collector(env_cfg, window: int = 8, backfill: bool = True,
                           capacity: int = 256):
    """Jitted, vmapped sim-in-the-loop rollout collector.

    Returns ``collect(traces, rjt, params, keys, eps, widths)`` where
    ``traces`` is a stacked :class:`TraceArrays` batch (leading axis B),
    ``keys`` is a (B, 2) uint32 PRNG-key batch, ``eps`` a scalar traced
    exploration rate shared across the batch, and ``widths`` a (B,) i32
    pod-width lane.  Yields ``(SweepSummary, TrainRollout)`` pytrees with
    leading axis B — the summary carries the terminal makespan and the
    ``err`` lane (callers must check it), the rollout carries the
    transition logs and per-window queueing buckets that
    ``train_online``'s host-side stitcher turns into replay transitions.
    With ``eps=0`` the rollout's decisions are bit-identical to the
    serving engine's.
    """
    runf = _build_run_rl(window, backfill, capacity, False, env_cfg,
                         train=True)

    def _one(tr, rjt, params, k, eps, width):
        st, roll = runf(tr, rjt, params, width, k, eps)
        return _summary_rl(st, tr, rjt), roll

    return jax.jit(jax.vmap(_one, in_axes=(0, None, None, 0, None, 0)))


def _emit_lane_rl(st: _RLState, jobs: list, parts: list,
                  records: list[JobRecord], pod: int = 0) -> list[Segment]:
    """RL mirror of ``_emit_lane``: rebuild each entry's fitted partition
    from the logged per-slot widths (the exact ``to_placements`` shrink)
    and recompute its record times with the float64 ``corun`` the heap
    stores — so decisions AND label/units/grouping match the heap
    bit-for-bit, and only the placement clock carries float32 rounding."""
    g_n = int(st.n_groups)
    g_arr = np.asarray(st.g_arr)[:g_n]
    g_job = np.asarray(st.g_job)[:g_n]
    g_size = np.asarray(st.g_size)[:g_n]
    g_pidx = np.asarray(st.g_pidx)[:g_n]
    g_uidx = np.asarray(st.g_uidx)[:g_n]
    g_start = np.asarray(st.g_start)[:g_n]
    g_t0 = np.asarray(st.g_t0)[:g_n]
    pack = np.asarray(st.g_pack)[:g_n]
    g_pseq, g_bf = pack >> 1, (pack & 1) == 1
    segs: list[tuple[int, Segment]] = []
    for g in range(g_n):
        size = int(g_size[g])
        group = [jobs[int(g_job[g, m])] for m in range(size)]
        planned = parts[int(g_pidx[g])]
        new_slices = list(planned.slices)
        changed = False
        for s_i, (si, s, _b) in enumerate(planned.slots):
            w = UNIT_SIZES[int(g_uidx[g, s_i])]
            if len(s.shares) == 1 and w < s.units:
                new_slices[si] = Slice(w, s.shares)
                changed = True
        part = (Partition(tuple(new_slices), slice_label(tuple(new_slices)))
                if changed else planned)
        pred = corun(group, part)
        t0 = float(g_t0[g])
        for m, (ft, (_si, s, _b)) in enumerate(zip(pred.finish_times,
                                                   part.slots)):
            rec = records[int(g_arr[g, m])]
            rec.dispatch = t0
            rec.finish = t0 + float(ft)
            rec.group_size = size
            rec.partition = part.label
            rec.units = s.units
            rec.backfilled = bool(g_bf[g])
            rec.pod = pod
        ranges = tuple((int(g_start[g, si]), s.units)
                       for si, s in enumerate(part.slices))
        segs.append((int(g_pseq[g]), Segment(
            t0=t0, t1=t0 + float(pred.makespan), jobs=size,
            partition=part.label, slices=ranges,
            backfilled=bool(g_bf[g]), pod=pod)))
    return [s for _, s in sorted(segs, key=lambda x: x[0])]


class VectorizedClusterSimulator:
    """Drop-in vectorized engine for time-sharing and RL dispatch plans.

    ``run(trace)`` returns a :class:`~repro.online.simulator.SimResult`
    built from the device lanes (records in sorted-trace order, timeline
    in placement order — the same shapes the heap produces), so every
    downstream consumer (summaries, percentiles, benchmarks) is shared.
    ``sweep(traces)`` evaluates a batch in one vmapped call (sharded over
    host devices via ``pmap`` when ``devices`` is given) and returns
    per-trace :class:`SweepSummary` lanes.

    ``policy`` is a :class:`~repro.online.policies.TimeSharingPolicy`
    (or ``None``, same semantics) or an :class:`~repro.online.policies.\
RLDispatchPolicy`, whose agent episodes then run in-graph at the
    window-formation seam (module docstring); ``hot_swap`` between calls
    never recompiles, and ``sweep(..., param_sets=[...])`` adds a
    leading params axis evaluating a population of agents in one call.
    Use :meth:`supports` to route other policies to the heap.  No
    ``on_tick``/re-training (host callbacks cannot run in-graph) and no
    ``mode="blocking"`` — the heap remains the only path for both.
    """

    def __init__(self, policy=None, window: int = 8, backfill: bool = True,
                 capacity: int = 256, telemetry: bool = False):
        if not self.supports(policy):
            raise ValueError(
                f"vectorized engine serves TimeSharingPolicy or "
                f"RLDispatchPolicy plans; got {type(policy).__name__}")
        assert window >= 1
        self.policy = policy if policy is not None else TimeSharingPolicy()
        self.window = window
        self.backfill = backfill
        self.capacity = capacity
        # `telemetry` is a *static* engine flag: False compiles the exact
        # pre-telemetry program; True threads a MetricsState through the
        # while_loop (run -> (state, metrics)) without touching the state
        # trajectory — see _build_run
        self.telemetry = telemetry
        self.last_metrics: dict | None = None
        self.last_sweep_metrics: MetricsState | None = None
        self._rl = isinstance(self.policy, RLDispatchPolicy)
        if self._rl:
            env_cfg = self.policy.scheduler.env_cfg
            if window > env_cfg.window:
                raise ValueError(
                    f"sim window {window} > agent window {env_cfg.window}: "
                    f"one formation would span several RL episodes "
                    f"(submission_protocol re-chunking); use a sim window "
                    f"<= EnvConfig.window")
            self._env_cfg = env_cfg
            self._parts = enumerate_partitions(env_cfg.c_max)
            runf = _build_run_rl(window, backfill, capacity, telemetry,
                                 env_cfg)
            if telemetry:
                def _one(tr, jt, params):
                    st, ms = runf(tr, jt, params)
                    return _summary_rl(st, tr, jt), ms
            else:
                def _one(tr, jt, params):
                    return _summary_rl(runf(tr, jt, params), tr, jt)
            self._sweepfn = jax.jit(jax.vmap(_one, in_axes=(0, None, None)))
            # population axis: outer vmap over stacked agent params — one
            # device call scores P agents x T traces on queueing reward
            self._sweep_pop = jax.jit(jax.vmap(
                jax.vmap(_one, in_axes=(0, None, None)),
                in_axes=(None, None, 0)))
        else:
            runf = _build_run(window, backfill, capacity, telemetry)
            if telemetry:
                def _one(tr, jt):
                    st, ms = runf(tr, jt)
                    return _summary(st, tr, jt), ms
            else:
                def _one(tr, jt):
                    return _summary(runf(tr, jt), tr, jt)
            self._sweepfn = jax.jit(jax.vmap(_one, in_axes=(0, None)))
        self._run1 = jax.jit(runf)

    @staticmethod
    def supports(policy) -> bool:
        """Policies this engine serves with decision-level heap parity."""
        return policy is None or isinstance(
            policy, (TimeSharingPolicy, RLDispatchPolicy))

    # ---------------------------------------------------------------- run

    def run(self, trace: list[Arrival]) -> SimResult:
        res = SimResult(policy=getattr(self.policy, "name", "time_sharing"),
                        window=self.window, jobs=[], mode="concurrent")
        if not trace:
            return res
        jobs: list = []
        tr, order = compile_trace(trace, self.capacity, jobs=jobs)
        if self._rl:
            jt = build_rl_job_table(jobs)
            out = jax.block_until_ready(
                self._run1(tr, jt, self.policy.agent.params))
        else:
            jt = build_job_table(jobs)
            out = jax.block_until_ready(self._run1(tr, jt))
        if self.telemetry:
            st, ms = out
            self.last_metrics = metrics_dict(ms)
        else:
            st = out
        self._check_err(int(st.err))

        records = [JobRecord(binary=a.binary, name=a.profile.name,
                             arrival=a.t, solo_time=a.profile.solo_time(),
                             idx=i, job_class=a.profile.job_class)
                   for i, a in enumerate(order)]
        res.jobs = records
        if self._rl:
            res.timeline = _emit_lane_rl(st, jobs, self._parts, records)
            res.refits = int(st.refits)
        else:
            res.timeline = _emit_lane(st, jt, records)
        res.busy_time = float(st.busy_time)
        res.dispatches = int(st.dispatches)
        res.backfills = int(st.backfills)
        res.slice_busy_s = [float(x) for x in np.asarray(st.slice_busy)]
        return res

    # -------------------------------------------------------------- sweep

    def sweep(self, traces: list[list[Arrival]],
              devices: list | None = None, with_metrics: bool = False,
              param_sets=None):
        """Evaluate ``traces`` in one device call (one compiled program).

        With ``devices`` (>= 2 and batch divisible), the batch axis is
        sharded across host devices via ``pmap`` — the CPU-CI parallelism
        of ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

        With ``with_metrics=True`` (requires a ``telemetry=True`` engine)
        returns ``(SweepSummary, MetricsState)`` — the per-lane metric
        tensors accumulated in-graph, batch axis leading, at no extra
        device syncs.  A telemetry engine still records
        ``last_sweep_metrics`` when ``with_metrics`` is off.

        ``param_sets`` (RL engines only): a list of DQN param pytrees (or
        one pre-stacked pytree) adds a leading *population* axis — the
        returned :class:`SweepSummary` lanes are ``(n_params, n_traces)``,
        one vmap evaluating every agent of a population on queueing
        reward (mean/p99 wait and friends).  Exclusive of ``devices``
        sharding and ``with_metrics``.
        """
        if not traces:
            raise ValueError("empty sweep")
        if with_metrics and not self.telemetry:
            raise ValueError("with_metrics needs an engine built with "
                             "telemetry=True")
        if param_sets is not None and not self._rl:
            raise ValueError("param_sets needs an RLDispatchPolicy engine")
        if param_sets is not None and with_metrics:
            raise ValueError("param_sets and with_metrics are exclusive")
        names: dict[str, int] = {}
        jobs: list = []
        compiled = [compile_trace(t, self.capacity, names, jobs)[0]
                    for t in traces]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *compiled)
        if self._rl:
            jt = build_rl_job_table(jobs)
            if param_sets is not None:
                stacked = (param_sets if isinstance(param_sets, dict)
                           else jax.tree.map(lambda *xs: jnp.stack(xs),
                                             *param_sets))
                out = jax.block_until_ready(
                    self._sweep_pop(batch, jt, stacked))
                self._check_err(int(np.max(np.asarray(out.err))))
                return out
            args = (jt, self.policy.agent.params)
        else:
            jt = build_job_table(jobs)
            args = (jt,)
        n_dev = len(devices) if devices else 1
        if n_dev > 1 and len(traces) % n_dev == 0:
            shard = jax.tree.map(
                lambda x: x.reshape((n_dev, len(traces) // n_dev)
                                    + x.shape[1:]), batch)
            pfn = jax.pmap(lambda tr: self._sweepfn(tr, *args),
                           devices=devices)
            out = jax.block_until_ready(pfn(shard))
            out = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), out)
        else:
            out = jax.block_until_ready(self._sweepfn(batch, *args))
        if self.telemetry:
            summ, ms = out
            self.last_sweep_metrics = ms
        else:
            summ = out
        self._check_err(int(np.max(np.asarray(summ.err))))
        return (summ, ms) if with_metrics else summ

    @staticmethod
    def _check_err(err: int) -> None:
        if err & ERR_READY_OVERFLOW:
            raise RuntimeError("vectorized engine: ready ring overflow")
        if err & ERR_EVENT_OVERFLOW:
            raise RuntimeError("vectorized engine: event-step budget "
                               "exceeded (stuck trace?)")
        if err:
            raise RuntimeError(f"vectorized engine: error lanes {err:#x}")


class VectorizedFleetSimulator:
    """Hash-routed fleet on the vectorized engine: a vmapped pod axis.

    The hash router is the one shipped policy computable from the trace
    alone — its assignment depends only on the binary path, the seed, and
    the *static* pod widths (eligibility), never on cluster state.  Routed
    sub-streams therefore never interact (claims are pod-local, windows
    are pod-local, a routed job never migrates), so the heap fleet under
    hash routing is **exactly** the merge of independent single-pod
    simulations of the routed subtraces.  This wrapper materializes that
    decomposition: split the trace with the same :class:`~repro.online.\\
    router.HashRouter` the heap uses, compile each pod's subtrace against
    one shared job table, and run all pods in ONE vmapped device call with
    a per-lane ``width`` (a narrow pod's upper units are born busy).
    Per-pod lanes are merged back into a single fleet
    :class:`~repro.online.simulator.SimResult` — records in sorted-trace
    order tagged with their pod, segments on the fleet-wide unit axis —
    matching the heap fleet's decisions exactly and its clock to float32.

    State-dependent routers (``least_loaded``/``frag``) couple the pods
    through the live :class:`FleetView` and stay heap-only, as do
    ``mode="blocking"``, ``on_tick`` re-training, and policies outside
    time-sharing/RL (:meth:`supports` mirrors
    :class:`VectorizedClusterSimulator`).  With an
    :class:`~repro.online.policies.RLDispatchPolicy` every pod lane runs
    the agent's episode in-graph; ``pod_params`` (a list of ``n_pods``
    params pytrees) optionally overrides the policy agent's params *per
    pod*, so heterogeneous fleets can serve per-pod-specialized agents
    in the same device call.  ``capacity``
    bounds the *per-pod* subtrace length; hash-splitting an
    ``n``-arrival trace needs roughly ``n / n_pods`` plus skew headroom.
    """

    def __init__(self, policy=None, config: SimConfig | None = None, *,
                 window: int = 8, backfill: bool = True,
                 capacity: int = 256,
                 pods: tuple[int, ...] | None = None,
                 router: str = "hash", router_seed: int = 0,
                 telemetry: bool = False, pod_params: list | None = None):
        if config is None:
            config = SimConfig(
                window=window, backfill=backfill,
                pods=tuple(pods) if pods is not None else (N_UNITS,),
                router=router, router_seed=router_seed)
        if not self.supports(policy):
            raise ValueError(
                f"vectorized fleet serves TimeSharingPolicy or "
                f"RLDispatchPolicy plans; got {type(policy).__name__}")
        if config.router != "hash":
            raise ValueError(
                f"vectorized fleet requires the state-free 'hash' router "
                f"(got {config.router!r}); state-dependent routers couple "
                f"pods and run on the heap ClusterSimulator")
        if config.mode != "concurrent" or config.tick_interval_s:
            raise ValueError("vectorized fleet is concurrent-mode only, "
                             "without ticks")
        self.config = config
        self.policy = policy if policy is not None else TimeSharingPolicy()
        self.capacity = capacity
        self.telemetry = telemetry
        self.last_metrics: dict | None = None
        self._router = make_router(config.router, config.router_seed)
        self._rl = isinstance(self.policy, RLDispatchPolicy)
        if pod_params is not None:
            if not self._rl:
                raise ValueError("pod_params needs an RLDispatchPolicy")
            if len(pod_params) != config.n_pods:
                raise ValueError(
                    f"pod_params has {len(pod_params)} entries for "
                    f"{config.n_pods} pods")
        self.pod_params = pod_params        # per-pod DQN params (None:
                                            # every pod runs policy.agent)
        if self._rl:
            env_cfg = self.policy.scheduler.env_cfg
            if config.window > env_cfg.window:
                raise ValueError(
                    f"sim window {config.window} > agent window "
                    f"{env_cfg.window}: use a sim window <= EnvConfig.window")
            self._env_cfg = env_cfg
            self._parts = enumerate_partitions(env_cfg.c_max)
            self._runp = jax.jit(jax.vmap(
                _build_run_rl(config.window, config.backfill, capacity,
                              telemetry, env_cfg),
                in_axes=(0, None, 0, 0)))
        else:
            self._runp = jax.jit(jax.vmap(
                _build_run(config.window, config.backfill, capacity,
                           telemetry),
                in_axes=(0, None, 0)))

    @staticmethod
    def supports(policy) -> bool:
        return VectorizedClusterSimulator.supports(policy)

    def run(self, trace: list[Arrival]) -> SimResult:
        cfg = self.config
        res = SimResult(policy=getattr(self.policy, "name", "time_sharing"),
                        window=cfg.window, jobs=[], mode="concurrent",
                        slice_busy_s=[0.0] * cfg.total_units,
                        pods=cfg.pods, router=cfg.router)
        if not trace:
            return res
        order = sorted(trace, key=lambda a: a.t)
        records = [JobRecord(binary=a.binary, name=a.profile.name,
                             arrival=a.t, solo_time=a.profile.solo_time(),
                             idx=i, job_class=a.profile.job_class)
                   for i, a in enumerate(order)]
        res.jobs = records

        # static pre-split: same router object the heap constructs, fed a
        # quiescent FleetView (hash ignores the dynamic fields) — so the
        # assignment is bit-identical to the heap's at-arrival routing
        view = FleetView(pods=tuple(
            PodView(idx=i, width=w, free=(True,) * w, pending=0, ready=0,
                    queue_units=0, busy_units=0)
            for i, w in enumerate(cfg.pods)))
        sub: list[list[Arrival]] = [[] for _ in cfg.pods]
        sub_rec: list[list[JobRecord]] = [[] for _ in cfg.pods]
        for a, rec in zip(order, records):
            p = 0 if cfg.n_pods == 1 else self._router.route(a, view)
            rec.pod = p
            sub[p].append(a)
            sub_rec[p].append(rec)

        names: dict[str, int] = {}
        jobs: list = []
        compiled = [compile_trace(s, self.capacity, names, jobs)[0]
                    for s in sub]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *compiled)
        widths = jnp.asarray(np.array(cfg.pods, np.int32))
        if self._rl:
            jt = build_rl_job_table(jobs)
            plist = (self.pod_params if self.pod_params is not None
                     else [self.policy.agent.params] * cfg.n_pods)
            pstack = jax.tree.map(lambda *xs: jnp.stack(xs), *plist)
            out = jax.block_until_ready(
                self._runp(batch, jt, pstack, widths))
        else:
            jt = build_job_table(jobs)
            out = jax.block_until_ready(self._runp(batch, jt, widths))
        if self.telemetry:
            sts, mss = out
            # pod lanes are disjoint sub-streams: fleet metrics are the sum
            self.last_metrics = metrics_dict(
                jax.tree.map(lambda x: x.sum(0), mss))
        else:
            sts = out
        VectorizedClusterSimulator._check_err(
            int(np.max(np.asarray(sts.err))))

        offs = res.pod_offsets
        segs: list[Segment] = []
        for p, w in enumerate(cfg.pods):
            st = jax.tree.map(lambda x, p=p: x[p], sts)
            if self._rl:
                segs.extend(_emit_lane_rl(st, jobs, self._parts,
                                          sub_rec[p], pod=p))
                res.refits += int(st.refits)
            else:
                segs.extend(_emit_lane(st, jt, sub_rec[p], pod=p))
            res.busy_time += float(st.busy_time)
            res.dispatches += int(st.dispatches)
            res.backfills += int(st.backfills)
            sb = np.asarray(st.slice_busy)
            for u in range(w):
                res.slice_busy_s[offs[p] + u] = float(sb[u])
        # merge lanes chronologically; Python's stable sort keeps each
        # pod's placement order intact on ties
        segs.sort(key=lambda s: (s.t0, s.pod))
        res.timeline = segs
        return res
