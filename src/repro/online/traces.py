"""Arrival-trace generators for the online cluster simulator.

Each generator returns a time-sorted ``list[Arrival]``, fully determined by
its seed.  Jobs are drawn from the :mod:`repro.core.workloads` zoo with
class weights that mirror the paper's §V-A2 queue recipes — ``mix`` maps
directly onto the Table V workload categories:

    "balanced"  — CI/MI/US equally likely       (Balanced queues)
    "ci"        — 50% CI, 25% MI, 25% US        (CI-dominant queues)
    "mi" / "us" — analogous dominant mixes

Five arrival processes cover the multi-tenant dynamics MISO-style systems
are evaluated under:

    poisson_trace      — memoryless submissions at a constant rate,
    mmpp_trace         — 2-state Markov-modulated Poisson (bursty: a
                         high-rate burst state and a low-rate lull state),
    diurnal_trace      — sinusoidal day/night rate, sampled by thinning,
    heavy_tailed_trace — Poisson arrivals whose *job scale* is
                         Pareto-distributed: each arrival's step count is
                         multiplied by a power-of-two factor drawn from a
                         heavy tail, creating the elephant-and-mice duration
                         mix real clusters see,
    fragmented_trace   — Poisson arrivals carrying *right-sized slice
                         requests* (``meta["units"]``): each submission asks
                         for the narrowest MIG slice whose solo step time
                         stays within a per-arrival tolerance of the
                         full-pod time, mixing 1-slice mice with 4-slice
                         and full-pod jobs — the fragmentation-stressing
                         family slice-level dispatch and backfill are
                         scored on.

Rates are expressed as a ``load`` factor relative to the mean solo duration
of the job pool: ``load=1.0`` submits work exactly as fast as pure time
sharing could retire it, ``load>1`` saturates the pod so makespan-derived
throughput measures scheduling quality rather than idle time.

Trace families double as the *context regimes* of the arrival-aware
observation (``docs/observation.md``): ``fragmented`` exercises the
busy-unit mask (partial occupancies at almost every dispatch), ``mmpp`` and
``diurnal`` swing the queue-depth and age features between lull and burst,
and ``heavy_tailed`` stretches ages behind elephants — which is why the
``arrival_aware`` benchmark section serves every family through both the
profile-only and the context-trained agent.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.profiles import JobProfile
from repro.online.simulator import Arrival

_CLASS_ORDER = ("CI", "MI", "US")


def _class_weights(mix: str) -> dict[str, float]:
    if mix == "balanced":
        return {c: 1 / 3 for c in _CLASS_ORDER}
    dom = mix.upper()
    assert dom in _CLASS_ORDER, mix
    return {c: 0.5 if c == dom else 0.25 for c in _CLASS_ORDER}


def _job_probs(jobs: list[JobProfile], mix: str) -> np.ndarray:
    """Per-job draw probabilities: class weight split evenly inside a class.

    Classes absent from the pool redistribute their weight proportionally
    (the normalization), so any non-empty pool works with any mix."""
    w = _class_weights(mix)
    by_cls: dict[str, int] = {c: 0 for c in _CLASS_ORDER}
    for j in jobs:
        by_cls[j.job_class] += 1
    p = np.array([w[j.job_class] / by_cls[j.job_class] for j in jobs])
    return p / p.sum()


def _draw_jobs(jobs, n, mix, rng) -> list[JobProfile]:
    p = _job_probs(jobs, mix)
    idx = rng.choice(len(jobs), size=n, p=p)
    return [jobs[i] for i in idx]


def mean_solo_time(jobs: list[JobProfile]) -> float:
    return float(np.mean([j.solo_time() for j in jobs]))


def _rate(jobs: list[JobProfile], load: float, capacity: float = 1.0) -> float:
    """Arrivals/second that submit ``load * capacity`` pods' worth of solo
    work.  ``capacity`` is the serving fleet's size in full-pod
    equivalents (``SimConfig.total_units / N_UNITS``), so ``load`` keeps
    its single-pod meaning — 1.0 saturates the *whole* fleet — and
    ``capacity=1.0`` reproduces the historical rates bit-for-bit."""
    return capacity * load / mean_solo_time(jobs)


def _binary(prof: JobProfile) -> str:
    return f"bin://{prof.name}"


def _assemble(times, picks) -> list[Arrival]:
    return [Arrival(t=float(t), binary=_binary(j), profile=j)
            for t, j in zip(times, picks)]


def poisson_trace(jobs: list[JobProfile], n: int, load: float = 1.2,
                  mix: str = "balanced", seed: int = 0,
                  capacity: float = 1.0) -> list[Arrival]:
    """Constant-rate memoryless submissions.  ``capacity`` scales the rate
    to a fleet of that many full-pod equivalents (all families take it)."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / _rate(jobs, load, capacity),
                                      size=n))
    return _assemble(times, _draw_jobs(jobs, n, mix, rng))


def mmpp_trace(jobs: list[JobProfile], n: int, load: float = 1.2,
               burst_factor: float = 4.0, mean_phase_s: float = 600.0,
               mix: str = "balanced", seed: int = 0,
               capacity: float = 1.0) -> list[Arrival]:
    """Bursty 2-state MMPP: alternating burst/lull phases of exponential
    length; the burst state submits ``burst_factor``x the lull rate while
    the *time-average* rate matches ``load``."""
    rng = np.random.default_rng(seed)
    base = _rate(jobs, load, capacity)
    lo = 2.0 * base / (1.0 + burst_factor)        # phases are equally likely
    hi = burst_factor * lo
    times, t, state, phase_end = [], 0.0, 1, 0.0
    while len(times) < n:
        if t >= phase_end:
            state = 1 - state
            phase_end = t + rng.exponential(mean_phase_s)
        t += rng.exponential(1.0 / (hi if state else lo))
        times.append(t)
    return _assemble(times, _draw_jobs(jobs, n, mix, rng))


def diurnal_trace(jobs: list[JobProfile], n: int, load: float = 1.2,
                  amplitude: float = 0.8, period_s: float = 7200.0,
                  mix: str = "balanced", seed: int = 0,
                  capacity: float = 1.0) -> list[Arrival]:
    """Sinusoidal day/night rate lambda(t) = base * (1 + A sin(2 pi t / P)),
    sampled exactly by thinning a dominating Poisson process."""
    assert 0.0 <= amplitude < 1.0
    rng = np.random.default_rng(seed)
    base = _rate(jobs, load, capacity)
    peak = base * (1.0 + amplitude)
    times, t = [], 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / peak)
        lam = base * (1.0 + amplitude * math.sin(2.0 * math.pi * t / period_s))
        if rng.uniform() * peak <= lam:
            times.append(t)
    return _assemble(times, _draw_jobs(jobs, n, mix, rng))


def heavy_tailed_trace(jobs: list[JobProfile], n: int, load: float = 1.2,
                       tail_index: float = 1.3, max_scale: int = 8,
                       mix: str = "balanced", seed: int = 0,
                       capacity: float = 1.0) -> list[Arrival]:
    """Poisson arrivals with Pareto-distributed job scale.

    Each arrival's step count is stretched by a power-of-two factor from a
    Pareto(``tail_index``) tail, capped at ``max_scale``.  Scaled variants
    get distinct names/binaries (``name@x4``), so the profile repository
    treats each scale as its own application — a few elephants dominate the
    submitted work while most jobs stay mice.
    """
    rng = np.random.default_rng(seed)
    picks = _draw_jobs(jobs, n, mix, rng)
    raw = 1.0 + rng.pareto(tail_index, size=n)
    scales = np.minimum(2 ** np.floor(np.log2(raw)).astype(int), max_scale)
    variants: dict[str, JobProfile] = {}
    scaled = []
    for j, s in zip(picks, scales):
        if s <= 1:
            scaled.append(j)
            continue
        key = f"{j.name}@x{int(s)}"
        if key not in variants:
            variants[key] = dataclasses.replace(
                j, name=key, steps=int(j.steps * int(s)), meta=dict(j.meta))
        scaled.append(variants[key])
    # elephants inflate the mean solo work; rate uses the *base* pool so the
    # nominal load stays comparable across trace families
    times = np.cumsum(rng.exponential(1.0 / _rate(jobs, load, capacity),
                                      size=n))
    return _assemble(times, scaled)


def fragmented_trace(jobs: list[JobProfile], n: int, load: float = 1.2,
                     mix: str = "balanced", seed: int = 0,
                     tols: tuple[float, ...] = (1.05, 1.35, 1.65),
                     capacity: float = 1.0) -> list[Arrival]:
    """Poisson arrivals with MISO-style right-sized slice requests.

    Each arrival draws a tolerance from ``tols`` and requests the narrowest
    slice width whose solo step time stays within that tolerance of the
    full-pod step time (:meth:`JobProfile.right_size`): US jobs right-size
    to 1 unit at any tolerance (short collective rings make them *faster*
    on small slices), MI decode lands on 2-4 units at looser tolerances,
    and scalable CI training stays full-pod.  Width-``w`` variants get
    distinct names/binaries (``name@u{w}``) and carry ``meta["units"] = w``
    — the placement hint the slice-level dispatch layer honors — so the
    repository treats each right-sized shape as its own application.

    The resulting mix of 1-slice mice among 4-slice and full-pod jobs is
    exactly the fragmentation stress of the MIG-placement literature: big
    jobs wait for wide aligned ranges while mice trickle into (or, without
    backfill, pile up behind) the gaps.  Arrival times reuse the base
    pool's rate, so nominal load stays comparable across trace families.
    """
    from repro.core.partition import N_UNITS

    rng = np.random.default_rng(seed)
    picks = _draw_jobs(jobs, n, mix, rng)
    tol_idx = rng.integers(0, len(tols), size=n)
    variants: dict[str, JobProfile] = {}
    sized = []
    for j, ti in zip(picks, tol_idx):
        w = j.right_size(tols[ti])
        if w >= N_UNITS:
            sized.append(j)
            continue
        key = f"{j.name}@u{w}"
        if key not in variants:
            variants[key] = dataclasses.replace(
                j, name=key, meta={**j.meta, "units": w})
        sized.append(variants[key])
    times = np.cumsum(rng.exponential(1.0 / _rate(jobs, load, capacity),
                                      size=n))
    return _assemble(times, sized)


TRACE_FAMILIES = {
    "poisson": poisson_trace,
    "mmpp": mmpp_trace,
    "diurnal": diurnal_trace,
    "heavy_tailed": heavy_tailed_trace,
    "fragmented": fragmented_trace,
}
