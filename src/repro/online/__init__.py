"""Online cluster serving: event-driven multi-tenant arrivals + re-training.

This package turns the offline queue solver into a system that serves
traffic over simulated time — the paper's §IV-B online phase under
MISO-style multi-tenant dynamics.

Event model
-----------
:class:`~repro.online.simulator.ClusterSimulator` advances a single event
heap of ``ARRIVE`` / ``TICK`` / ``FREE`` events.  Submissions queue FCFS;
whenever slice units are idle and the dispatched-group queue has drained,
the head window (up to W submissions) is handed to a
:class:`~repro.online.policies.DispatchPolicy` as ``(binary, profile)``
pairs.  First-sight binaries run solo while being profiled and enter the
:class:`~repro.core.profiles.ProfileRepository`; profiled jobs are
co-scheduled into hierarchically partitioned groups.  The policy's
width-fitted :class:`~repro.core.scheduler.Placement`\\ s are first-fitted
onto disjoint aligned slice-unit ranges, so independent groups run
**concurrently**; a blocked head reserves its earliest feasible start and
an EASY-backfill scan lets small later groups jump into idle gaps without
delaying it.  Each group's FREE event is keyed by its claimed slice
ranges.  Per-job wait/turnaround, cluster makespan/throughput/utilization,
and slice-level fragmentation metrics (idle-slice fraction, per-slice
utilization timeline) land in a
:class:`~repro.online.simulator.SimResult`; ``mode="blocking"`` recovers
the PR-3 whole-pod block dispatch bit-compatibly.  Everything is
deterministic given the trace seed.

Fleet serving
-------------
:class:`~repro.online.simulator.SimConfig` scales the same event model to
an N-pod fleet with heterogeneous slice widths: a
:class:`~repro.online.router.Router` (hash / least-loaded /
fragmentation-scored) assigns each arrival a pod at its arrival instant,
and the whole dispatch path above runs per pod — claims never span pods.
``SimConfig(pods=(8,),...)`` (the default) is the single-pod cluster of
earlier PRs, bit-compatible with it.  The hash-routed fleet also runs on
the vectorized engine
(:class:`~repro.online.vecsim.VectorizedFleetSimulator`) as one vmapped
pod axis — hash routing is trace-computable, so the fleet decomposes into
independent per-pod lanes.  Both vectorized engines serve time-sharing
*and* RL plans: an :class:`~repro.online.policies.RLDispatchPolicy`'s
agent episodes run in-graph at the window-formation seam (observation
assembly + fit-masked greedy argmax, ``docs/architecture.md``), and
``sweep(param_sets=...)`` evaluates a population of agents in one device
call.

Traces ↔ paper workload mix
---------------------------
:mod:`repro.online.traces` generates arrival processes (Poisson, bursty
MMPP, diurnal, heavy-tailed job scales, fragmentation-stressing
right-sized slice requests) whose per-arrival job draw follows the paper's
§V-A2 queue recipes: ``mix="ci"|"mi"|"us"`` weights the dominant class at
50% (the CI/MI/US-dominant queue categories of Table V),
``mix="balanced"`` draws classes uniformly.  A trace is therefore the
streaming analogue of the paper's static queue families.

Arrival-aware observations
--------------------------
Every dispatch window hands the policy a
:class:`~repro.core.env.DispatchContext` — free-unit mask, per-submission
ages, pending depth at the dispatch instant.  An RL policy whose
environment has ``EnvConfig.obs_context`` set folds that snapshot into the
agent's observation (the context block of ``docs/observation.md``), so the
policy plans from *profiles + live cluster state*; all other policies, and
context-blind agents, ignore it bit-compatibly.

Re-training
-----------
:class:`~repro.online.retrain.OnlineRetrainer` hangs off the simulator's
periodic tick: every K simulated minutes it re-trains the agent on the live
repository (warm-started from current params via ``train_agent(...,
warm_start=...)``) and hot-swaps the refreshed agent into the RL dispatch
policy.  With ``trigger="drift"`` a
:class:`~repro.online.telemetry.DriftMonitor` gates each tick on
arrival-mix entropy and idle-fraction shifts instead of retraining
unconditionally.

Telemetry
---------
:mod:`repro.online.telemetry` is the observability layer
(``docs/observability.md``): pass ``telemetry=Telemetry()`` to
:class:`~repro.online.simulator.ClusterSimulator` (or ``telemetry=True``
to the vectorized engines) for lifecycle event traces (JSONL /
Perfetto-loadable Chrome trace), a streaming metrics registry, and
windowed time series via
:meth:`~repro.online.simulator.SimResult.timeseries`.  Telemetry observes
and never steers: disabled runs are bit-identical, enabled runs change no
decision.
"""
from repro.online.policies import (
    DispatchPolicy, GreedyPackerPolicy, PolicyStats, RLDispatchPolicy,
    StaticPartitionPolicy, TimeSharingPolicy,
)
from repro.online.retrain import (
    OnlineRetrainer, default_retrain_online_config,
    default_retrain_train_config,
)
from repro.online.router import (
    FleetView, FragRouter, HashRouter, LeastLoadedRouter, PodView, ROUTERS,
    Router, make_router,
)
from repro.online.simulator import (
    Arrival, ClusterSimulator, JobRecord, Segment, SimConfig, SimResult,
)
from repro.online.telemetry import (
    DriftMonitor, MetricsRegistry, PhaseTimer, Telemetry, TraceRecorder,
    WAIT_BUCKETS_S,
)
from repro.online.traces import (
    TRACE_FAMILIES, diurnal_trace, fragmented_trace, heavy_tailed_trace,
    mmpp_trace, poisson_trace,
)
from repro.online.vecsim import (
    SweepSummary, TrainRollout, VectorizedClusterSimulator,
    VectorizedFleetSimulator, make_rollout_collector,
)

__all__ = [
    "Arrival", "ClusterSimulator", "DispatchPolicy", "DriftMonitor",
    "FleetView", "FragRouter", "GreedyPackerPolicy", "HashRouter",
    "JobRecord", "LeastLoadedRouter", "MetricsRegistry", "OnlineRetrainer",
    "PhaseTimer", "PodView", "PolicyStats", "ROUTERS", "RLDispatchPolicy",
    "Router", "Segment", "SimConfig", "SimResult", "StaticPartitionPolicy",
    "SweepSummary", "TRACE_FAMILIES", "Telemetry", "TimeSharingPolicy",
    "TraceRecorder", "TrainRollout", "VectorizedClusterSimulator",
    "VectorizedFleetSimulator", "WAIT_BUCKETS_S",
    "default_retrain_online_config", "default_retrain_train_config",
    "diurnal_trace", "fragmented_trace",
    "heavy_tailed_trace", "make_rollout_collector", "make_router",
    "mmpp_trace", "poisson_trace",
]
