"""Serving launcher: batched KV-cache decode for any registry architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models.model import init_cache, init_params
from repro.runtime.steps import make_decode_step, state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.scale == "full" else get_smoke_config(args.arch)
    mesh = jax.make_mesh((args.mesh_data, args.mesh_model), ("data", "model"))
    max_len = args.gen + 1

    step_fn = make_decode_step(cfg, mesh, args.batch, max_len, donate=True)
    _, psh, _, _ = state_shardings(cfg, mesh, with_opt=False)
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(jax.random.PRNGKey(0))
    cache = init_cache(params, cfg, args.batch, max_len)

    tok = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.full((args.batch,), i)
        logits, cache = step_fn(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.gen} steps x {args.batch} seqs: "
          f"{args.gen * args.batch / dt:.1f} tok/s ({dt/args.gen*1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
