"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = weighted_collective_bytes_per_chip / ICI_bw

``cost_analysis()`` FLOPs/bytes are for the SPMD-partitioned (= per-chip)
module.  Collective bytes are parsed from the optimized HLO text: each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
contributes its *result-shape* bytes, weighted by the ring-traffic factor of
the op (all-reduce moves ~2x its payload per chip; the others ~1x).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_LINK_BW = 50e9           # bytes/s per link
ICI_LINKS_PER_AXIS = 2       # bidirectional ring on one mesh axis
ICI_BW = ICI_LINK_BW * ICI_LINKS_PER_AXIS
HBM_BYTES = 16 * 1024**3     # 16 GiB HBM per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# result shapes like `bf16[8,128,512]{2,1,0}` or tuple `(f32[4], bf16[8,16])`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\]{},\d]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# Opcodes whose operands/results cross HBM on TPU (everything else is assumed
# fused into these by the TPU backend; XLA:CPU's raw "bytes accessed" counts
# every unfused elementwise op and overstates HBM traffic by orders of
# magnitude — both figures are recorded).
_MAJOR_OPS = {
    "dot", "convolution", "gather", "scatter", "sort", "reduce",
    "reduce-window", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "fusion", "custom-call", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "copy",
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},])+)\s+([\w-]+)")
_OPERAND_RE = re.compile(r"%([\w.-]+)")


def fusion_adjusted_bytes(hlo_text: str) -> float:
    """Estimate per-chip HBM traffic assuming TPU-style fusion: sum operand +
    result bytes over major (unfusable) ops only."""
    shapes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = _shape_bytes(m.group(2))
    total = 0.0
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = op.split(".")[0]
        if base.endswith("-start") or base.endswith("-done"):
            base = base.rsplit("-", 1)[0]
        if base not in _MAJOR_OPS:
            continue
        res_bytes = _shape_bytes(m.group(2))
        arg_str = line[m.end():]
        arg_bytes = sum(shapes.get(nm, 0) for nm in _OPERAND_RE.findall(arg_str))
        total += res_bytes + arg_bytes
    return total


@dataclass
class CollectiveStats:
    bytes_weighted: float = 0.0
    bytes_raw: float = 0.0
    count: int = 0
    by_op: dict = field(default_factory=dict)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective result bytes from optimized (or stable-) HLO text."""
    stats = CollectiveStats()
    seen_done: set[str] = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        # async pairs appear as -start/-done with identical shapes; count once
        tag = f"{op}:{m.start()}"
        if "-done(" in m.group(0):
            continue  # the -start carries the payload shape
        b = _shape_bytes(shape_str)
        w = _COLLECTIVE_WEIGHT[op]
        stats.bytes_raw += b
        stats.bytes_weighted += b * w
        stats.count += 1
        agg = stats.by_op.setdefault(op, {"bytes": 0.0, "count": 0})
        agg["bytes"] += b
        agg["count"] += 1
        _ = tag, seen_done
    return stats


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_weighted: float) -> dict:
    ct = flops_per_chip / PEAK_FLOPS
    mt = bytes_per_chip / HBM_BW
    xt = coll_bytes_weighted / ICI_BW
    dominant = max(("compute", ct), ("memory", mt), ("collective", xt), key=lambda kv: kv[1])
    total = max(ct, mt, xt)
    return {
        "compute_term_s": ct,
        "memory_term_s": mt,
        "collective_term_s": xt,
        "dominant": dominant[0],
        "step_time_lb_s": total,  # overlap roofline: max of the three
    }


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (useful work) per cell — 6ND convention
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """6*N_active*D for train (3x fwd), 2*N_active per token for inference,
    plus the attention quadratic term; embeddings excluded from N."""
    n_active = cfg.n_active_params()
    emb = cfg.vocab_size * cfg.d_model
    n_body = n_active - emb - (0 if cfg.tie_embeddings else emb)
    logits_per_tok = 2 * cfg.vocab_size * cfg.d_model

    # attention layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
    elif cfg.family == "ssm":
        n_attn = 0
    elif cfg.enc_dec:
        n_attn = cfg.n_enc_layers + 2 * cfg.n_layers
    else:
        n_attn = cfg.n_layers

    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        tokens = B * S
        # causal fwd attn flops per layer: 2 * B * S^2 * Hq * Dh  (qk + pv, /2 causal)
        attn_fwd = 2.0 * B * S * S * cfg.n_heads * cfg.d_head * n_attn
        mult = 3.0 if shape.kind == "train" else 1.0
        body = 2.0 * n_body * tokens * mult
        logits = logits_per_tok * tokens * (mult if shape.kind == "train" else 1.0)
        return body + logits + attn_fwd * mult
    # decode: one token per sequence against an S-long cache
    tokens = B
    attn = 4.0 * B * S * cfg.n_kv_heads * cfg.d_head * n_attn  # qk + pv over cache
    return 2.0 * n_active * tokens + logits_per_tok * tokens + attn


def _n_attn_layers(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        return 0
    if cfg.enc_dec:
        return cfg.n_layers
    return cfg.n_layers


def model_bytes_min(cfg, shape) -> float:
    """Realistic minimum HBM traffic per step (fused-TPU assumption).

    train:   params bf16 fwd+bwd reads + grad write + optimizer state r/w
             (~30 B/param) + activation streams: ~10 (B,S,M)-sized tensors
             per layer per pass x 3 passes (fwd, remat re-fwd, bwd).
    prefill: params once + 10-tensor activation stream x 1 pass.
    decode:  active params once + KV/state cache read + MoE expert reads.
    """
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    layers = max(1, cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0))
    act_stream = 10.0 * 2.0 * cfg.d_model * layers  # bytes per token per pass

    if shape.kind == "train":
        pbytes = 30.0 * n_active
        return pbytes + 3.0 * act_stream * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active + act_stream * tokens
    # decode
    B, S = shape.global_batch, shape.seq_len
    pbytes = 2.0 * n_active
    kv = 2.0 * B * S * cfg.n_kv_heads * cfg.d_head * _n_attn_layers(cfg) * 2
    moe = 0.0
    if cfg.moe is not None:
        m = cfg.moe
        touched = min(m.n_routed, B * m.top_k)
        moe = (cfg.n_layers // m.every) * touched * 3.0 * cfg.d_model * m.d_expert * 2
        pbytes = 2.0 * (n_active - cfg.n_active_params() + n_active)  # keep params term
    if cfg.family in ("hybrid", "ssm"):
        # recurrent state r/w per step
        if cfg.mamba is not None:
            d_in = cfg.mamba.expand * cfg.d_model
            n_mamba = cfg.n_layers - _n_attn_layers(cfg)
            kv += 2.0 * B * d_in * cfg.mamba.d_state * 4 * n_mamba
        if cfg.xlstm is not None:
            dh = int(cfg.xlstm.expand_m * cfg.d_model) // cfg.n_heads
            kv += 2.0 * B * cfg.n_heads * dh * dh * 4 * (cfg.n_layers // 2)
    return pbytes + kv + moe


def model_coll_bytes_chip(cfg, shape, chips: int = 256, tp: int = 16) -> float:
    """Analytic per-chip weighted collective bytes per step under the baseline
    TP(model axis) x FSDP(data axis) rules — used when no dry-run record backs
    a profile. Matches the measured structure: per-layer activation
    all-reduces (x2 ring weight) + FSDP param all-gather/grad reduce-scatter."""
    dp = max(1, chips // tp)
    tokens = shape.global_batch * shape.seq_len
    layers = max(1, cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0))
    if shape.kind == "train":
        act = tokens // dp * cfg.d_model * 2            # one (B/dp, S, M) bf16
        ar = 4.0 * layers * act * 2.0                   # 2 fwd + 2 bwd ARs, ring x2
        fsdp = 3.0 * 2.0 * cfg.n_active_params() / tp   # AG fwd+bwd + RS grads (bf16)
        return ar + fsdp
    if shape.kind == "prefill":
        act = tokens // dp * cfg.d_model * 2
        return 2.0 * layers * act * 2.0
    # decode: tiny activations, per-layer AR of (B, M)
    act = shape.global_batch * cfg.d_model * 2
    return 2.0 * layers * act * 2.0
