"""Mesh construction: production pod / multi-pod meshes + scheduler slices.

Functions (not module constants) so importing never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256-chip pod; multi_pod stacks 2 pods on a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2) -> Mesh:
    """Small host-device mesh for CPU tests (requires enough host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def slice_mesh(mesh: Mesh, lo_row: int, hi_row: int) -> Mesh:
    """Rectangular sub-slice of a ("data","model") pod mesh along the data axis.

    This is the Level-1 *physical* partition (DESIGN.md §2): the returned
    sub-mesh owns its chips (compute + HBM) and intra-slice ICI exclusively.
    Cutting the torus breaks the wraparound link on the data axis — the perf
    model charges `torus_factor = 1/2` on that axis for split slices.
    """
    devices = np.asarray(mesh.devices)
    assert devices.ndim == 2, "slice_mesh expects a single-pod (data, model) mesh"
    assert 0 <= lo_row < hi_row <= devices.shape[0]
    return Mesh(devices[lo_row:hi_row, :], ("data", "model"))


def slice_meshes(mesh: Mesh, widths: list[int]) -> list[Mesh]:
    """Partition the pod's data axis into contiguous slices of `widths` rows."""
    assert sum(widths) <= np.asarray(mesh.devices).shape[0]
    out, lo = [], 0
    for w in widths:
        out.append(slice_mesh(mesh, lo, lo + w))
        lo += w
    return out
