"""Co-scheduler launcher (the paper's online phase as a CLI):

    PYTHONPATH=src python -m repro.launch.schedule --episodes 2000 --window 12

Trains (or loads) the DQN agent over the job zoo, schedules the Q1..Q12
queues, and prints the five-method comparison (paper Fig. 8).
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=2000)
    ap.add_argument("--window", type=int, default=12)
    ap.add_argument("--c-max", type=int, default=4)
    ap.add_argument("--per-kind", type=int, default=3)
    args = ap.parse_args()

    from benchmarks.common import get_zoo, trained_agent
    from repro.core import POLICIES, RLScheduler, paper_queues, summarize, validate_schedule

    zoo = get_zoo()
    agent, env_cfg = trained_agent(zoo, args.window, args.c_max, episodes=args.episodes)
    sched = RLScheduler(agent, env_cfg)
    queues = paper_queues(zoo, window=args.window, per_kind=args.per_kind)

    methods = ["time_sharing", "mig_only", "mps_only", "mig_mps_default", "rl", "oracle"]
    table = {m: [] for m in methods}
    for qname, queue in queues.items():
        for m in methods:
            s = sched.schedule(queue) if m == "rl" else POLICIES[m](queue, args.c_max)
            if m == "rl":
                validate_schedule(queue, s, args.c_max)
            table[m].append(summarize(s)["throughput"])
    print(f"{'method':18s} " + " ".join(f"{q:>6s}" for q in queues) + "    AM   max")
    for m in methods:
        row = table[m]
        print(f"{m:18s} " + " ".join(f"{v:6.3f}" for v in row) +
              f" {np.mean(row):6.3f} {np.max(row):5.3f}")


if __name__ == "__main__":
    main()
