"""Production training launcher: mesh + sharded train_step + data + elastic
checkpointing, for any registry architecture.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 100 \
        --mesh-data 1 --mesh-model 1 --batch 8 --seq 128 --scale smoke

On a real pod, run with --mesh-data 16 --mesh-model 16 --scale full under the
TPU runtime; on CPU this drives the same code path at reduced scale (the
mesh collapses to available devices).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ck
from repro.configs import ShapeConfig, get_config, get_smoke_config
from repro.data import DataPipeline
from repro.models.model import init_params
from repro.optim import OptConfig, init_opt_state
from repro.runtime.steps import make_train_step, state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.scale == "full" else get_smoke_config(args.arch)
    mesh = jax.make_mesh((args.mesh_data, args.mesh_model), ("data", "model"))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"batch={args.batch} seq={args.seq}")

    step_fn = make_train_step(cfg, OptConfig(), mesh, donate=True)
    _, psh, _, osh = state_shardings(cfg, mesh)
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(jax.random.PRNGKey(0))
    opt = jax.jit(init_opt_state, out_shardings=osh)(params)

    pipe = DataPipeline(cfg.vocab_size, args.seq, args.batch, seed=0, mode="markov")
    start = 0
    if args.ckpt_dir:
        try:
            tree, _, start = ck.restore(args.ckpt_dir)
            params = jax.device_put(tree["params"], psh)
            opt = jax.device_put(tree["opt"], osh)
            print(f"resumed @ {start}")
        except FileNotFoundError:
            pass

    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss={float(metrics['loss']):.3f} "
                  f"({(s - start + 1) / (time.time() - t0):.2f} it/s)")
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, s + 1, {"params": jax.device_get(params),
                                           "opt": jax.device_get(opt)})
    print("done")


if __name__ == "__main__":
    main()
