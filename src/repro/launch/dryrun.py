import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax-touching import: jax locks the
device count at first backend init, and the dry-run needs 512 placeholder
host devices to build the production meshes (16x16 single-pod, 2x16x16
multi-pod).  Smoke tests and benchmarks must NOT import this module.

Cost-extraction protocol (3 compiles per cell)
----------------------------------------------
XLA's HloCostAnalysis visits a while-loop body ONCE, so the layer-scanned
module under-reports FLOPs/bytes/collectives by ~the stack depth.  We
therefore compile:
  A. the full scanned module  -> memory_analysis (trip-count independent),
     compile-time proof, collective *schedule*;
  B. an unrolled 2-scan-unit variant and
  C. an unrolled 1-scan-unit variant -> exact per-unit costs by differencing:
     total = C + (B - C) * (n_units - 1).
Unrolled variants also python-loop the inner chunk scans (mamba/mLSTM), so
every FLOP is visible.  The sLSTM per-token scan stays a lax.scan (a 32k-step
python loop is not lowerable); its cost is latency- not FLOP-bound and is
handled analytically in the §Roofline notes.

Per cell this prints/records:
  * compiled.memory_analysis()   -- proves the sharded program fits HBM
  * compiled.cost_analysis()     -- per-chip FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO (corrected per-unit)
Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import dataclasses
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import all_cells, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    HBM_BYTES,
    PEAK_FLOPS,
    fusion_adjusted_bytes,
    model_bytes_min,
    model_flops,
    parse_collectives,
    roofline_terms,
)
from repro.runtime.steps import (
    abstract_state,
    batch_specs,
    cache_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.optim import OptConfig
from repro.sharding import FSDP_SP_RULES, SEQ_PARALLEL_RULES

RULESETS = {"baseline": None, "sp": SEQ_PARALLEL_RULES, "fsdp_sp": FSDP_SP_RULES}


# ---------------------------------------------------------------------------
# Scan-unit helpers (cost extraction)
# ---------------------------------------------------------------------------

def scan_units(cfg) -> int:
    """Length of the layer-stack scan (the trip count cost analysis misses)."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        return cfg.n_layers // 2
    return cfg.n_layers  # dense/moe/vlm; enc-dec scales enc+dec together


def with_scan_units(cfg, u: int):
    """Unrolled cost-variant config with `u` scan units."""
    kw: dict = {"unroll_layers": True}
    if cfg.family == "hybrid":
        kw["n_layers"] = u * cfg.attn_every
    elif cfg.family == "ssm":
        kw["n_layers"] = u * 2
    else:
        kw["n_layers"] = u
        if cfg.enc_dec:
            kw["n_enc_layers"] = u
    # unrolled variants python-loop the inner chunk scans too; bound the
    # number of unrolled chunks (compile time) with a larger chunk length —
    # FLOPs/bytes per chunk are length-linear, so costs are unchanged.
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, chunk=4096)
    if cfg.xlstm is not None:
        # mLSTM intra-chunk work is quadratic in chunk length: 512 keeps the
        # unrolled module small at a bounded (~2x at 256->512) overstatement
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=512)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def input_specs(cfg, shape, mesh, rules=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell:
    weak-type-correct, shardable, no device allocation."""
    if shape.kind == "train":
        batch, _ = batch_specs(cfg, shape, mesh, rules)
        params, opt = abstract_state(cfg)
        return {"params": params, "opt_state": opt, "batch": batch}
    if shape.kind == "prefill":
        params, _ = abstract_state(cfg, with_opt=False)
        if cfg.enc_dec:
            Se = min(cfg.enc_len, shape.seq_len)
            return {
                "params": params,
                "frames": jax.ShapeDtypeStruct(
                    (shape.global_batch, Se, cfg.d_model), jnp.dtype(cfg.dtype)),
                "enc_lens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            }
        return {
            "params": params,
            "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32),
        }
    params, _ = abstract_state(cfg, with_opt=False)
    cache, _ = cache_shardings(cfg, mesh, shape.global_batch, shape.seq_len)
    vec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return {"params": params, "cache": cache, "token": vec, "pos": vec}


def lower_cell(cfg, shape, mesh, rules=None):
    specs = input_specs(cfg, shape, mesh, rules)
    if shape.kind == "train":
        fn = make_train_step(cfg, OptConfig(), mesh, rules)
        return fn.lower(specs["params"], specs["opt_state"], specs["batch"])
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, mesh, shape, rules)
        if cfg.enc_dec:
            return fn.lower(specs["params"], specs["frames"], specs["enc_lens"])
        return fn.lower(specs["params"], specs["tokens"])
    fn = make_decode_step(cfg, mesh, shape.global_batch, shape.seq_len, rules)
    return fn.lower(specs["params"], specs["cache"], specs["token"], specs["pos"])


def _compile_costs(cfg, shape, mesh, rules):
    """(flops, bytes, collectives, compiled) for one lowering."""
    lowered = lower_cell(cfg, shape, mesh, rules)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "bytes_fused": fusion_adjusted_bytes(hlo),
        "coll_w": coll.bytes_weighted,
        "coll_raw": coll.bytes_raw,
        "coll_count": coll.count,
        "coll_by_op": coll.by_op,
    }
    return out, compiled


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_id: str, mesh_kind: str = "pod", rules_name: str = "baseline",
             verbose: bool = True, cfg_override=None, cost_extract: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rules = RULESETS[rules_name]
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_id)
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_kind, "rules": rules_name,
           "chips": mesh.size, "kind": shape.kind, "ok": False}
    t0 = time.time()
    try:
        # --- A: full scanned module (memory + compile proof) ----------------
        lowered = lower_cell(cfg, shape, mesh, rules)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec.update(
            argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
            output_bytes=getattr(mem, "output_size_in_bytes", 0),
            temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        )
        rec["peak_bytes"] = rec["argument_bytes"] + rec["temp_bytes"]
        rec["fits_hbm"] = bool(rec["peak_bytes"] < HBM_BYTES)
        rec["hbm_limit"] = HBM_BYTES
        del compiled, lowered
        gc.collect()

        if not cost_extract:
            # multi-pod proof run: compile success + memory only (the
            # roofline table is single-pod per EXPERIMENTS.md §Roofline)
            rec["ok"] = True
            if verbose:
                print(f"== {arch} x {shape_id} x {mesh_kind} [{rules_name}] ==", flush=True)
                print(f"  memory_analysis: args={rec['argument_bytes']/1e9:.2f}GB "
                      f"temp={rec['temp_bytes']/1e9:.2f}GB fits16GiB={rec['fits_hbm']} "
                      f"(compile {rec['compile_s']}s)", flush=True)
            return rec

        # --- B/C: unrolled cost variants -------------------------------------
        L = scan_units(cfg)
        c1, comp1 = _compile_costs(with_scan_units(cfg, 1), shape, mesh, rules)
        del comp1
        gc.collect()
        if L > 1:
            c2, comp2 = _compile_costs(with_scan_units(cfg, 2), shape, mesh, rules)
            del comp2
            gc.collect()
        else:
            c2 = c1
        def lin(key):
            return c1[key] + (c2[key] - c1[key]) * (L - 1)

        flops = lin("flops")
        byts = lin("bytes_fused")
        coll_w = lin("coll_w")
        rec.update(
            ok=True,
            scan_units=L,
            flops_per_chip=flops,
            bytes_per_chip=byts,
            bytes_per_chip_raw_cpu=lin("bytes"),
            coll_bytes_weighted=coll_w,
            coll_bytes_raw=lin("coll_raw"),
            coll_count_unit=c2["coll_count"] - c1["coll_count"],
            coll_by_op_u1=c1["coll_by_op"],
            coll_by_op_u2=c2["coll_by_op"],
        )
        rec.update(roofline_terms(flops, byts, coll_w))
        mf = model_flops(cfg, shape)
        rec["model_flops_total"] = mf
        rec["model_flops_per_chip"] = mf / mesh.size
        rec["useful_flops_ratio"] = rec["model_flops_per_chip"] / flops if flops else 0.0
        rec["model_bytes_min_total"] = model_bytes_min(cfg, shape)
        rec["roofline_fraction"] = (
            (rec["model_flops_per_chip"] / PEAK_FLOPS) / rec["step_time_lb_s"]
            if rec["step_time_lb_s"] > 0 else 0.0
        )

        if verbose:
            print(f"== {arch} x {shape_id} x {mesh_kind} [{rules_name}] ==", flush=True)
            print(f"  memory_analysis: args={rec['argument_bytes']/1e9:.2f}GB "
                  f"temp={rec['temp_bytes']/1e9:.2f}GB out={rec['output_bytes']/1e9:.2f}GB "
                  f"fits16GiB={rec['fits_hbm']}")
            print(f"  cost_analysis (corrected x{L}): flops/chip={flops:.3e} "
                  f"bytes/chip={byts:.3e} (raw-cpu {rec['bytes_per_chip_raw_cpu']:.3e})")
            print(f"  collectives: weighted={coll_w/1e9:.3f}GB raw={rec['coll_bytes_raw']/1e9:.3f}GB")
            print(f"  roofline: compute={rec['compute_term_s']*1e3:.3f}ms "
                  f"memory={rec['memory_term_s']*1e3:.3f}ms "
                  f"collective={rec['collective_term_s']*1e3:.3f}ms "
                  f"dominant={rec['dominant']} useful_ratio={rec['useful_flops_ratio']:.3f} "
                  f"roofline_frac={rec['roofline_fraction']:.3f}", flush=True)
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"== {arch} x {shape_id} x {mesh_kind} FAILED: {rec['error']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--rules", default="baseline", choices=list(RULESETS))
    ap.add_argument("--all", action="store_true", help="sweep all runnable cells")
    ap.add_argument("--resume", action="store_true", help="skip cells with ok records")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for c in all_cells():
            if c.runnable:
                cells.append((c.arch, c.shape))
            else:
                print(f"SKIP {c.arch} x {c.shape}: {c.skip}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_fail = 0
    for arch, shape_id in cells:
        for mesh_kind in meshes:
            tag = f"{arch}_{shape_id}_{mesh_kind}_{args.rules}".replace(".", "_").replace("/", "_")
            out_path = os.path.join(args.out, tag + ".json")
            if args.resume and os.path.exists(out_path):
                with open(out_path) as f:
                    if json.load(f).get("ok"):
                        continue
            rec = run_cell(arch, shape_id, mesh_kind, args.rules,
                           cost_extract=(mesh_kind == "pod"))
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
            n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete: {len(cells) * len(meshes) - n_fail} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
