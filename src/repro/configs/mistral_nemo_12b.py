"""mistral-nemo-12b — dense GQA decoder, 128k context, head_dim 128 (< d_model/H).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5_120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,          # explicit head_dim (not d_model // n_heads = 160)
    d_ff=14_336,
    vocab_size=131_072,
    qkv_bias=False,
    rope_theta=1_000_000.0,
)

SMOKE = FULL.replace(
    name="mistral-nemo-12b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=8,            # keep the d_head != d_model//n_heads property
    d_ff=128,
    vocab_size=256,
)
