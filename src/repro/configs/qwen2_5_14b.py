"""qwen2.5-14b — dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5_120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13_824,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = FULL.replace(
    name="qwen2.5-14b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
)
