"""xlstm-125m — sLSTM + mLSTM block stack (d_ff=0: FFN lives inside blocks).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, XLSTMCfg

FULL = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_head=192,
    d_ff=0,                    # no separate FFN: m/s blocks carry up-projections
    vocab_size=50_304,
    qkv_bias=False,
    rope_theta=0.0,            # recurrence provides position information
    xlstm=XLSTMCfg(pattern="ms", expand_m=2.0, proj_factor_s=4.0 / 3.0),
    sub_quadratic=True,
)

SMOKE = FULL.replace(
    name="xlstm-125m-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    vocab_size=256,
    xlstm=XLSTMCfg(pattern="ms", expand_m=2.0, proj_factor_s=4.0 / 3.0, chunk=16),
)
