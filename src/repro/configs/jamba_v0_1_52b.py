"""jamba-v0.1-52b — hybrid Mamba+attention (1:7 interleave), MoE 16e top-2.
[arXiv:2403.19887; hf]

Structure per the Jamba paper: blocks of 8 layers with one attention layer at
block offset 4 (attn:mamba = 1:7); MoE replaces the dense MLP every 2nd layer.
"""
from repro.configs.base import MambaCfg, ModelConfig, MoECfg

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab_size=65_536,
    qkv_bias=False,
    rope_theta=0.0,            # Jamba uses no positional encoding (Mamba provides it)
    moe=MoECfg(n_routed=16, top_k=2, n_shared=0, d_expert=14_336, every=2),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    sub_quadratic=True,        # decode state is O(1)/token for 7/8 of layers
)

SMOKE = FULL.replace(
    name="jamba-v0.1-52b-smoke",
    n_layers=8,                # one full jamba super-block
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    moe=MoECfg(n_routed=4, top_k=2, n_shared=0, d_expert=128, every=2),
    mamba=MambaCfg(d_state=8, d_conv=4, expand=2, chunk=16),
    attn_every=8,
)
