from repro.configs.base import SHAPES, MambaCfg, ModelConfig, MoECfg, ShapeConfig, XLSTMCfg, scaled_shape
from repro.configs.registry import (
    ARCH_IDS,
    SHAPE_IDS,
    Cell,
    all_cells,
    get_config,
    get_shape,
    get_smoke_config,
    runnable_cells,
)

__all__ = [
    "SHAPES",
    "ARCH_IDS",
    "SHAPE_IDS",
    "Cell",
    "MambaCfg",
    "ModelConfig",
    "MoECfg",
    "ShapeConfig",
    "XLSTMCfg",
    "all_cells",
    "get_config",
    "get_shape",
    "get_smoke_config",
    "runnable_cells",
    "scaled_shape",
]
