"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoECfg

FULL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2_048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1_408,              # fine-grained expert hidden size
    vocab_size=102_400,
    qkv_bias=False,
    rope_theta=10_000.0,
    moe=MoECfg(n_routed=64, top_k=6, n_shared=2, d_expert=1_408, every=1),
)

SMOKE = FULL.replace(
    name="deepseek-moe-16b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=96,
    vocab_size=256,
    moe=MoECfg(n_routed=8, top_k=2, n_shared=2, d_expert=96, every=1),
)
