"""Architecture registry: ``--arch <id>`` lookup, cell enumeration, skips."""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

# arch id -> module path (one module per assigned architecture)
_ARCH_MODULES: dict[str, str] = {
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "llama3-8b": "repro.configs.llama3_8b",
    "command-r-35b": "repro.configs.command_r_35b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)
SHAPE_IDS: tuple[str, ...] = tuple(SHAPES)


def get_config(arch: str) -> ModelConfig:
    """Full (production) config for an assigned architecture id."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).FULL


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).SMOKE


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]


@dataclass(frozen=True)
class Cell:
    """One (architecture x input-shape) dry-run cell."""

    arch: str
    shape: str
    skip: str = ""               # non-empty -> documented skip reason

    @property
    def runnable(self) -> bool:
        return not self.skip


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Documented skip logic (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "pure full-attention arch: 500k-token decode requires a "
            "sub-quadratic path (run only for SSM/hybrid archs)"
        )
    return ""


def all_cells() -> list[Cell]:
    """The 40 assigned (arch x shape) cells, with skip annotations."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_id in SHAPE_IDS:
            cells.append(Cell(arch, shape_id, cell_skip_reason(cfg, SHAPES[shape_id])))
    return cells


def runnable_cells() -> list[Cell]:
    return [c for c in all_cells() if c.runnable]
