"""seamless-m4t-large-v2 — encoder-decoder multimodal (audio) backbone.
[arXiv:2308.11596; hf]

The speech frontend (w2v-BERT feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings of shape (batch, enc_len, d_model).
24 encoder + 24 decoder layers (the assigned 24L is interpreted per side,
matching the seamless large text-decoder depth).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder layers
    n_enc_layers=24,           # encoder layers
    d_model=1_024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8_192,
    vocab_size=256_206,
    qkv_bias=True,
    enc_dec=True,
    enc_len=4_096,             # encoder frames for decode shapes (speech ~ downsampled)
    rope_theta=10_000.0,
)

SMOKE = FULL.replace(
    name="seamless-m4t-large-v2-smoke",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    enc_len=16,
)
