"""Config dataclasses for the model zoo and workload shapes.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  Configs are frozen dataclasses so
they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts block configuration (shared + routed experts)."""

    n_routed: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0            # expert FFN hidden size
    every: int = 1               # MoE replaces dense MLP every `every` layers
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3  # router z-loss coefficient
    aux_coef: float = 1e-2       # load-balance auxiliary loss coefficient
    impl: str = "gspmd"          # "gspmd" (sharding-constraint) | "ep" (shard_map all_to_all)


@dataclass(frozen=True)
class MambaCfg:
    """Mamba-1 selective SSM configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model/16)
    chunk: int = 256             # chunked-scan block length (train/prefill)


@dataclass(frozen=True)
class XLSTMCfg:
    """xLSTM block stack configuration (pattern of mLSTM / sLSTM blocks)."""

    pattern: str = "ms"          # repeated over the depth: m = mLSTM, s = sLSTM
    expand_m: float = 2.0        # mLSTM pre-up-projection factor
    proj_factor_s: float = 4.0 / 3.0  # sLSTM post-up-projection factor
    d_conv: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (decoder-only LM unless ``enc_dec``)."""

    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 1_000_000.0
    moe: MoECfg | None = None
    mamba: MambaCfg | None = None
    attn_every: int = 0          # hybrid: 1 attention layer per `attn_every` layers
    xlstm: XLSTMCfg | None = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 0             # encoder frame length used with decode shapes
    sub_quadratic: bool = False  # supports long-context decode (SSM/hybrid)
    remat: str = "block"         # "none" | "block" (checkpoint each layer block)
    attn_impl: str = "auto"      # "auto" | "kernel" | "ref"
    dtype: str = "bfloat16"
    # Perf knobs (hillclimbing levers; defaults = paper-faithful baseline).
    seq_parallel: bool = False   # Megatron-SP style activation sharding
    fused_qkv: bool = True
    # Dry-run cost-extraction mode: python-loop the layer stack instead of
    # lax.scan so XLA cost analysis sees every layer (scan bodies are counted
    # once). Never used for real execution.
    unroll_layers: bool = False

    # -- derived helpers ---------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    """A workload cell: sequence length x global batch x step kind."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


# The four assigned input shapes (identical across the LM family).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def scaled_shape(shape: ShapeConfig, batch_div: int = 1, seq_div: int = 1) -> ShapeConfig:
    """Reduced variant of a shape (smoke tests / scheduler job variants)."""

    return ShapeConfig(
        name=f"{shape.name}_d{batch_div}x{seq_div}",
        seq_len=max(8, shape.seq_len // seq_div),
        global_batch=max(1, shape.global_batch // batch_div),
        kind=shape.kind,
    )
