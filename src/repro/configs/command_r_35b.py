"""command-r-35b — dense GQA decoder, no-bias, 256k vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8_192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22_528,
    vocab_size=256_000,
    qkv_bias=False,
    tie_embeddings=True,   # command-r ties input/output embeddings
    rope_theta=8_000_000.0,
)

SMOKE = FULL.replace(
    name="command-r-35b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
)
