"""llama3-8b — dense GQA decoder, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab_size=128_256,
    qkv_bias=False,
    rope_theta=500_000.0,
)

SMOKE = FULL.replace(
    name="llama3-8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
)
