"""qwen2-moe-a2.7b — MoE: 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ModelConfig, MoECfg

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2_048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1_408,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoECfg(n_routed=60, top_k=4, n_shared=4, d_expert=1_408, every=1),
)

SMOKE = FULL.replace(
    name="qwen2-moe-a2.7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=96,
    vocab_size=256,
    moe=MoECfg(n_routed=6, top_k=2, n_shared=2, d_expert=96, every=1),
)
