"""chameleon-34b — early-fusion VLM backbone; VQ image tokens share the vocab.
[arXiv:2405.09818; unverified]

The modality frontend (VQ-GAN tokenizer) is a STUB: ``input_specs`` provides
token ids that already include the image-token id range. The backbone is a
dense GQA decoder (Chameleon uses QK-norm for stability; modeled here).
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8_192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22_016,
    vocab_size=65_536,
    qkv_bias=False,
    rope_theta=10_000.0,
)

SMOKE = FULL.replace(
    name="chameleon-34b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
)
