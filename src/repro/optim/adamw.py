"""AdamW with fp32 master weights, global-norm clipping, warmup+cosine LR.

Pure pytree transform (no optax): optimizer state inherits parameter
shardings (master/m/v are spec'd identically to params by the runtime), which
is what makes the ZeRO-style FSDP sharding of optimizer state fall out of the
parameter spec tree for free.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> dict:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics). Params keep their dtype."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, count)

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        wd = cfg.weight_decay if master.ndim >= 2 else 0.0  # no decay on norms/biases
        master = master - lr * (update + wd * master)
        return master, m, v

    new = jax.tree.map(upd, state["master"], grads, state["m"], state["v"])
    master = jax.tree.map(lambda t: t[0], new, is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], new, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], new, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda ms, p: ms.astype(p.dtype), master, params)
    new_state = {"master": master, "m": m, "v": v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
