from repro.optim.adamw import OptConfig, adamw_update, global_norm, init_opt_state, lr_at

__all__ = ["OptConfig", "adamw_update", "global_norm", "init_opt_state", "lr_at"]
