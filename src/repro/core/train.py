"""Offline RL training (paper §IV-B): random queues over the zoo, ε-greedy
exploration, dueling double-DQN updates; held-out jobs excluded (paper's
unseen-application generalization test)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import CoScheduleEnv, EnvConfig
from repro.core.metrics import relative_throughput
from repro.core.profiles import JobProfile
from repro.core.scheduler import RLScheduler
from repro.core.workloads import QUEUE_KINDS, make_queue


@dataclass
class TrainConfig:
    episodes: int = 3000
    updates_per_step: int = 1
    n_train_queues: int = 20            # paper: 20 random queues for training
    seed: int = 0
    eval_every: int = 100
    dqn: DQNConfig = field(default_factory=DQNConfig)


def heldout_split(jobs: list[JobProfile], frac: float = 0.33, seed: int = 7):
    """Paper: mark ~1/3 of programs as unseen (*) — excluded from training."""
    rng = np.random.default_rng(seed)
    by_cls: dict[str, list[JobProfile]] = {}
    for j in jobs:
        by_cls.setdefault(j.job_class, []).append(j)
    held: set[str] = set()
    for cls, pool in by_cls.items():
        k = max(1, int(len(pool) * frac)) if len(pool) > 1 else 0
        idx = rng.permutation(len(pool))[:k]
        held.update(pool[i].name for i in idx)
    return held


def train_agent(jobs: list[JobProfile], env_cfg: EnvConfig | None = None,
                cfg: TrainConfig | None = None, heldout: set[str] | None = None,
                verbose: bool = False) -> tuple[DQNAgent, list[dict]]:
    cfg = cfg or TrainConfig()
    env_cfg = env_cfg or EnvConfig()
    env = CoScheduleEnv(env_cfg)
    agent = DQNAgent(env.state_dim, env.n_actions, cfg.dqn, seed=cfg.seed)
    rng = np.random.default_rng(cfg.seed)
    heldout = heldout if heldout is not None else heldout_split(jobs)

    # 20 fixed training queues, all classes represented (paper §V-A2)
    train_queues = [
        make_queue(jobs, QUEUE_KINDS[i % len(QUEUE_KINDS)], env_cfg.window, rng,
                   exclude=heldout)
        for i in range(cfg.n_train_queues)
    ]

    history: list[dict] = []
    for ep in range(cfg.episodes):
        queue = train_queues[int(rng.integers(0, len(train_queues)))]
        state, mask = env.reset(queue)
        ep_reward = 0.0
        while not env.done:
            action = agent.act(state, mask)
            s2, r, done, mask2, _ = env.step(action)
            agent.observe(state, action, r, s2, done, mask2)
            state, mask = s2, mask2
            ep_reward += r
            for _ in range(cfg.updates_per_step):
                agent.update()
        if (ep + 1) % cfg.eval_every == 0 or ep == cfg.episodes - 1:
            sched = RLScheduler(agent, env_cfg).schedule(train_queues[0])
            rec = {"episode": ep + 1, "eps": agent.epsilon, "ep_reward": ep_reward,
                   "eval_throughput": relative_throughput(sched)}
            history.append(rec)
            if verbose:
                print(f"ep {ep+1:5d} eps={agent.epsilon:.3f} "
                      f"reward={ep_reward:8.1f} eval_tp={rec['eval_throughput']:.3f}")
    return agent, history
