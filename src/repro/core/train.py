"""Offline RL training (paper §IV-B) on a vectorized pure-functional engine.

``train_agent`` drives B parallel environments through a single jitted
``lax.scan``: vmapped ε-greedy action selection, batched ``EnvState.step``
transitions, pushes into the on-device replay ring, and interleaved
double-DQN updates all live in one compiled program — no per-step Python
dispatch.  With ``cfg.per_alpha > 0`` the ring is a sum-tree prioritized
buffer (``repro.core.replay``): stratified proportional sampling, IS
weights (β annealed alongside ε) inside the loss, and |TD|-driven priority
refresh, all threaded through the scan carry.  Episodes auto-reset inside
the scan; the driver peels off segments of ~``eval_every`` episodes, runs
the greedy evaluation rollout — itself a jitted ``step_batch`` scan over
*every* train queue at once, with co-run/solo times accumulated from the
in-graph perfmodel, so a training run never leaves device between
segments — and emits history records with the same keys as the original
loop.  Record semantics are segment-granular: ``episode`` is the cumulative
completed-episode count when the record was taken (it can overshoot
``cfg.episodes`` by up to one segment), ``ep_reward`` is the mean return
of the episodes completed in that segment, and ``eval_throughput`` is the
mean relative throughput over the train queues (previously: queue 0 only,
via the scalar reference env).  Each record also carries
``heldout_throughput`` — the same greedy metric over a second stacked batch
of queues drawn *only* from the held-out (unseen) jobs, the paper's
generalization test — evaluated in the same jitted ``step_batch`` rollout
(the two batches are concatenated along the queue axis).  When no held-out
jobs exist (e.g. re-training on a live profile repository with
``heldout=set()``) the field is ``None``.

``train_agent(..., warm_start=agent)`` seeds the engine from an existing
agent's online/target params and optimizer state instead of a fresh
initialization — the MISO-style periodic re-training entry point
(``repro.online.retrain``): exploration (``env_steps``) restarts at zero so
``cfg.dqn``'s ε schedule governs the refresh, but the Q-function continues
from where the previous cycle left off.

**Scan-carry layout.**  One ``_Carry`` NamedTuple threads the entire
training state through ``lax.scan`` (and is *donated* to the jitted
segment, so the ~100 MB replay ring is updated in place rather than
copied):

    env / obs / mask             — live B-batched episode state: EnvState
                                   pytree, (B, D) observations, (B, A)
                                   action masks;
    reset_env / reset_obs /      — per-env episode-start snapshots; when
    reset_mask                     env ``b`` reports done, ``_bsel``
                                   tree-selects row ``b`` back to its
                                   reset copy inside the scan (episode
                                   auto-reset without leaving the graph);
    params / target / opt        — online Q-network, target network, and
                                   optimizer state pytrees, updated by the
                                   gated double-DQN step;
    replay                       — ``ReplayState`` or (``per_alpha > 0``)
                                   ``PrioritizedReplayState``; the static
                                   choice selects the uniform or PER
                                   engine at trace time;
    key                          — PRNG key, split per scan step for
                                   action noise and replay sampling;
    env_steps / updates          — () i32 counters driving the ε/β
                                   schedules and the target-sync cadence;
    ep_ret                       — (B,) running episode returns, emitted
                                   (masked by done) as the scan's per-step
                                   output for history records.

Because every mutable quantity lives in the carry, a segment is a pure
function ``(carry, n_steps) -> (carry, (dones, returns))`` — the driver
owns nothing but the Python-side history bookkeeping, and identical
carries replay identically (the determinism test pins this).

``train_agent_scalar`` preserves the seed per-step Python loop verbatim —
it is the semantic reference for the parity test and the baseline for
``benchmarks/train_throughput.py``.

Random queues over the zoo, ε-greedy exploration, dueling double-DQN
updates; held-out jobs excluded (paper's unseen-application generalization
test).

**Deliberate default-cadence change:** the scalar seed loop ran 1 DQN
update per env transition (128 gradient samples per transition — far above
the classic DQN ratio).  The vectorized default is 1 update per
``update_every`` (16) transitions = 8 samples/transition, the
DeepMind-classic cadence; with the target network synced on a fixed
*transition* cadence this trains schedulers whose throughput clears the
seed acceptance bar across seeds.  Set ``update_every=1`` to recover the
seed's update work exactly (at matched update work the scanned engine is
no faster than the scalar loop — updates dominate; see BENCH_train.json's
``speedup_matched_updates``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import (
    DQNAgent, DQNConfig, _dqn_update, _dqn_update_aux, _dqn_update_per,
    _dqn_update_per_aux, act_batch, beta_at, epsilon_at,
)
from repro.core.env import CoScheduleEnv, EnvConfig, EnvState, VecCoScheduleEnv
from repro.core.metrics import relative_throughput
from repro.core.network import dqn_apply, masked_argmax
from repro.core.perfmodel_jax import stack_queues
from repro.core.profiles import JobProfile
from repro.core.replay import (
    PrioritizedReplayState, ReplayState, per_init, per_push, per_sample,
    per_update, replay_init, replay_push, replay_sample,
)
from repro.core.scheduler import RLScheduler
from repro.core.workloads import QUEUE_KINDS, make_queue


@dataclass
class TrainConfig:
    episodes: int = 3000
    updates_per_step: int = 1
    n_train_queues: int = 20            # paper: 20 random queues for training
    n_heldout_queues: int = 8           # unseen-job queues per eval record
    strict_classes: bool = True         # demand CI+MI+US in the train pool
    seed: int = 0
    eval_every: int = 100
    batch_envs: int = 16                # B parallel envs in the scanned engine
    update_every: int = 16              # env transitions per DQN update
    per_alpha: float = 0.0              # PER priority exponent; 0 = uniform
    per_beta0: float = 0.4              # initial IS-correction exponent
    per_eps: float = 1e-3               # priority floor added to |TD|
    obs_context: bool = False           # arrival-aware context features:
                                        # promotes env_cfg.obs_context and
                                        # samples per-episode contexts in-scan
    telemetry: bool = False             # per-record loss/TD/grad-norm series
                                        # extracted from the scan carry
    dqn: DQNConfig = field(default_factory=DQNConfig)


def heldout_split(jobs: list[JobProfile], frac: float = 0.33, seed: int = 7):
    """Paper: mark ~1/3 of programs as unseen (*) — excluded from training."""
    rng = np.random.default_rng(seed)
    by_cls: dict[str, list[JobProfile]] = {}
    for j in jobs:
        by_cls.setdefault(j.job_class, []).append(j)
    held: set[str] = set()
    for cls, pool in by_cls.items():
        k = max(1, int(len(pool) * frac)) if len(pool) > 1 else 0
        idx = rng.permutation(len(pool))[:k]
        held.update(pool[i].name for i in idx)
    return held


def _train_queues(jobs, env_cfg, cfg, heldout, rng):
    """20 fixed training queues, all classes represented (paper §V-A2).

    ``cfg.strict_classes=False`` lets recipes remap missing classes onto
    the ones present — required when training on a live profile repository
    mid-growth (the online retrainer sets it); offline callers keep the
    historical 'zoo has no X jobs' validation by default."""
    return [
        make_queue(jobs, QUEUE_KINDS[i % len(QUEUE_KINDS)], env_cfg.window, rng,
                   exclude=heldout, strict=cfg.strict_classes)
        for i in range(cfg.n_train_queues)
    ]


def _heldout_queues(jobs, env_cfg, cfg, heldout, rng):
    """Queues drawn only from held-out jobs — the generalization eval batch.

    Empty when there are no held-out jobs (then the per-record
    ``heldout_throughput`` is ``None``).  Uses its own RNG so the training
    stream (queue composition, per-segment env assignment) is untouched.
    """
    pool = [j for j in jobs if j.name in heldout]
    if not pool or cfg.n_heldout_queues <= 0:
        return []
    return [
        make_queue(pool, QUEUE_KINDS[i % len(QUEUE_KINDS)], env_cfg.window, rng,
                   strict=False)
        for i in range(cfg.n_heldout_queues)
    ]


# ---------------------------------------------------------------------------
# Scanned rollout+update engine
# ---------------------------------------------------------------------------

class _Carry(NamedTuple):
    env: EnvState                        # B-batched episode states
    obs: jnp.ndarray                     # (B, D)
    mask: jnp.ndarray                    # (B, A)
    reset_env: EnvState                  # per-env episode-start states
    reset_obs: jnp.ndarray
    reset_mask: jnp.ndarray
    params: dict
    target: dict
    opt: dict
    replay: ReplayState | PrioritizedReplayState
    key: jax.Array
    env_steps: jnp.ndarray               # () i32
    updates: jnp.ndarray                 # () i32
    ep_ret: jnp.ndarray                  # (B,) running episode returns


def _bsel(pred, a, b):
    """Per-env tree select: pred (B,) broadcast over each leaf's trailing dims."""
    def sel(x, y):
        p = pred.reshape(pred.shape + (1,) * (x.ndim - 1))
        return jnp.where(p, x, y)
    return jax.tree.map(sel, a, b)


def _build_engine(venv: VecCoScheduleEnv, dqn_cfg: DQNConfig,
                  batch_envs: int, updates_per_scan: int,
                  update_period: int, target_sync_updates: int,
                  per: tuple[float, float, float] | None = None,
                  telemetry: bool = False):
    """One scan step = B env transitions + gated DQN updates.

    ``updates_per_scan`` updates run every ``update_period``-th scan step —
    the two together honor ``update_every`` whether B is larger or smaller
    than it.  ``target_sync_updates`` is the sync period in *updates*,
    pre-scaled by the driver so the target network refreshes on the same
    env-transition cadence as the scalar loop (whose 1:1 update ratio made
    ``DQNConfig.target_sync`` updates == transitions).

    ``per = (alpha, beta0, eps)`` statically selects the prioritized-replay
    engine: the carry holds a :class:`PrioritizedReplayState`, each update
    draws a stratified proportional sample, applies IS weights (β annealed
    alongside ε) inside the loss, and writes the new |TD|-derived priorities
    back into the sum-tree before the next update of the same scan step.
    ``per=None`` is the uniform engine, unchanged.

    With ``venv.cfg.obs_context`` (a static trace-time branch) every episode
    auto-reset draws a **fresh arrival-aware context** for that env — busy
    mask from the aligned-claim table, ages/depth from the wait model
    (``VecCoScheduleEnv.sample_context``) — so offline training sees the
    occupancy distribution serve time will, one context per episode, all
    inside the scanned rollout.  The reset observation is recomputed from
    the re-contexted state; masks are context-independent.  Without the
    flag the key stream and compiled program are byte-identical to PR 4.

    ``telemetry`` (static) swaps the update steps for their ``_aux``
    variants and emits per-scan-step ``(loss, |td|, grad_norm, updated)``
    alongside the episode outputs — same forward pass, same gradients,
    bit-identical parameter trajectory (the aux outputs are reads of
    quantities the update computes anyway).
    """
    B = batch_envs
    ctx_mode = venv.cfg.obs_context

    def body(c: _Carry, _):
        if ctx_mode:
            key, k_act, k_upd, k_ctx = jax.random.split(c.key, 4)
        else:
            key, k_act, k_upd = jax.random.split(c.key, 3)
        env_steps = c.env_steps + B
        eps = epsilon_at(dqn_cfg, env_steps)
        a = act_batch(c.params, k_act, c.obs, c.mask, eps)
        env2, obs2, r, done, mask2 = venv.step_batch(c.env, a)
        push = replay_push if per is None else per_push
        replay = push(c.replay, {
            "s": c.obs, "a": a, "r": r, "s2": obs2,
            "done": done.astype(jnp.float32), "mask2": mask2})
        scan_t = env_steps // B                       # 1-based scan step index
        can = (replay.size >= dqn_cfg.batch_size) & (scan_t % update_period == 0)

        # (loss, |td|, grad_norm) of the scan step's last update — zeros on
        # steps with no update; `can` tells the consumer which is which
        tl = (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
        if per is None:
            def upd(_, uc):
                if telemetry:
                    params, target, opt, updates, k, _ = uc
                else:
                    params, target, opt, updates, k = uc
                k, k_s = jax.random.split(k)
                batch = replay_sample(replay, k_s, dqn_cfg.batch_size)
                if telemetry:
                    params, opt, loss, td, gn = _dqn_update_aux(
                        params, target, opt, batch, dqn_cfg)
                else:
                    params, opt, _ = _dqn_update(params, target, opt, batch,
                                                 dqn_cfg)
                updates = updates + 1
                sync = updates % target_sync_updates == 0
                target = jax.tree.map(lambda p, t: jnp.where(sync, p, t),
                                      params, target)
                if telemetry:
                    return params, target, opt, updates, k, (loss, td, gn)
                return params, target, opt, updates, k

            uc0 = (c.params, c.target, c.opt, c.updates, k_upd)
            if telemetry:
                uc0 = uc0 + (tl,)
            # `can` is a scalar (the body is not vmapped), so cond really
            # skips the untaken branch — no tree-wide where copies, and
            # warmup steps before the buffer fills pay nothing
            out = jax.lax.cond(
                can,
                lambda uc: jax.lax.fori_loop(0, updates_per_scan, upd, uc),
                lambda uc: uc,
                uc0)
            if telemetry:
                params, target, opt, updates, _, tl = out
            else:
                params, target, opt, updates, _ = out
        else:
            alpha, beta0, per_eps = per
            beta = beta_at(beta0, env_steps, dqn_cfg.eps_decay_steps)

            def upd(_, uc):
                if telemetry:
                    params, target, opt, updates, rep, k, _ = uc
                else:
                    params, target, opt, updates, rep, k = uc
                k, k_s = jax.random.split(k)
                batch, idx, w = per_sample(rep, k_s, dqn_cfg.batch_size,
                                           alpha, beta)
                if telemetry:
                    params, opt, loss, td, gn = _dqn_update_per_aux(
                        params, target, opt, batch, w, dqn_cfg)
                else:
                    params, opt, _, td = _dqn_update_per(params, target, opt,
                                                         batch, w, dqn_cfg)
                if alpha > 0:          # alpha == 0: priorities never read
                    rep = per_update(rep, idx, td, alpha, per_eps)
                updates = updates + 1
                sync = updates % target_sync_updates == 0
                target = jax.tree.map(lambda p, t: jnp.where(sync, p, t),
                                      params, target)
                if telemetry:
                    return (params, target, opt, updates, rep, k,
                            (loss, jnp.mean(td), gn))
                return params, target, opt, updates, rep, k

            uc0 = (c.params, c.target, c.opt, c.updates, replay, k_upd)
            if telemetry:
                uc0 = uc0 + (tl,)
            # the replay joins the update carry here: priority writes must
            # be visible to the next update drawn in the same scan step
            out = jax.lax.cond(
                can,
                lambda uc: jax.lax.fori_loop(0, updates_per_scan, upd, uc),
                lambda uc: uc,
                uc0)
            if telemetry:
                params, target, opt, updates, replay, _, tl = out
            else:
                params, target, opt, updates, replay, _ = out
        ep_all = c.ep_ret + r
        if ctx_mode:
            # per-episode context refresh: envs that finished an episode
            # restart on a freshly sampled cluster state (the snapshot in
            # reset_env keeps its zero/segment context; only the live row
            # is re-contexted, so the carry layout is unchanged).  The
            # profile prefix of a reset observation is context-independent,
            # so splice the fresh context tail onto the cached prefix
            # instead of rebuilding the whole observation every step.
            fresh = venv.sample_context(k_ctx, c.reset_env.queue.mean_d,
                                        c.reset_env.queue.valid)
            r_env = c.reset_env._replace(ctx=fresh)
            d0 = venv.state_dim - venv.context_dim
            r_obs = jnp.concatenate(
                [c.reset_obs[:, :d0], fresh.busy_units, fresh.ages,
                 fresh.queue_depth[:, None]], axis=1)
        else:
            r_env, r_obs = c.reset_env, c.reset_obs
        carry = _Carry(
            env=_bsel(done, r_env, env2),
            obs=jnp.where(done[:, None], r_obs, obs2),
            mask=jnp.where(done[:, None], c.reset_mask, mask2),
            reset_env=c.reset_env, reset_obs=c.reset_obs, reset_mask=c.reset_mask,
            params=params, target=target, opt=opt, replay=replay, key=key,
            env_steps=env_steps, updates=updates,
            ep_ret=jnp.where(done, 0.0, ep_all),
        )
        ret = jnp.where(done, ep_all, 0.0)
        if telemetry:
            return carry, (done, ret, tl[0], tl[1], tl[2], can)
        return carry, (done, ret)

    def run_segment(carry: _Carry, n_steps: int):
        return jax.lax.scan(body, carry, None, length=n_steps)

    # donate the carry: the replay ring is ~100 MB and re-enters every
    # segment — without donation each call copies it across the jit boundary
    return jax.jit(run_segment, static_argnums=1, donate_argnums=0)


def _build_eval(venv: VecCoScheduleEnv):
    """Jitted greedy evaluation: many queues per record, fully on device.

    Greedy rollout over a batch of eval queues via ``step_batch`` (2W scan
    steps — the episode-length upper bound: W selects + at most W closes),
    accumulating each closed group's co-run/solo time from the in-graph
    perfmodel.  Mirrors ``RLScheduler._enforce_constraints``: a multi-job
    group whose co-run loses to time sharing is counted at its solo time
    (the §IV-A constraint-1 fallback).  Returns per-queue relative
    throughput — no scalar ``CoScheduleEnv`` anywhere in the eval hot path.
    """
    two_w = 2 * venv.cfg.window

    def run(params, env, obs, mask):
        def body(carry, _):
            env, obs, mask, cot, sol = carry
            a = masked_argmax(dqn_apply(params, obs), mask)
            mk, so, multi = venv.close_metrics_batch(env, a)
            env2, obs2, _, _, mask2 = venv.step_batch(env, a)
            cot = cot + jnp.where(multi & (mk > so), so, mk)
            sol = sol + so
            return (env2, obs2, mask2, cot, sol), None

        zeros = jnp.zeros(mask.shape[:1], jnp.float32)
        (_, _, _, cot, sol), _ = jax.lax.scan(
            body, (env, obs, mask, zeros, zeros), None, length=two_w)
        return jnp.where(cot > 0, sol / jnp.maximum(cot, 1e-30), 0.0)

    return jax.jit(run)


_ENGINE_CACHE: dict = {}


def _engine_for(env_cfg: EnvConfig, dqn_cfg: DQNConfig,
                batch_envs: int, updates_per_scan: int,
                update_period: int, target_sync_updates: int,
                per: tuple[float, float, float] | None,
                telemetry: bool = False):
    key = (env_cfg.key(), dqn_cfg, batch_envs, updates_per_scan,
           update_period, target_sync_updates, per, telemetry)
    if key not in _ENGINE_CACHE:
        venv = VecCoScheduleEnv(env_cfg)
        _ENGINE_CACHE[key] = (venv, _build_engine(venv, dqn_cfg, batch_envs,
                                                  updates_per_scan,
                                                  update_period,
                                                  target_sync_updates, per,
                                                  telemetry),
                              _build_eval(venv))
        while len(_ENGINE_CACHE) > 8:      # bound compiled-engine retention
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
    return _ENGINE_CACHE[key]


def train_agent(jobs: list[JobProfile], env_cfg: EnvConfig | None = None,
                cfg: TrainConfig | None = None, heldout: set[str] | None = None,
                verbose: bool = False, warm_start: DQNAgent | None = None,
                _force_per: bool = False) -> tuple[DQNAgent, list[dict]]:
    """Train on the scanned vectorized engine; same signature/records as ever.

    ``cfg.per_alpha > 0`` switches the engine to prioritized replay.
    ``warm_start`` seeds params/target/opt from an existing agent (shapes
    must match this ``env_cfg``); exploration restarts at step 0 under
    ``cfg.dqn``'s ε schedule — the periodic re-training entry point.
    ``_force_per`` routes ``per_alpha == 0`` through the PER machinery
    anyway (uniform indices, unit weights) — the regression parity test
    uses it to pin that path bit-exactly to the uniform engine.
    ``cfg.obs_context`` (or ``env_cfg.obs_context``) widens observations
    with the arrival-aware context block and samples a fresh cluster-state
    context per episode inside the scan; evaluation rollouts stay at the
    neutral zero context, so ``eval_throughput`` remains comparable across
    the two observation modes.
    ``cfg.telemetry`` adds ``loss``/``td_abs``/``grad_norm``/``beta``/
    ``updates`` to each history record (means of the scan's per-step
    update samples since the previous record) while keeping the parameter
    trajectory bit-identical — see ``docs/observability.md``.
    """
    cfg = cfg or TrainConfig()
    env_cfg = env_cfg or EnvConfig()
    if cfg.obs_context and not env_cfg.obs_context:
        env_cfg = dataclasses.replace(env_cfg, obs_context=True)
    use_ctx = env_cfg.obs_context
    B = cfg.batch_envs
    use_per = cfg.per_alpha > 0 or _force_per
    per = (cfg.per_alpha, cfg.per_beta0, cfg.per_eps) if use_per else None
    # honor the configured updates-per-transition ratio on both sides of
    # B vs update_every: several updates per scan step when B is larger,
    # one update every few scan steps when B is smaller
    ratio = B * cfg.updates_per_step / max(1, cfg.update_every)
    if ratio >= 1.0:
        updates_per_scan, update_period = max(1, round(ratio)), 1
    else:
        updates_per_scan, update_period = 1, max(1, round(1.0 / ratio))
    # keep the target-refresh cadence fixed in env transitions (the scalar
    # loop's 1:1 ratio made target_sync updates == transitions)
    sync_updates = max(1, round(cfg.dqn.target_sync * updates_per_scan
                                / (B * update_period)))
    venv, engine, eval_fn = _engine_for(env_cfg, cfg.dqn, B, updates_per_scan,
                                        update_period, sync_updates, per,
                                        cfg.telemetry)
    agent = DQNAgent(venv.state_dim, venv.n_actions, cfg.dqn, seed=cfg.seed,
                     per_alpha=cfg.per_alpha, per_beta0=cfg.per_beta0,
                     per_eps=cfg.per_eps)
    if warm_start is not None:
        # copy (not alias): the jitted segment donates its carry, and donated
        # buffers are invalidated — the caller's agent must stay usable
        src, dst = jax.tree.leaves(warm_start.params), jax.tree.leaves(agent.params)
        assert len(src) == len(dst) and all(a.shape == b.shape
                                            for a, b in zip(src, dst)), \
            "warm_start agent shape mismatch with this EnvConfig/DQNConfig"
        agent.params = jax.tree.map(jnp.copy, warm_start.params)
        agent.target_params = jax.tree.map(jnp.copy, warm_start.target_params)
        agent.opt = jax.tree.map(jnp.copy, warm_start.opt)
    rng = np.random.default_rng(cfg.seed)
    heldout = heldout if heldout is not None else heldout_split(jobs)
    train_queues = _train_queues(jobs, env_cfg, cfg, heldout, rng)
    held_queues = _heldout_queues(jobs, env_cfg, cfg, heldout,
                                  np.random.default_rng(cfg.seed + 0x9E37))
    qa = [venv.queue_arrays(q) for q in train_queues]
    n_tr = len(train_queues)
    # one stacked eval batch: train queues first, held-out queues after —
    # a single jitted rollout yields both metrics per record
    qa_eval = stack_queues(qa + [venv.queue_arrays(q) for q in held_queues])

    # segment length targeting ~eval_every completed episodes per scan;
    # never below one worst-case episode (2W steps: all-solo groups) —
    # env state resets at segment boundaries, so a shorter segment would
    # complete zero episodes and the driver loop could never terminate
    ep_len = env_cfg.window + math.ceil(env_cfg.window / env_cfg.c_max)
    seg_eps = max(1, min(cfg.eval_every, cfg.episodes))
    seg_steps = max(2 * env_cfg.window, math.ceil(seg_eps * ep_len / B))

    params, target, opt = agent.params, agent.target_params, agent.opt
    # round capacity up to a multiple of B: ring writes stay block-aligned
    capacity = -(-cfg.dqn.buffer_size // B) * B
    init = per_init if use_per else replay_init
    replay = init(capacity, venv.state_dim, venv.n_actions)
    key = jax.random.PRNGKey(cfg.seed)
    # segment-start context draws use their own key rather than consuming
    # from the main stream.  Note the *in-scan* streams still differ from a
    # profile-only run: context mode splits the carry key 4 ways instead of
    # 3, so per-step action/replay randomness is not comparable across the
    # two observation modes under one seed (the compiled programs differ
    # anyway — wider obs, extra sampling).
    ctx_key = jax.random.PRNGKey(cfg.seed + 0x51C3) if use_ctx else None
    env_steps = jnp.int32(0)
    updates = jnp.int32(0)
    eval_every = max(1, cfg.eval_every)
    episodes_done, next_eval = 0, eval_every
    history: list[dict] = []
    # telemetry accumulators flushed into each history record: sums of the
    # per-scan-step (loss, |td|, grad_norm) samples over steps that ran an
    # update since the last record
    tel = {"loss": 0.0, "td_abs": 0.0, "grad_norm": 0.0, "n": 0}

    while episodes_done < cfg.episodes:
        # each env runs one of the 20 fixed queues for this segment
        env_q = rng.integers(0, len(train_queues), size=B)
        qa_batch = stack_queues([qa[i] for i in env_q])
        r_env, r_obs, r_mask = venv.reset_batch(qa_batch)
        if use_ctx:
            # segment-start contexts; later episodes resample at auto-reset
            ctx_key, k0 = jax.random.split(ctx_key)
            r_env = r_env._replace(ctx=venv.sample_context(
                k0, r_env.queue.mean_d, r_env.queue.valid))
            r_obs = venv.obs_batch(r_env)
        # distinct buffers for the live-env side: the jitted segment donates
        # its carry, and XLA rejects the same buffer donated twice
        live_env = jax.tree.map(jnp.copy, r_env)
        carry = _Carry(env=live_env, obs=jnp.copy(r_obs), mask=jnp.copy(r_mask),
                       reset_env=r_env, reset_obs=r_obs, reset_mask=r_mask,
                       params=params, target=target, opt=opt, replay=replay,
                       key=key, env_steps=env_steps, updates=updates,
                       ep_ret=jnp.zeros((B,), jnp.float32))
        carry, outs = engine(carry, seg_steps)
        if cfg.telemetry:
            dones, rets, losses, tds, gnorms, cans = outs
            m = np.asarray(cans)
            if m.any():
                tel["loss"] += float(np.asarray(losses)[m].sum())
                tel["td_abs"] += float(np.asarray(tds)[m].sum())
                tel["grad_norm"] += float(np.asarray(gnorms)[m].sum())
                tel["n"] += int(m.sum())
        else:
            dones, rets = outs
        params, target, opt, replay, key = (carry.params, carry.target, carry.opt,
                                            carry.replay, carry.key)
        env_steps, updates = carry.env_steps, carry.updates
        n_done = int(np.asarray(dones).sum())
        episodes_done += n_done
        if episodes_done >= next_eval or episodes_done >= cfg.episodes:
            agent.params, agent.target_params, agent.opt = params, target, opt
            agent.env_steps, agent.updates = int(env_steps), int(updates)
            # device-resident greedy eval: every train queue in one jitted
            # batch rollout; record the mean relative throughput
            e_env, e_obs, e_mask = venv.reset_batch(qa_eval)
            tp = np.asarray(eval_fn(params, e_env, e_obs, e_mask))
            ep_reward = float(np.asarray(rets).sum() / max(1, n_done))
            rec = {"episode": episodes_done, "eps": agent.epsilon,
                   "ep_reward": ep_reward,
                   "eval_throughput": float(tp[:n_tr].mean()),
                   "heldout_throughput": (float(tp[n_tr:].mean())
                                          if held_queues else None)}
            if cfg.telemetry:
                n = tel["n"]
                rec["loss"] = tel["loss"] / n if n else None
                rec["td_abs"] = tel["td_abs"] / n if n else None
                rec["grad_norm"] = tel["grad_norm"] / n if n else None
                rec["beta"] = (float(beta_at(cfg.per_beta0, int(env_steps),
                                             cfg.dqn.eps_decay_steps))
                               if use_per else None)
                rec["updates"] = int(updates)
                tel = {"loss": 0.0, "td_abs": 0.0, "grad_norm": 0.0, "n": 0}
            history.append(rec)
            next_eval = (episodes_done // eval_every + 1) * eval_every
            if verbose:
                held = rec["heldout_throughput"]
                print(f"ep {rec['episode']:5d} eps={rec['eps']:.3f} "
                      f"reward={rec['ep_reward']:8.1f} "
                      f"eval_tp={rec['eval_throughput']:.3f} "
                      f"held_tp={held if held is None else f'{held:.3f}'}")

    agent.params, agent.target_params, agent.opt = params, target, opt
    agent.env_steps, agent.updates = int(env_steps), int(updates)
    return agent, history


# ---------------------------------------------------------------------------
# Seed-equivalent scalar loop (reference + throughput baseline)
# ---------------------------------------------------------------------------

def train_agent_scalar(jobs: list[JobProfile], env_cfg: EnvConfig | None = None,
                       cfg: TrainConfig | None = None,
                       heldout: set[str] | None = None,
                       verbose: bool = False) -> tuple[DQNAgent, list[dict]]:
    """The original per-step Python training loop, preserved verbatim."""
    cfg = cfg or TrainConfig()
    env_cfg = env_cfg or EnvConfig()
    env = CoScheduleEnv(env_cfg)
    agent = DQNAgent(env.state_dim, env.n_actions, cfg.dqn, seed=cfg.seed,
                     per_alpha=cfg.per_alpha, per_beta0=cfg.per_beta0,
                     per_eps=cfg.per_eps)
    rng = np.random.default_rng(cfg.seed)
    heldout = heldout if heldout is not None else heldout_split(jobs)
    train_queues = _train_queues(jobs, env_cfg, cfg, heldout, rng)

    history: list[dict] = []
    for ep in range(cfg.episodes):
        queue = train_queues[int(rng.integers(0, len(train_queues)))]
        state, mask = env.reset(queue)
        ep_reward = 0.0
        while not env.done:
            action = agent.act(state, mask)
            s2, r, done, mask2, _ = env.step(action)
            agent.observe(state, action, r, s2, done, mask2)
            state, mask = s2, mask2
            ep_reward += r
            for _ in range(cfg.updates_per_step):
                agent.update()
        if (ep + 1) % max(1, cfg.eval_every) == 0 or ep == cfg.episodes - 1:
            sched = RLScheduler(agent, env_cfg).schedule(train_queues[0])
            rec = {"episode": ep + 1, "eps": agent.epsilon, "ep_reward": ep_reward,
                   "eval_throughput": relative_throughput(sched)}
            history.append(rec)
            if verbose:
                print(f"ep {ep+1:5d} eps={agent.epsilon:.3f} "
                      f"reward={ep_reward:8.1f} eval_tp={rec['eval_throughput']:.3f}")
    return agent, history


# ---------------------------------------------------------------------------
# Sim-in-the-loop training on queueing reward (+ population-based training)
# ---------------------------------------------------------------------------

@dataclass
class TrainOnlineConfig:
    """Config for :func:`train_online` — the environment is the vectorized
    serving simulator itself, so the reward is the real queueing outcome
    (negative per-window wait/turnaround, makespan terminal) rather than
    the offline per-window throughput proxy."""

    rounds: int = 30                    # collect -> update -> eval cycles
    traces_per_round: int = 6           # fresh serving traces per member
    n_arrivals: int = 48                # arrivals per trace
    window: int = 8                     # serve window (<= env_cfg.window)
    backfill: bool = True
    capacity: int = 128                 # engine trace capacity
    scenarios: tuple = (("poisson", 1.25), ("mmpp", 1.25),
                        ("heavy_tailed", 1.1), ("diurnal", 1.0))
    seed: int = 0
    eps_start: float = 0.5              # round-schedule ε (not cfg.dqn's)
    eps_end: float = 0.05
    eps_decay_rounds: int = 20
    updates_per_round: int = 48         # DQN updates after each collect
    target_sync_updates: int = 32       # target refresh cadence, in updates
    push_block: int = 32                # replay ring block-push size
    population: int = 4                 # PBT members
    pbt_interval: int = 5               # rounds between exploit/explore
    pbt_quantile: float = 0.25          # copy bottom q from top q
    eval_traces: int = 6                # shared eval set, one sweep/round
    wait_weight: float = 1.0            # reward mix (per arrival)
    turnaround_weight: float = 0.0
    makespan_weight: float = 1.0
    per_alpha: float = 0.0              # PER exponent; 0 = uniform ring
    per_beta0: float = 0.4
    per_eps: float = 1e-3
    dqn: DQNConfig = field(default_factory=lambda: DQNConfig(
        buffer_size=20_000))


def _stitch_transitions(roll, n_windows: int, makespan: float,
                        cfg: TrainOnlineConfig):
    """Host-side transition stitcher for one trace rollout.

    Chains every valid decision step (window-major, step order) into one
    serving episode.  Window ``w``'s queueing bucket (member waits +
    turnarounds, normalized per arrival) lands as negative reward on the
    *last* decision of window ``w`` — the close that committed the plan;
    windows with no decisions (all first-sight solos) fold into the most
    recent earlier decision (or the first, for a leading window).  The
    final transition adds the makespan terminal and sets ``done``; its
    ``mask2`` is all-False, which the TD target treats as terminal.
    Returns ``None`` when the trace produced no decisions at all.
    """
    valid = np.asarray(roll.valid)[:n_windows]
    if not valid.any():
        return None
    idx = np.argwhere(valid)                      # row-major: window, step
    m = len(idx)
    obs = np.asarray(roll.obs)[:n_windows]
    act = np.asarray(roll.act)[:n_windows]
    mask = np.asarray(roll.mask)[:n_windows]
    s = obs[idx[:, 0], idx[:, 1]]
    a = act[idx[:, 0], idx[:, 1]]
    mk = mask[idx[:, 0], idx[:, 1]]
    s2 = np.concatenate([s[1:], np.zeros_like(s[:1])])
    mask2 = np.concatenate([mk[1:], np.zeros_like(mk[:1])])
    done = np.zeros(m, np.float32)
    done[-1] = 1.0
    norm = 1.0 / max(1, cfg.n_arrivals)
    bucket = -(cfg.wait_weight * np.asarray(roll.w_wait, np.float64)
               + cfg.turnaround_weight
               * np.asarray(roll.w_turn, np.float64))[:n_windows] * norm
    r = np.zeros(m, np.float64)
    # last decision with window <= w; leading no-decision windows fold
    # forward into the first decision
    tx = np.maximum(np.searchsorted(idx[:, 0], np.arange(n_windows),
                                    side="right") - 1, 0)
    np.add.at(r, tx, bucket)
    r[-1] += -cfg.makespan_weight * float(makespan) * norm
    return {"s": s.astype(np.float32), "a": a.astype(np.int32),
            "r": r.astype(np.float32), "s2": s2.astype(np.float32),
            "done": done, "mask2": mask2.astype(bool)}


_UPDATER_CACHE: dict = {}


def _online_updater(dqn_cfg: DQNConfig, n_updates: int, sync_updates: int,
                    per):
    """Jitted K-update loop over a replay ring: sample -> double-DQN step
    -> priority refresh (PER) -> cadenced target sync.  ``per`` is None
    for the uniform ring or ``(alpha, per_eps)`` for the sum-tree."""
    key_t = (dqn_cfg, n_updates, sync_updates, per)
    if key_t in _UPDATER_CACHE:
        return _UPDATER_CACHE[key_t]

    def run(params, target, opt, replay, key, updates, beta):
        def upd(_, carry):
            params, target, opt, replay, key, updates = carry
            key, ks = jax.random.split(key)
            if per is None:
                batch = replay_sample(replay, ks, dqn_cfg.batch_size)
                params, opt, _ = _dqn_update(params, target, opt, batch,
                                             dqn_cfg)
            else:
                alpha, p_eps = per
                batch, idx, w = per_sample(replay, ks, dqn_cfg.batch_size,
                                           alpha, beta)
                params, opt, _, td = _dqn_update_per(params, target, opt,
                                                     batch, w, dqn_cfg)
                if alpha > 0.0:
                    replay = per_update(replay, idx, td, alpha, p_eps)
            updates = updates + 1
            sync = updates % sync_updates == 0
            target = jax.tree.map(lambda p, t: jnp.where(sync, p, t),
                                  params, target)
            return params, target, opt, replay, key, updates
        return jax.lax.fori_loop(
            0, n_updates, upd, (params, target, opt, replay, key, updates))

    fn = jax.jit(run)
    if len(_UPDATER_CACHE) >= 8:
        _UPDATER_CACHE.pop(next(iter(_UPDATER_CACHE)))
    _UPDATER_CACHE[key_t] = fn
    return fn


_COLLECTOR_CACHE: dict = {}


def _collector_for(env_cfg: EnvConfig, cfg: TrainOnlineConfig):
    from repro.online.vecsim import make_rollout_collector
    key_t = (env_cfg.key(), cfg.window, cfg.backfill, cfg.capacity)
    if key_t not in _COLLECTOR_CACHE:
        if len(_COLLECTOR_CACHE) >= 8:
            _COLLECTOR_CACHE.pop(next(iter(_COLLECTOR_CACHE)))
        _COLLECTOR_CACHE[key_t] = make_rollout_collector(
            env_cfg, window=cfg.window, backfill=cfg.backfill,
            capacity=cfg.capacity)
    return _COLLECTOR_CACHE[key_t]


def train_online(jobs: list[JobProfile], env_cfg: EnvConfig | None = None,
                 cfg: TrainOnlineConfig | None = None,
                 warm_start: DQNAgent | None = None,
                 verbose: bool = False) -> tuple[DQNAgent, list[dict]]:
    """Sim-in-the-loop training: the vectorized serving simulator is the
    environment, queueing outcome is the reward.

    Each round, every population member rolls ``traces_per_round`` fresh
    traces of its (family, load) scenario through the ε-greedy training
    engine (`vecsim` ``train=True``), the host stitches the logged
    window-seam decisions into replay transitions whose rewards are the
    engine-accumulated per-window wait/turnaround (plus a terminal
    makespan term), and ``updates_per_round`` double-DQN updates run on
    the member's ring.  All members are then scored in ONE
    ``sweep(param_sets=...)`` call on a shared eval-trace set (mean p99
    wait — lower is better); every ``pbt_interval`` rounds the bottom
    ``pbt_quantile`` of members copy the top performers' weights and
    re-draw their exploration scale and scenario (exploit/explore over
    agents AND trace families).  Returns the best member as a
    :class:`DQNAgent` plus per-round history.  With ``warm_start`` the
    population starts from the given agent's weights, and the unchanged
    warm-start params are scored in the final eval as an elitism guard —
    if no trained member beats them, the original agent's weights are
    returned (``history[-1]["selected"] == "warm_start"``).
    """
    from repro.online import TRACE_FAMILIES
    from repro.online.policies import RLDispatchPolicy
    from repro.online.vecsim import (
        VectorizedClusterSimulator, build_rl_job_table, compile_trace,
    )
    from repro.core.partition import N_UNITS

    cfg = cfg or TrainOnlineConfig()
    env_cfg = env_cfg or EnvConfig()
    if cfg.window > env_cfg.window:
        raise ValueError(f"serve window {cfg.window} > agent window "
                         f"{env_cfg.window}")
    for fam, _ld in cfg.scenarios:
        if fam not in TRACE_FAMILIES:
            raise ValueError(f"unknown trace family {fam!r}")
    env = CoScheduleEnv(env_cfg)
    state_dim, n_actions = env.state_dim, env.n_actions
    pop = max(1, cfg.population)
    rng = np.random.default_rng(cfg.seed)
    base_key = jax.random.PRNGKey(cfg.seed)
    collect = _collector_for(env_cfg, cfg)
    use_per = cfg.per_alpha > 0.0
    per_t = (cfg.per_alpha, cfg.per_eps) if use_per else None
    updater = _online_updater(cfg.dqn, cfg.updates_per_round,
                              max(1, cfg.target_sync_updates), per_t)
    blk = cfg.push_block
    ring_cap = -(-cfg.dqn.buffer_size // blk) * blk

    def _fresh_member(m: int) -> dict:
        seed_agent = DQNAgent(state_dim, n_actions, cfg.dqn,
                              seed=cfg.seed + m)
        if warm_start is not None:
            params = jax.tree.map(jnp.copy, warm_start.params)
            target = jax.tree.map(jnp.copy, warm_start.target_params)
            opt = jax.tree.map(jnp.copy, warm_start.opt)
        else:
            params = seed_agent.params
            target = seed_agent.target_params
            opt = seed_agent.opt
        ring = (per_init(ring_cap, state_dim, n_actions) if use_per
                else replay_init(ring_cap, state_dim, n_actions))
        return {"params": params, "target": target, "opt": opt,
                "replay": ring, "updates": jnp.int32(0),
                "stage": {f: [] for f in
                          ("s", "a", "r", "s2", "done", "mask2")},
                "staged": 0, "env_steps": 0,
                "eps_scale": 1.0, "scenario": m % len(cfg.scenarios),
                "score": float("inf")}

    members = [_fresh_member(m) for m in range(pop)]

    # shared eval traces, round-robin over the scenario axis
    eval_traces = [
        TRACE_FAMILIES[cfg.scenarios[t % len(cfg.scenarios)][0]](
            jobs, n=cfg.n_arrivals,
            load=cfg.scenarios[t % len(cfg.scenarios)][1],
            seed=cfg.seed + 9000 + t)
        for t in range(max(1, cfg.eval_traces))]
    eval_agent = DQNAgent(state_dim, n_actions, cfg.dqn, seed=cfg.seed)
    vec = VectorizedClusterSimulator(
        RLDispatchPolicy(eval_agent, env_cfg), window=cfg.window,
        backfill=cfg.backfill, capacity=cfg.capacity)

    def _eval_scores(param_list) -> np.ndarray:
        summ = vec.sweep(eval_traces, param_sets=param_list)
        return np.asarray(summ.p99_wait, np.float64).mean(axis=1)

    widths = jnp.full((cfg.traces_per_round,), N_UNITS, jnp.int32)
    history: list[dict] = []
    total_tx = 0
    for rnd in range(cfg.rounds):
        frac = min(1.0, rnd / max(1, cfg.eps_decay_rounds))
        eps_round = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        for m, mem in enumerate(members):
            fam, load = cfg.scenarios[mem["scenario"]]
            traces = [TRACE_FAMILIES[fam](
                jobs, n=cfg.n_arrivals, load=load,
                seed=cfg.seed + 1 + rnd * 131 + m * 17 + t)
                for t in range(cfg.traces_per_round)]
            names: dict[str, int] = {}
            tjobs: list = []
            compiled = [compile_trace(t, cfg.capacity, names, tjobs)[0]
                        for t in traces]
            batch = jax.tree.map(lambda *xs: jnp.stack(xs), *compiled)
            rjt = build_rl_job_table(tjobs)
            keys = jax.random.split(
                jax.random.fold_in(base_key, rnd * pop + m),
                cfg.traces_per_round)
            eps = jnp.float32(min(1.0, eps_round * mem["eps_scale"]))
            summ, roll = collect(batch, rjt, mem["params"], keys, eps,
                                 widths)
            VectorizedClusterSimulator._check_err(
                int(np.max(np.asarray(summ.err))))
            n_win = np.asarray(summ.dispatches, np.int64)
            mks = np.asarray(summ.makespan, np.float64)
            for t in range(cfg.traces_per_round):
                one = jax.tree.map(lambda x: x[t], roll)
                tx = _stitch_transitions(one, int(n_win[t]),
                                         float(mks[t]), cfg)
                if tx is None:
                    continue
                for f in mem["stage"]:
                    mem["stage"][f].append(tx[f])
                mem["staged"] += len(tx["a"])
                mem["env_steps"] += len(tx["a"])
                total_tx += len(tx["a"])
            # block-aligned ring pushes; remainder stays staged
            if mem["staged"] >= blk:
                full = {f: np.concatenate(v) for f, v in
                        mem["stage"].items()}
                n_push = (mem["staged"] // blk) * blk
                for lo in range(0, n_push, blk):
                    chunk = {f: jnp.asarray(v[lo:lo + blk])
                             for f, v in full.items()}
                    mem["replay"] = (per_push(mem["replay"], chunk)
                                     if use_per
                                     else replay_push(mem["replay"], chunk))
                for f in mem["stage"]:
                    mem["stage"][f] = [full[f][n_push:]]
                mem["staged"] -= n_push
            size = int(mem["replay"].ring.size if use_per
                       else mem["replay"].size)
            if size >= cfg.dqn.batch_size:
                beta = jnp.float32(beta_at(cfg.per_beta0,
                                           mem["env_steps"],
                                           cfg.dqn.eps_decay_steps))
                (mem["params"], mem["target"], mem["opt"], mem["replay"],
                 _, mem["updates"]) = updater(
                    mem["params"], mem["target"], mem["opt"],
                    mem["replay"],
                    jax.random.fold_in(base_key, 70_000 + rnd * pop + m),
                    mem["updates"], beta)

        scores = _eval_scores([mem["params"] for mem in members])
        for mem, sc in zip(members, scores):
            mem["score"] = float(sc)
        order = np.argsort(scores)
        rec = {"round": rnd + 1, "eps": float(eps_round),
               "scores": [float(s) for s in scores],
               "best_member": int(order[0]),
               "best_p99": float(scores[order[0]]),
               "transitions": total_tx}
        if pop > 1 and cfg.pbt_interval > 0 and rnd < cfg.rounds - 1 \
                and (rnd + 1) % cfg.pbt_interval == 0:
            n_q = max(1, int(pop * cfg.pbt_quantile))
            swaps = []
            for dst, src in zip(order[-n_q:], order[:n_q]):
                lo, hi = members[dst], members[src]
                lo["params"] = jax.tree.map(jnp.copy, hi["params"])
                lo["target"] = jax.tree.map(jnp.copy, hi["target"])
                lo["opt"] = jax.tree.map(jnp.copy, hi["opt"])
                lo["eps_scale"] = float(np.clip(
                    hi["eps_scale"] * rng.choice([0.8, 1.25]), 0.25, 2.0))
                lo["scenario"] = int(rng.integers(len(cfg.scenarios)))
                swaps.append((int(dst), int(src)))
            rec["pbt"] = swaps
        history.append(rec)
        if verbose:
            print(f"round {rnd + 1:3d} eps={eps_round:.3f} "
                  f"best_p99={rec['best_p99']:.2f} tx={total_tx}")

    # final selection (+ warm-start elitism guard: a refresh must beat the
    # incumbent strictly on eval, else the incumbent's weights are kept)
    finals = [mem["params"] for mem in members]
    labels: list = list(range(pop))
    if warm_start is not None:
        finals.append(warm_start.params)
        labels.append("warm_start")
    scores = _eval_scores(finals)
    best = int(np.argmin(scores[:pop]))
    if warm_start is not None and scores[pop] <= scores[best]:
        best = pop
    selected = labels[best]
    agent = DQNAgent(state_dim, n_actions, cfg.dqn, seed=cfg.seed,
                     per_alpha=cfg.per_alpha, per_beta0=cfg.per_beta0,
                     per_eps=cfg.per_eps)
    if selected == "warm_start":
        agent.params = jax.tree.map(jnp.copy, warm_start.params)
        agent.target_params = jax.tree.map(jnp.copy,
                                           warm_start.target_params)
        agent.opt = jax.tree.map(jnp.copy, warm_start.opt)
    else:
        mem = members[selected]
        agent.params, agent.target_params = mem["params"], mem["target"]
        agent.opt = mem["opt"]
        agent.env_steps = int(mem["env_steps"])
        agent.updates = int(mem["updates"])
    if history:
        history[-1]["selected"] = ("warm_start"
                                   if selected == "warm_start"
                                   else int(selected))
        history[-1]["final_scores"] = [float(s) for s in scores]
    return agent, history
