"""Online phase (paper §IV-B): trained agent -> (L_JS, L_R) for a queue.

The agent runs greedily (ε = 0) on the stateful reference env — greedy
calls do not advance the agent's ε-decay schedule, so scheduling/evaluation
frequency never perturbs training exploration. The §IV-A constraint
``CoRunTime <= SoloRunTime`` is then *enforced by construction*: any group
whose predicted co-run loses to time sharing is split back into solo runs
(the paper's constraint-1 guard).  Jobs without a profile in the repository
are excluded from co-scheduling and executed solo while being profiled
(paper's online protocol).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.agent import DQNAgent
from repro.core.env import CoScheduleEnv, EnvConfig
from repro.core.partition import enumerate_partitions
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.problem import Schedule
from repro.core.profiles import JobProfile, ProfileRepository


@dataclass
class SchedulerStats:
    fallback_groups: int = 0
    unprofiled_jobs: int = 0


class RLScheduler:
    def __init__(self, agent: DQNAgent, env_cfg: EnvConfig | None = None,
                 repository: ProfileRepository | None = None):
        self.agent = agent
        self.env_cfg = env_cfg or EnvConfig()
        self.repository = repository or ProfileRepository()
        self.stats = SchedulerStats()

    def schedule(self, queue: list[JobProfile]) -> Schedule:
        env = CoScheduleEnv(self.env_cfg)
        state, mask = env.reset(queue)
        guard = 0
        while not env.done:
            action = self.agent.act(state, mask, greedy=True)
            state, _, _, mask, _ = env.step(action)
            guard += 1
            assert guard < 10 * self.env_cfg.window, "scheduler failed to terminate"
        return self._enforce_constraints(env.schedule)

    def schedule_submissions(self, submissions: list[tuple[str, JobProfile | None]]) -> Schedule:
        """Online protocol: (binary_path, maybe-fresh-profile) submissions.
        Unprofiled jobs run solo (full pod) and enter the repository."""
        solo = [p for p in enumerate_partitions(1) if p.arity == 1][0]
        profiled: list[JobProfile] = []
        sched = Schedule()
        for path, fresh in submissions:
            prof = self.repository.lookup(path)
            if prof is None:
                self.stats.unprofiled_jobs += 1
                if fresh is not None:       # measured during this solo run
                    self.repository.insert(path, fresh)
                    sched.add([fresh], solo)
                continue
            profiled.append(prof)
        if profiled:
            inner = self.schedule(profiled)
            for g, p in zip(inner.groups, inner.partitions):
                sched.add(g, p)
        return sched

    def _enforce_constraints(self, sched: Schedule) -> Schedule:
        solo = [p for p in enumerate_partitions(1) if p.arity == 1][0]
        out = Schedule()
        for g, p in zip(sched.groups, sched.partitions):
            if len(g) > 1 and corun_time(g, p) > solo_run_time(g):
                self.stats.fallback_groups += 1
                for j in g:
                    out.add([j], solo)
            else:
                out.add(g, p)
        return out
