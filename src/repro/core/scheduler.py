"""Online phase (paper §IV-B): trained agent -> (L_JS, L_R) for a queue.

The agent runs greedily (ε = 0) on the stateful reference env — greedy
calls do not advance the agent's ε-decay schedule, so scheduling/evaluation
frequency never perturbs training exploration. The §IV-A constraint
``CoRunTime <= SoloRunTime`` is then *enforced by construction*: any group
whose predicted co-run loses to time sharing is split back into solo runs
(the paper's constraint-1 guard).  Jobs without a profile in the repository
are excluded from co-scheduling and executed solo while being profiled
(paper's online protocol).

Two shared pieces sit between any planner and the cluster simulator:

* :func:`submission_protocol` — the single first-sight implementation
  (unprofiled binary -> solo run + repository insert) every dispatcher
  wraps, so the profiling cost is identical across policies by
  construction.  It also carries the dispatch-time
  :class:`~repro.core.env.DispatchContext` (free-unit mask, per-submission
  ages, pending depth) down to context-aware planners, re-chunked so each
  planning window sees exactly its own submissions' ages.
* :func:`to_placements` — width-fits a planned :class:`Schedule` into
  :class:`Placement`\\ s: dedicated (single-share) slices shrink to their
  job's ``requested_units`` hint so right-sized jobs occupy only the slice
  range they can use, which is what lets the simulator run independent
  groups concurrently on disjoint slices and backfill small jobs into idle
  gaps.  MPS-shared slices keep their planned width (the share semantics
  assume the planned slice), and a job without a hint keeps the full
  width — offline schedules are bit-identical through this function.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.agent import DQNAgent
from repro.core.env import CoScheduleEnv, DispatchContext, EnvConfig
from repro.core.partition import Partition, Slice, slice_label, solo_partition
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.problem import Schedule
from repro.core.profiles import JobProfile, ProfileRepository


@dataclass
class SchedulerStats:
    fallback_groups: int = 0
    unprofiled_jobs: int = 0
    windows: int = 0                 # RL scheduling windows run by submissions


def submission_protocol(repository: ProfileRepository,
                        submissions: list[tuple[str, JobProfile | None]],
                        plan, window: int | None = None,
                        on_unprofiled=None, on_window=None,
                        context: DispatchContext | None = None) -> Schedule:
    """The §IV-B online submission protocol, shared by every dispatcher.

    Submissions are ``(binary_path, maybe-fresh-profile)`` pairs.  A binary
    the repository has never seen runs **solo** on the full pod (profiled as
    it runs) and its fresh measurement enters the repository — a first
    sight with no measurement is reported via ``on_unprofiled`` but cannot
    be scheduled.  The profiled remainder is chunked into ``window``-sized
    batches (``None``: one batch) and handed to ``plan(queue) -> Schedule``.
    ``RLScheduler.schedule_submissions`` and the online package's
    ``DispatchPolicy.dispatch`` are both thin wrappers over this function,
    so the first-sight cost is identical across policies by construction.

    ``context`` is the dispatcher's cluster-state snapshot: its ``ages_s``
    align positionally with ``submissions``.  When given, each chunk's
    planner is called as ``plan(queue, context)`` with the ages filtered to
    that chunk's profiled jobs and ``queue_depth`` grown by the profiled
    submissions still waiting in later chunks of this same window (they
    queue behind this plan exactly like pending arrivals do).  ``None``
    preserves the historical ``plan(queue)`` call unchanged.
    """
    solo = solo_partition()
    sched = Schedule()
    profiled: list[JobProfile] = []
    ages: list[float] = []
    for k, (path, fresh) in enumerate(submissions):
        prof = repository.lookup(path)
        if prof is None:
            if on_unprofiled is not None:
                on_unprofiled(path, fresh)
            if fresh is not None:       # measured during this solo run
                repository.insert(path, fresh)
                sched.add([fresh], solo)
            continue
        profiled.append(prof)
        if context is not None:
            ages.append(context.ages_s[k] if k < len(context.ages_s) else 0.0)
    W = window or max(1, len(profiled))
    for lo in range(0, len(profiled), W):
        chunk = profiled[lo:lo + W]
        if on_window is not None:
            on_window(chunk)
        if context is None:
            inner = plan(chunk)
        else:
            later = len(profiled) - (lo + len(chunk))
            inner = plan(chunk, DispatchContext(
                free_units=context.free_units,
                ages_s=tuple(ages[lo:lo + len(chunk)]),
                queue_depth=context.queue_depth + later,
                now_s=context.now_s))
        for g, p in zip(inner.groups, inner.partitions):
            sched.add(g, p)
    return sched


@dataclass
class Placement:
    """One co-run group bound to the (possibly sub-pod) partition it will
    occupy.  The *which slice units* decision is the simulator's (its
    occupancy map first-fits the partition's slices onto free ranges);
    the placement fixes *how wide* each slice is."""

    group: list[JobProfile]
    partition: Partition


@dataclass(frozen=True)
class DispatchDecision:
    """The single result of one dispatch window — what
    ``DispatchPolicy.decide`` returns.

    Collapses the historical ``dispatch()`` (schedule), ``placements()``
    (width-fitted placements) and per-call stats bookkeeping into one
    value: ``schedule`` is the planned :class:`Schedule` (``None`` only
    when a legacy ``placements``-override subclass produced the
    placements without one), ``placements`` is what the slice-level
    simulator consumes, and ``first_sight`` / ``planned`` count this
    window's submissions on each side of the profiling protocol."""

    schedule: Schedule | None
    placements: tuple[Placement, ...]
    first_sight: int = 0
    planned: int = 0


def to_placements(sched: Schedule) -> list[Placement]:
    """Width-fit a planned Schedule into slice-level placements.

    Dedicated (single-share) slices shrink to their job's
    ``requested_units`` placement hint — never grow, and MPS-shared slices
    are untouched.  Groups and slot order are preserved, so per-job finish
    times still come from :func:`~repro.core.perfmodel.corun` on the fitted
    partition.  Schedules over jobs without width hints pass through
    unchanged (identical objects), which keeps full-pod dispatch
    bit-compatible."""
    out: list[Placement] = []
    for g, p in zip(sched.groups, sched.partitions):
        new_slices = list(p.slices)
        changed = False
        for pos, (si, s, _beta) in enumerate(p.slots):
            if len(s.shares) != 1:
                continue
            req = g[pos].requested_units
            if req < s.units:
                new_slices[si] = Slice(req, s.shares)
                changed = True
        part = (Partition(tuple(new_slices), slice_label(tuple(new_slices)))
                if changed else p)
        out.append(Placement(list(g), part))
    return out


class RLScheduler:
    def __init__(self, agent: DQNAgent, env_cfg: EnvConfig | None = None,
                 repository: ProfileRepository | None = None):
        self.agent = agent
        self.env_cfg = env_cfg or EnvConfig()
        # `or` would discard an *empty* repository (len 0 is falsy) and
        # silently sever the caller's handle to the shared profile store
        self.repository = repository if repository is not None else ProfileRepository()
        self.stats = SchedulerStats()

    def schedule(self, queue: list[JobProfile],
                 context: DispatchContext | None = None) -> Schedule:
        """Greedy episode over ``queue``; ``context`` is the dispatch-time
        cluster snapshot an ``obs_context`` environment folds into the
        observation (ignored — zero block — otherwise)."""
        env = CoScheduleEnv(self.env_cfg)
        state, mask = env.reset(queue, context)
        guard = 0
        while not env.done:
            action = self.agent.act(state, mask, greedy=True)
            state, _, _, mask, _ = env.step(action)
            guard += 1
            assert guard < 10 * self.env_cfg.window, "scheduler failed to terminate"
        return self._enforce_constraints(env.schedule)

    def schedule_submissions(self, submissions: list[tuple[str, JobProfile | None]],
                             context: DispatchContext | None = None) -> Schedule:
        """:func:`submission_protocol` with the agent as planner.

        Unprofiled jobs run solo (full pod) and enter the repository; the
        profiled remainder is co-scheduled by the agent.  More profiled jobs
        than the agent's window are chunked into successive window-sized RL
        episodes (each counted in ``stats.windows``) — the event-driven
        cluster simulator hands over whatever is pending, which can exceed W.
        ``context`` (the simulator's dispatch snapshot) reaches each episode
        re-chunked by :func:`submission_protocol`.
        """
        def on_unprofiled(path, fresh):
            self.stats.unprofiled_jobs += 1

        def on_window(chunk):
            self.stats.windows += 1

        return submission_protocol(self.repository, submissions,
                                   self.schedule, window=self.env_cfg.window,
                                   on_unprofiled=on_unprofiled,
                                   on_window=on_window, context=context)

    def _enforce_constraints(self, sched: Schedule) -> Schedule:
        solo = solo_partition()
        out = Schedule()
        for g, p in zip(sched.groups, sched.partitions):
            if len(g) > 1 and corun_time(g, p) > solo_run_time(g):
                self.stats.fallback_groups += 1
                for j in g:
                    out.add([j], solo)
            else:
                out.add(g, p)
        return out
