"""Uniform experience replay buffer (numpy circular store)."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, n_actions: int, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.mask2 = np.zeros((capacity, n_actions), bool)
        self.ptr = 0
        self.full = False

    def push(self, s, a, r, s2, done, mask2) -> None:
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i], self.mask2[i] = s2, float(done), mask2
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def __len__(self) -> int:
        return self.capacity if self.full else self.ptr

    def sample(self, batch: int) -> dict:
        idx = self.rng.integers(0, len(self), size=batch)
        return {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s2": self.s2[idx], "done": self.done[idx], "mask2": self.mask2[idx],
        }
