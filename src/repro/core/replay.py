"""Experience replay: uniform + prioritized, JAX on-device and numpy mirror.

Two storage layers share one ring-buffer contract (block-aligned
``dynamic_update_slice`` writes — see :func:`replay_push`):

  * ``ReplayState`` + ``replay_init/push/sample`` — the original pure
    uniform ring that threads through ``lax.scan`` as part of the training
    carry.
  * ``PrioritizedReplayState`` + ``per_init/push/sample/update`` —
    proportional prioritized experience replay (Schaul et al. 2016) built on
    a **pure-JAX sum-tree**: leaf ``i`` holds ``(|td_i| + eps)**alpha``,
    internal nodes hold subtree sums, and sampling descends the tree with a
    fixed ``log2(L)``-step ``fori_loop`` so push/sample/priority-update are
    all jit-able and live inside the scanned engine.  Writes are
    **incremental**: after setting the touched leaves, only their ancestor
    paths are recomputed bottom-up (``O(B log C)`` adds for a B-leaf write
    instead of the old ``O(C)`` full-level rebuild), and because every
    affected internal node is recomputed as the exact sum of its two
    children the tree stays bit-identical to a from-scratch rebuild —
    float32 error never accumulates (``_tree_rebuild`` is kept as the
    reference the parity test pins against).  New transitions
    enter at the running max priority; ``per_sample`` draws stratified
    proportional samples and returns importance-sampling weights normalized
    to ``max(w) == 1``.  ``alpha == 0`` is a *static* branch that
    reproduces the uniform sampler bit-exactly (same key -> same indices,
    weights all ones), which is what lets ``TrainConfig.per_alpha = 0``
    default to uniform-equivalent behavior.

**Sum-tree invariants.**  The tree is a flat ``(2L,)`` array over
``L = next_pow2(capacity)`` leaves: node ``i``'s children are ``2i`` and
``2i + 1``, leaves occupy ``[L, 2L)``, and node 1 is the root holding the
total priority mass.  Three invariants hold after every operation:

  1. *Exact-sum*: every internal node equals the float32 sum of its two
     children — maintained by recomputing each touched leaf's ancestor
     path bottom-up (``_tree_ascend``), so a node is always written as the
     exact ``children[0] + children[1]``, never nudged by a delta.  This
     is why incremental updates stay **bit-identical** to a from-scratch
     ``_tree_rebuild``: both compute the same sums from the same leaves,
     only over different node subsets.  The retained ``_tree_rebuild`` is
     the reference the parity test pins ``per_push``/``per_update``
     against; it is not used in the hot path.
  2. *Padding is zero*: leaves at or past ``capacity`` hold 0.0 and are
     therefore unreachable by the proportional descent (a zero-mass
     subtree is never entered), so the power-of-two padding cannot leak
     phantom transitions.
  3. *Non-negative mass*: leaf priorities are ``(|td| + eps)**alpha`` with
     ``eps > 0``, so any stored transition has strictly positive mass and
     the fixed ``log2(L)``-step descent terminates at a valid leaf.

``ReplayBuffer`` / ``PrioritizedReplayBuffer`` keep the same semantics in
numpy (identical sum-tree layout) for the scalar reference loop, so parity
tests can pin the functional core against them.

Sampling an **empty** ring is undefined: both samplers index the
zero-initialized store and would return garbage transitions.  Callers must
gate on ``size`` (the scanned engine's warmup gate requires
``size >= batch_size`` before the first update); the eager path asserts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

FIELDS = ("s", "a", "r", "s2", "done", "mask2")


class ReplayState(NamedTuple):
    """Ring buffer contents + cursor; capacity is the static leading dim."""

    s: jnp.ndarray                   # (C, state_dim) f32
    a: jnp.ndarray                   # (C,) i32
    r: jnp.ndarray                   # (C,) f32
    s2: jnp.ndarray                  # (C, state_dim) f32
    done: jnp.ndarray                # (C,) f32
    mask2: jnp.ndarray               # (C, n_actions) bool
    ptr: jnp.ndarray                 # () i32 — next write slot
    size: jnp.ndarray                # () i32 — filled entries (<= C)

    @property
    def capacity(self) -> int:
        return self.a.shape[0]


def replay_init(capacity: int, state_dim: int, n_actions: int) -> ReplayState:
    return ReplayState(
        s=jnp.zeros((capacity, state_dim), jnp.float32),
        a=jnp.zeros((capacity,), jnp.int32),
        r=jnp.zeros((capacity,), jnp.float32),
        s2=jnp.zeros((capacity, state_dim), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        mask2=jnp.zeros((capacity, n_actions), bool),
        ptr=jnp.int32(0),
        size=jnp.int32(0),
    )


def replay_push(rs: ReplayState, batch: dict) -> ReplayState:
    """Write B transitions at the cursor (wrapping); pure, jit-able.

    Contract: every push to a given ring must use the **same** block size,
    and that size must divide the capacity (the training driver rounds
    capacity up to a multiple of B).  Uniform block-aligned writes keep each
    push one contiguous ``dynamic_update_slice`` — XLA updates those in
    place when the buffer is a loop carry, whereas a gather-indexed scatter
    copies the whole ring every scan step.  Mixed push sizes would leave the
    cursor mid-block where ``dynamic_update_slice`` clamps instead of
    wrapping; the divisibility assert below catches size/capacity mismatch,
    uniformity is the caller's obligation.
    """
    cap = rs.capacity
    n = batch["a"].shape[0]
    assert cap % n == 0, f"push size {n} must divide capacity {cap}"
    if not isinstance(rs.ptr, jax.core.Tracer):
        # eager path: catch mixed block sizes before they corrupt the ring
        # (inside jit the cursor is a tracer; the engine pushes uniformly)
        assert int(rs.ptr) % n == 0, (
            f"cursor {int(rs.ptr)} not aligned to push size {n} — all pushes "
            "to a ring must use one block size")

    def put(buf, new):
        new = new.astype(buf.dtype)
        start = (rs.ptr,) + (jnp.int32(0),) * (buf.ndim - 1)
        return jax.lax.dynamic_update_slice(buf, new, start)

    return rs._replace(
        s=put(rs.s, batch["s"]),
        a=put(rs.a, batch["a"]),
        r=put(rs.r, batch["r"]),
        s2=put(rs.s2, batch["s2"]),
        done=put(rs.done, batch["done"]),
        mask2=put(rs.mask2, batch["mask2"]),
        ptr=(rs.ptr + n) % cap,
        size=jnp.minimum(rs.size + n, cap),
    )


def _assert_nonempty(size) -> None:
    """Eager-path guard: sampling an empty ring reads zero-filled garbage.

    Inside jit `size` is a tracer and the caller owns the warmup gate (the
    scanned engine only updates once ``size >= batch_size``)."""
    if not isinstance(size, jax.core.Tracer):
        assert int(size) > 0, (
            "replay sample on an empty ring — push transitions first or gate "
            "on `size` (the engine's warmup gate)")


def _uniform_indices(rs: ReplayState, key: jax.Array, n: int) -> jnp.ndarray:
    return jax.random.randint(key, (n,), 0, jnp.maximum(rs.size, 1))


def replay_sample(rs: ReplayState, key: jax.Array, n: int) -> dict:
    """Uniform sample of n transitions from the filled region.

    Precondition: ``rs.size > 0`` (asserted eagerly; jitted callers gate)."""
    _assert_nonempty(rs.size)
    idx = _uniform_indices(rs, key, n)
    return {f: getattr(rs, f)[idx] for f in FIELDS}


# ---------------------------------------------------------------------------
# Prioritized replay: pure-JAX sum-tree over the same ring
# ---------------------------------------------------------------------------

def _leaf_count(capacity: int) -> int:
    """Leaves of the complete binary tree: next power of two >= capacity."""
    return 1 << max(0, capacity - 1).bit_length()


class PrioritizedReplayState(NamedTuple):
    """Uniform ring + sum-tree priorities; threads through ``lax.scan``.

    ``tree`` is a flat complete binary tree of ``2 * L`` float32 nodes
    (``L = _leaf_count(capacity)``): leaf ``i`` lives at ``L + i``, node
    ``k``'s children are ``2k`` and ``2k + 1``, the total priority mass is
    the root ``tree[1]`` (``tree[0]`` is unused).  Leaves hold priorities
    already exponentiated by alpha; leaves past ``capacity`` stay zero.
    """

    ring: ReplayState
    tree: jnp.ndarray                # (2 * L,) f32 — sum-tree nodes
    max_p: jnp.ndarray               # () f32 — running max leaf priority

    @property
    def capacity(self) -> int:
        return self.ring.capacity

    @property
    def ptr(self) -> jnp.ndarray:
        return self.ring.ptr

    @property
    def size(self) -> jnp.ndarray:
        return self.ring.size


def per_init(capacity: int, state_dim: int, n_actions: int) -> PrioritizedReplayState:
    return PrioritizedReplayState(
        ring=replay_init(capacity, state_dim, n_actions),
        tree=jnp.zeros((2 * _leaf_count(capacity),), jnp.float32),
        max_p=jnp.float32(1.0),
    )


def _tree_rebuild(tree: jnp.ndarray) -> jnp.ndarray:
    """Recompute every internal node from the (already written) leaves.

    log2(L) reshape-sums (~2L adds total).  No longer on the hot path —
    ``per_push``/``per_update`` use the O(B log C) ancestor-path update —
    but kept as the reference implementation: the incremental update is
    parity-tested bit-exact against this."""
    level = tree[tree.shape[0] // 2:]
    levels = [level]
    while level.shape[0] > 1:
        level = level.reshape(-1, 2).sum(axis=1)
        levels.append(level)
    return jnp.concatenate([jnp.zeros((1,), tree.dtype)] + levels[::-1])


def _tree_ascend(tree: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Recompute the ancestors of the leaves at absolute positions ``pos``.

    Walks the log2(L) levels bottom-up; at each level every touched node is
    recomputed as the exact sum of its two children (gather before scatter,
    so duplicate parents write identical values).  Because untouched nodes
    already equal the sum of their children by induction, the result is
    **bit-identical** to ``_tree_rebuild`` at O(B log C) instead of O(C)
    work — the incremental form the 1M+-capacity rings need.
    """
    L = tree.shape[0] // 2
    depth = max(0, L.bit_length() - 1)

    def level(_, carry):
        tree, k = carry
        k = k // 2
        return tree.at[k].set(tree[2 * k] + tree[2 * k + 1]), k

    tree, _ = jax.lax.fori_loop(0, depth, level, (tree, pos))
    return tree


def _tree_query(tree: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Vectorized sum-tree descent: prefix-sum targets ``v`` -> leaf indices.

    Descends right only when the right subtree still has mass, so float
    round-off at segment boundaries can't walk into the zero-padded tail.
    """
    L = tree.shape[0] // 2
    depth = max(0, L.bit_length() - 1)

    def step(_, kv):
        k, v = kv
        left = tree[2 * k]
        go_right = (v >= left) & (tree[2 * k + 1] > 0)
        return 2 * k + go_right.astype(jnp.int32), v - jnp.where(go_right, left, 0.0)

    k0 = jnp.ones(v.shape, jnp.int32)
    k, _ = jax.lax.fori_loop(0, depth, step, (k0, v))
    return k - L


def per_push(ps: PrioritizedReplayState, batch: dict) -> PrioritizedReplayState:
    """Ring push (same block-aligned contract as ``replay_push``); the new
    block enters at the running max priority so fresh transitions are seen
    at least once before TD errors re-rank them."""
    n = batch["a"].shape[0]
    L = ps.tree.shape[0] // 2
    tree = jax.lax.dynamic_update_slice(
        ps.tree, jnp.full((n,), ps.max_p, jnp.float32), (L + ps.ring.ptr,))
    pos = L + ps.ring.ptr + jnp.arange(n, dtype=jnp.int32)
    return PrioritizedReplayState(
        ring=replay_push(ps.ring, batch),
        tree=_tree_ascend(tree, pos),
        max_p=ps.max_p,
    )


def per_sample(ps: PrioritizedReplayState, key: jax.Array, n: int,
               alpha: float, beta) -> tuple[dict, jnp.ndarray, jnp.ndarray]:
    """Stratified proportional sample -> (batch, indices, IS weights).

    ``alpha`` is static: ``alpha == 0`` takes the uniform branch, which
    bit-matches ``replay_sample`` given the same key (weights all ones).
    Otherwise weights are ``(size * P(i)) ** -beta`` normalized so the
    largest sampled weight is exactly 1.  Precondition: ``ps.size > 0``.
    """
    _assert_nonempty(ps.ring.size)
    if alpha == 0.0:
        idx = _uniform_indices(ps.ring, key, n)
        w = jnp.ones((n,), jnp.float32)
    else:
        L = ps.tree.shape[0] // 2
        total = ps.tree[1]
        u = jax.random.uniform(key, (n,))
        targets = (jnp.arange(n, dtype=jnp.float32) + u) * (total / n)
        idx = _tree_query(ps.tree, targets)
        idx = jnp.minimum(idx, jnp.maximum(ps.ring.size, 1) - 1)
        probs = ps.tree[L + idx] / jnp.maximum(total, 1e-30)
        n_filled = jnp.maximum(ps.ring.size, 1).astype(jnp.float32)
        w = (n_filled * jnp.maximum(probs, 1e-30)) ** (-beta)
        w = (w / jnp.max(w)).astype(jnp.float32)
    batch = {f: getattr(ps.ring, f)[idx] for f in FIELDS}
    return batch, idx, w


def per_update(ps: PrioritizedReplayState, idx: jnp.ndarray,
               td_err: jnp.ndarray, alpha: float,
               eps: float) -> PrioritizedReplayState:
    """Re-rank sampled leaves from TD error: ``p = (|td| + eps) ** alpha``.

    Duplicate indices in ``idx`` carry identical TD errors (same transition,
    same params), so the scatter is deterministic in effect."""
    # cast before use: TD errors arrive f64 when JAX_ENABLE_X64 promotes the
    # network params, but the tree (scan carry) must stay f32
    p = ((jnp.abs(td_err) + eps) ** alpha).astype(jnp.float32)
    L = ps.tree.shape[0] // 2
    tree = _tree_ascend(ps.tree.at[L + idx].set(p), L + idx.astype(jnp.int32))
    return ps._replace(tree=tree, max_p=jnp.maximum(ps.max_p, jnp.max(p)))


# ---------------------------------------------------------------------------
# numpy mirrors for the scalar reference loop
# ---------------------------------------------------------------------------

class ReplayBuffer:
    """Uniform replay (numpy circular store) for the scalar training loop."""

    def __init__(self, capacity: int, state_dim: int, n_actions: int, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.mask2 = np.zeros((capacity, n_actions), bool)
        self.ptr = 0
        self.full = False

    def push(self, s, a, r, s2, done, mask2) -> None:
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i], self.mask2[i] = s2, float(done), mask2
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def __len__(self) -> int:
        return self.capacity if self.full else self.ptr

    def sample(self, batch: int) -> dict:
        assert len(self) > 0, "sample from an empty replay buffer"
        idx = self.rng.integers(0, len(self), size=batch)
        return {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s2": self.s2[idx], "done": self.done[idx], "mask2": self.mask2[idx],
        }


class PrioritizedReplayBuffer(ReplayBuffer):
    """Numpy mirror of the JAX sum-tree PER (identical tree layout).

    ``sample`` returns ``(batch, indices, IS weights)``; priorities update
    per-leaf with an ancestor walk (the scalar loop pushes one transition at
    a time, so incremental updates beat full rebuilds here).
    """

    def __init__(self, capacity: int, state_dim: int, n_actions: int,
                 seed: int = 0, alpha: float = 0.6, eps: float = 1e-3):
        super().__init__(capacity, state_dim, n_actions, seed)
        self.alpha = alpha
        self.eps = eps
        self.leaves = _leaf_count(capacity)
        self.tree = np.zeros((2 * self.leaves,), np.float64)
        self.max_p = 1.0

    def _set(self, idx, priorities) -> None:
        for i, p in zip(np.atleast_1d(idx), np.atleast_1d(priorities)):
            j = self.leaves + int(i)
            self.tree[j] = p
            j //= 2
            while j >= 1:
                self.tree[j] = self.tree[2 * j] + self.tree[2 * j + 1]
                j //= 2

    def push(self, s, a, r, s2, done, mask2) -> None:
        i = self.ptr
        super().push(s, a, r, s2, done, mask2)
        self._set(i, self.max_p)

    def _query(self, v: float) -> int:
        k = 1
        while k < self.leaves:
            left = self.tree[2 * k]
            if v >= left and self.tree[2 * k + 1] > 0:
                v -= left
                k = 2 * k + 1
            else:
                k = 2 * k
        return k - self.leaves

    def sample(self, batch: int, beta: float = 0.4):
        assert len(self) > 0, "sample from an empty replay buffer"
        if self.alpha == 0.0:
            idx = self.rng.integers(0, len(self), size=batch)
            w = np.ones(batch, np.float32)
        else:
            total = self.tree[1]
            u = self.rng.uniform(size=batch)
            targets = (np.arange(batch) + u) * (total / batch)
            idx = np.array([self._query(t) for t in targets], np.int64)
            idx = np.minimum(idx, len(self) - 1)
            probs = self.tree[self.leaves + idx] / max(total, 1e-30)
            w = (len(self) * np.maximum(probs, 1e-30)) ** (-beta)
            w = (w / w.max()).astype(np.float32)
        out = {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s2": self.s2[idx], "done": self.done[idx], "mask2": self.mask2[idx],
        }
        return out, idx, w

    def update_priorities(self, idx, td_err) -> None:
        p = (np.abs(np.asarray(td_err, np.float64)) + self.eps) ** self.alpha
        self._set(idx, p)
        self.max_p = max(self.max_p, float(p.max()))
