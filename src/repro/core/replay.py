"""Experience replay: JAX-native on-device ring + numpy reference buffer.

``ReplayState`` + ``replay_init/push/sample`` form a pure-functional circular
buffer that lives on-device and threads through ``lax.scan`` as part of the
training carry — pushes are batched scatters, sampling is a jitted gather.
``ReplayBuffer`` keeps the original numpy API for the scalar (seed-equivalent)
training loop and the single-env agent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

FIELDS = ("s", "a", "r", "s2", "done", "mask2")


class ReplayState(NamedTuple):
    """Ring buffer contents + cursor; capacity is the static leading dim."""

    s: jnp.ndarray                   # (C, state_dim) f32
    a: jnp.ndarray                   # (C,) i32
    r: jnp.ndarray                   # (C,) f32
    s2: jnp.ndarray                  # (C, state_dim) f32
    done: jnp.ndarray                # (C,) f32
    mask2: jnp.ndarray               # (C, n_actions) bool
    ptr: jnp.ndarray                 # () i32 — next write slot
    size: jnp.ndarray                # () i32 — filled entries (<= C)

    @property
    def capacity(self) -> int:
        return self.a.shape[0]


def replay_init(capacity: int, state_dim: int, n_actions: int) -> ReplayState:
    return ReplayState(
        s=jnp.zeros((capacity, state_dim), jnp.float32),
        a=jnp.zeros((capacity,), jnp.int32),
        r=jnp.zeros((capacity,), jnp.float32),
        s2=jnp.zeros((capacity, state_dim), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        mask2=jnp.zeros((capacity, n_actions), bool),
        ptr=jnp.int32(0),
        size=jnp.int32(0),
    )


def replay_push(rs: ReplayState, batch: dict) -> ReplayState:
    """Write B transitions at the cursor (wrapping); pure, jit-able.

    Contract: every push to a given ring must use the **same** block size,
    and that size must divide the capacity (the training driver rounds
    capacity up to a multiple of B).  Uniform block-aligned writes keep each
    push one contiguous ``dynamic_update_slice`` — XLA updates those in
    place when the buffer is a loop carry, whereas a gather-indexed scatter
    copies the whole ring every scan step.  Mixed push sizes would leave the
    cursor mid-block where ``dynamic_update_slice`` clamps instead of
    wrapping; the divisibility assert below catches size/capacity mismatch,
    uniformity is the caller's obligation.
    """
    cap = rs.capacity
    n = batch["a"].shape[0]
    assert cap % n == 0, f"push size {n} must divide capacity {cap}"
    if not isinstance(rs.ptr, jax.core.Tracer):
        # eager path: catch mixed block sizes before they corrupt the ring
        # (inside jit the cursor is a tracer; the engine pushes uniformly)
        assert int(rs.ptr) % n == 0, (
            f"cursor {int(rs.ptr)} not aligned to push size {n} — all pushes "
            "to a ring must use one block size")

    def put(buf, new):
        new = new.astype(buf.dtype)
        start = (rs.ptr,) + (jnp.int32(0),) * (buf.ndim - 1)
        return jax.lax.dynamic_update_slice(buf, new, start)

    return rs._replace(
        s=put(rs.s, batch["s"]),
        a=put(rs.a, batch["a"]),
        r=put(rs.r, batch["r"]),
        s2=put(rs.s2, batch["s2"]),
        done=put(rs.done, batch["done"]),
        mask2=put(rs.mask2, batch["mask2"]),
        ptr=(rs.ptr + n) % cap,
        size=jnp.minimum(rs.size + n, cap),
    )


def replay_sample(rs: ReplayState, key: jax.Array, n: int) -> dict:
    """Uniform sample of n transitions from the filled region."""
    idx = jax.random.randint(key, (n,), 0, jnp.maximum(rs.size, 1))
    return {f: getattr(rs, f)[idx] for f in FIELDS}


class ReplayBuffer:
    """Uniform replay (numpy circular store) for the scalar training loop."""

    def __init__(self, capacity: int, state_dim: int, n_actions: int, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.mask2 = np.zeros((capacity, n_actions), bool)
        self.ptr = 0
        self.full = False

    def push(self, s, a, r, s2, done, mask2) -> None:
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i], self.mask2[i] = s2, float(done), mask2
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def __len__(self) -> int:
        return self.capacity if self.full else self.ptr

    def sample(self, batch: int) -> dict:
        idx = self.rng.integers(0, len(self), size=batch)
        return {
            "s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
            "s2": self.s2[idx], "done": self.done[idx], "mask2": self.mask2[idx],
        }
