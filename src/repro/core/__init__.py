"""The paper's primary contribution: RL-based co-optimization of hierarchical
resource partitioning (Level-1 mesh slicing + Level-2 fractional sharing) and
co-scheduling group selection. See DESIGN.md §2 for the GPU->TPU mapping."""
from repro.core.agent import DQNAgent, DQNConfig
from repro.core.baselines import POLICIES, oracle, time_sharing
from repro.core.env import CoScheduleEnv, EnvConfig
from repro.core.metrics import summarize
from repro.core.partition import Partition, Slice, enumerate_partitions
from repro.core.perfmodel import corun, corun_time, solo_run_time
from repro.core.problem import Schedule, validate_schedule
from repro.core.profiles import JobProfile, ProfileRepository, analytic_profile
from repro.core.scheduler import RLScheduler
from repro.core.train import TrainConfig, heldout_split, train_agent
from repro.core.workloads import make_queue, make_zoo, paper_queues

__all__ = [
    "CoScheduleEnv", "DQNAgent", "DQNConfig", "EnvConfig", "JobProfile",
    "POLICIES", "Partition", "ProfileRepository", "RLScheduler", "Schedule",
    "Slice", "TrainConfig", "analytic_profile", "corun", "corun_time",
    "enumerate_partitions", "heldout_split", "make_queue", "make_zoo",
    "oracle", "paper_queues", "solo_run_time", "summarize", "time_sharing",
    "train_agent", "validate_schedule",
]
