"""The paper's primary contribution: RL-based co-optimization of hierarchical
resource partitioning (Level-1 mesh slicing + Level-2 fractional sharing) and
co-scheduling group selection. See DESIGN.md §2 for the GPU->TPU mapping."""
from repro.core.agent import DQNAgent, DQNConfig, act_batch, beta_at, epsilon_at
from repro.core.baselines import POLICIES, oracle, time_sharing
from repro.core.env import (
    CoScheduleEnv, DispatchContext, EnvConfig, EnvState, ObsContext,
    VecCoScheduleEnv, dispatch_obs_context, zero_context,
)
from repro.core.metrics import summarize
from repro.core.network import widen_dqn_params
from repro.core.partition import Partition, Slice, enumerate_partitions
from repro.core.perfmodel import corun, corun_time, solo_run_time
from repro.core.problem import Schedule, validate_schedule
from repro.core.profiles import JobProfile, ProfileRepository, analytic_profile
from repro.core.replay import (
    PrioritizedReplayBuffer, PrioritizedReplayState, ReplayBuffer, ReplayState,
    per_init, per_push, per_sample, per_update, replay_init, replay_push,
    replay_sample,
)
from repro.core.scheduler import RLScheduler
from repro.core.train import (
    TrainConfig, TrainOnlineConfig, heldout_split, train_agent,
    train_agent_scalar, train_online,
)
from repro.core.workloads import make_queue, make_zoo, paper_queues

__all__ = [
    "CoScheduleEnv", "DQNAgent", "DQNConfig", "DispatchContext", "EnvConfig",
    "EnvState", "JobProfile", "ObsContext", "POLICIES", "Partition",
    "PrioritizedReplayBuffer", "PrioritizedReplayState", "ProfileRepository",
    "RLScheduler", "ReplayBuffer", "ReplayState", "Schedule", "Slice",
    "TrainConfig", "TrainOnlineConfig", "VecCoScheduleEnv", "act_batch",
    "analytic_profile",
    "beta_at", "corun", "corun_time", "dispatch_obs_context",
    "enumerate_partitions", "epsilon_at", "heldout_split", "make_queue",
    "make_zoo", "oracle", "paper_queues", "per_init", "per_push",
    "per_sample", "per_update", "replay_init", "replay_push",
    "replay_sample", "solo_run_time", "summarize", "time_sharing",
    "train_agent", "train_agent_scalar", "train_online", "validate_schedule",
    "widen_dqn_params", "zero_context",
]
