"""JAX mirror of the co-run performance model for in-graph RL rewards.

Everything ``perfmodel.py`` computes per (group, partition) — roofline terms,
water-filled bandwidth contention, the phase simulation over completion
events — is reproduced here as fixed-shape ``jnp`` operations so the
environment's close-group reward can run under ``jit``/``vmap``/``scan``.

Two precomputed array bundles make that possible:

  * ``PartitionTable`` — static per ``EnvConfig``: slot -> (slice id, units,
    Level-2 share) for every partition in the curated table, padded to
    ``c_max`` slots.
  * ``QueueArrays``   — static per queue: per-job roofline terms at every
    slice width, solo times, counter features, and window means.

The scalar Python model stays the float64 reference; the parity test in
``tests/test_vectorized_train.py`` pins this float32 mirror to it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import N_UNITS, Partition, find_offsets, solo_partition
from repro.core.perfmodel import KAPPA_INTERFERENCE, SIGMA_QUANTUM, corun
from repro.core.profiles import FEATURES, JobProfile

UNIT_SIZES = (1, 2, 4, 8)            # valid slice widths (powers of two)
_FP_ITERS = 30                       # perfmodel fixed-point iteration budget


class PartitionTable(NamedTuple):
    """Curated partition table flattened to padded per-slot arrays."""

    slot_valid: jnp.ndarray          # (P, S) bool — slot exists
    slot_slice: jnp.ndarray          # (P, S) i32 — slice id within partition
    slot_units_idx: jnp.ndarray      # (P, S) i32 — index into UNIT_SIZES
    slot_units: jnp.ndarray          # (P, S) f32 — slice width in units
    slot_beta: jnp.ndarray           # (P, S) f32 — Level-2 compute share
    slice_shared: jnp.ndarray        # (P, S) bool — slice id s holds >1 share
    arity: jnp.ndarray               # (P,) i32


class QueueArrays(NamedTuple):
    """Per-queue job terms; leading axis is the (padded) window slot."""

    features: jnp.ndarray            # (W, F) f32 — paper counter features
    valid: jnp.ndarray               # (W,) bool — real job (not padding)
    comp: jnp.ndarray                # (W, U) f32 — compute seconds/step
    mem: jnp.ndarray                 # (W, U) f32 — HBM seconds/step
    collb: jnp.ndarray               # (W, U) f32 — collective-bytes seconds
    colll: jnp.ndarray               # (W, U) f32 — collective latency chain
    fixedt: jnp.ndarray              # (W, U) f32 — fixed + serial seconds
    steps: jnp.ndarray               # (W,) f32 — job length in steps
    solo: jnp.ndarray                # (W,) f32 — SoloRunTime
    cpct: jnp.ndarray                # (W,) f32 — Compute (SM) [%]
    mpct: jnp.ndarray                # (W,) f32 — Memory [%]
    mean_c: jnp.ndarray              # () f32 — window mean of cpct
    mean_m: jnp.ndarray              # () f32 — window mean of mpct
    mean_d: jnp.ndarray              # () f32 — window mean of solo


def build_partition_table(partitions: list[Partition], c_max: int) -> PartitionTable:
    P, S = len(partitions), c_max
    valid = np.zeros((P, S), bool)
    slot_slice = np.zeros((P, S), np.int32)
    units_idx = np.zeros((P, S), np.int32)
    units = np.ones((P, S), np.float32)
    beta = np.ones((P, S), np.float32)
    shared = np.zeros((P, S), bool)
    arity = np.zeros((P,), np.int32)
    for p_i, p in enumerate(partitions):
        arity[p_i] = p.arity
        for k, (si, s, b) in enumerate(p.slots):
            valid[p_i, k] = True
            slot_slice[p_i, k] = si
            units_idx[p_i, k] = UNIT_SIZES.index(s.units)
            units[p_i, k] = s.units
            beta[p_i, k] = b
        for si, s in enumerate(p.slices):
            shared[p_i, si] = len(s.shares) > 1
    return PartitionTable(*(jnp.asarray(a) for a in
                            (valid, slot_slice, units_idx, units, beta, shared, arity)))


def queue_arrays(queue: list[JobProfile], window: int) -> QueueArrays:
    """Precompute all job terms the jitted reward needs (numpy, once/queue)."""
    assert len(queue) <= window, (len(queue), window)
    W, U, F = window, len(UNIT_SIZES), len(FEATURES)
    feats = np.zeros((W, F), np.float32)
    valid = np.zeros((W,), bool)
    comp, mem, collb, colll, fixedt = (np.zeros((W, U), np.float32) for _ in range(5))
    fixedt[:] = 1.0                   # harmless nonzero for padded rows
    steps = np.ones((W,), np.float32)
    solo = np.zeros((W,), np.float32)
    cpct = np.zeros((W,), np.float32)
    mpct = np.zeros((W,), np.float32)
    for i, j in enumerate(queue):
        valid[i] = True
        feats[i] = j.features()
        for u_i, u in enumerate(UNIT_SIZES):
            c, m, x = j.terms(u)      # torus factor defaults to the slice's
            comp[i, u_i], mem[i, u_i], collb[i, u_i] = c, m, x
            colll[i, u_i] = j.coll_latency(u)
            fixedt[i, u_i] = j.fixed_latency(u) + j.serial_s
        steps[i] = j.steps
        solo[i] = j.solo_time()
        cpct[i] = j.compute_pct
        mpct[i] = j.memory_pct
    n = max(1, len(queue))
    return QueueArrays(
        features=jnp.asarray(feats), valid=jnp.asarray(valid),
        comp=jnp.asarray(comp), mem=jnp.asarray(mem), collb=jnp.asarray(collb),
        colll=jnp.asarray(colll), fixedt=jnp.asarray(fixedt),
        steps=jnp.asarray(steps), solo=jnp.asarray(solo),
        cpct=jnp.asarray(cpct), mpct=jnp.asarray(mpct),
        mean_c=jnp.float32(cpct[:len(queue)].sum() / n),
        mean_m=jnp.float32(mpct[:len(queue)].sum() / n),
        mean_d=jnp.float32(solo[:len(queue)].sum() / n),
    )


def stack_queues(qas: list[QueueArrays]) -> QueueArrays:
    """Batch per-queue arrays along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *qas)


class JobTermsTable(NamedTuple):
    """Per-*job* roofline terms, gatherable into window ``QueueArrays``.

    ``queue_arrays`` lays terms out per window slot on the host; the
    vectorized serving engine instead precomputes them once per distinct
    job and gathers rows in-graph at each window formation.  Row ``J``
    (one past the last job) is the padding row — the same harmless
    values ``queue_arrays`` writes for empty slots (``fixedt = 1``,
    ``steps = 1``, everything else 0), so a gather of the padding index
    reproduces a padded window slot bit-for-bit.
    """

    features: jnp.ndarray            # (J+1, F) f32
    comp: jnp.ndarray                # (J+1, U) f32
    mem: jnp.ndarray                 # (J+1, U) f32
    collb: jnp.ndarray               # (J+1, U) f32
    colll: jnp.ndarray               # (J+1, U) f32
    fixedt: jnp.ndarray              # (J+1, U) f32
    steps: jnp.ndarray               # (J+1,) f32
    solo: jnp.ndarray                # (J+1,) f32
    cpct: jnp.ndarray                # (J+1,) f32
    mpct: jnp.ndarray                # (J+1,) f32


def job_terms_table(jobs: list[JobProfile]) -> JobTermsTable:
    """Precompute :class:`JobTermsTable` rows for ``jobs`` (+ padding row)."""
    J, U, F = len(jobs), len(UNIT_SIZES), len(FEATURES)
    feats = np.zeros((J + 1, F), np.float32)
    comp, mem, collb, colll, fixedt = (np.zeros((J + 1, U), np.float32)
                                       for _ in range(5))
    fixedt[:] = 1.0
    steps = np.ones((J + 1,), np.float32)
    solo = np.zeros((J + 1,), np.float32)
    cpct = np.zeros((J + 1,), np.float32)
    mpct = np.zeros((J + 1,), np.float32)
    for i, j in enumerate(jobs):
        feats[i] = j.features()
        for u_i, u in enumerate(UNIT_SIZES):
            c, m, x = j.terms(u)
            comp[i, u_i], mem[i, u_i], collb[i, u_i] = c, m, x
            colll[i, u_i] = j.coll_latency(u)
            fixedt[i, u_i] = j.fixed_latency(u) + j.serial_s
        steps[i] = j.steps
        solo[i] = j.solo_time()
        cpct[i] = j.compute_pct
        mpct[i] = j.memory_pct
    return JobTermsTable(*(jnp.asarray(a) for a in
                           (feats, comp, mem, collb, colll, fixedt,
                            steps, solo, cpct, mpct)))


def build_fit_table(partitions: list[Partition]) -> jnp.ndarray:
    """(P, 2**N_UNITS) f32 — does partition ``p`` first-fit busy mask ``m``?

    ``fit[p, m] = 1.0`` iff :func:`~repro.core.partition.find_offsets` places
    every slice of partition ``p`` onto the free units of mask ``m`` (bit u of
    ``m`` set = unit u busy).  Precomputed once per ``EnvConfig`` so the
    arrival-aware environment's close-group shaping — a penalty for choosing
    a partition that cannot start on the *current* free-unit shape — is a
    single in-graph gather (see ``EnvConfig.ctx_fit_weight``).  Fit is
    evaluated on the planned slice widths; dispatch-time width narrowing
    (``to_placements``) can only make a placement easier, so the penalty is
    a conservative blocking signal.
    """
    P, M = len(partitions), 1 << N_UNITS
    fits = np.zeros((P, M), np.float32)
    for p_i, p in enumerate(partitions):
        for m in range(M):
            free = [not (m >> u) & 1 for u in range(N_UNITS)]
            if find_offsets(p, free) is not None:
                fits[p_i, m] = 1.0
    return jnp.asarray(fits)


# ---------------------------------------------------------------------------
# water-filling + phase simulation (fixed-shape mirrors of perfmodel.py)
# ---------------------------------------------------------------------------

def water_fill_vec(demands: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """``perfmodel.water_fill`` over an S-lane vector with an active mask.

    The Python loop removes >=1 sated lane per iteration or terminates, so
    it reaches the fixed point in at most S iterations; the while form exits
    as soon as capacity is exhausted or everyone is sated.
    """

    def cond(carry):
        _, remaining, act = carry
        return jnp.any(act) & (remaining > 1e-12)

    def body(carry):
        alloc, remaining, act = carry
        fair = remaining / jnp.maximum(jnp.sum(act), 1)
        sated = act & (demands - alloc <= fair + 1e-15)
        any_sated = jnp.any(sated)
        deficit = jnp.sum(jnp.where(sated, demands - alloc, 0.0))
        remaining = jnp.where(any_sated, remaining - deficit, 0.0)
        alloc = jnp.where(sated, demands,
                          jnp.where(~any_sated & act, alloc + fair, alloc))
        return alloc, remaining, act & ~sated

    alloc, _, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros_like(demands), jnp.float32(1.0), active))
    return alloc


def _slice_step_times(c, m, xb, xl, fx, active, shared_flag):
    """Per-step times for the active co-residents of one slice (S lanes)."""
    n_active = jnp.sum(active)
    multi = n_active > 1
    shared_mem = shared_flag & multi

    def cond(carry):
        _, _, _, _, delta, i = carry
        return (i < _FP_ITERS) & (delta >= 1e-9)

    def body(carry):
        mem_t, coll_t, _, _, _, i = carry
        st = jnp.maximum(jnp.maximum(c, mem_t), coll_t + xl) + fx
        mem_u = jnp.minimum(1.0, m / st)
        coll_u = jnp.minimum(1.0, xb / st)
        ma = water_fill_vec(mem_u, active)
        ca = water_fill_vec(coll_u, active)
        use_m = shared_mem & (ma > 1e-12) & (mem_u > ma + 1e-12)
        use_x = multi & (ca > 1e-12) & (coll_u > ca + 1e-12)
        tgt_m = jnp.where(use_m, m / jnp.maximum(ma, 1e-30), m)
        tgt_x = jnp.where(use_x, xb / jnp.maximum(ca, 1e-30), xb)
        delta = jnp.sum(jnp.where(active, jnp.abs(tgt_m - mem_t)
                                  + jnp.abs(tgt_x - coll_t), 0.0))
        return (mem_t + 0.5 * (tgt_m - mem_t), coll_t + 0.5 * (tgt_x - coll_t),
                mem_u, coll_u, delta, i + 1)

    mem_t, coll_t, mem_u, coll_u, _, _ = jax.lax.while_loop(
        cond, body,
        (m, xb, jnp.zeros_like(m), jnp.zeros_like(m), jnp.float32(jnp.inf),
         jnp.int32(0)))
    sum_mu = jnp.sum(jnp.where(active, mem_u, 0.0))
    sum_cu = jnp.sum(jnp.where(active, coll_u, 0.0))
    km = jnp.where(shared_mem, 1.0 + KAPPA_INTERFERENCE * (sum_mu - mem_u), 1.0)
    kx = jnp.where(multi, 1.0 + KAPPA_INTERFERENCE * (sum_cu - coll_u), 1.0)
    t = jnp.maximum(jnp.maximum(c, mem_t * km), (coll_t + xl) * kx) + fx
    return t * jnp.where(multi, 1.0 + SIGMA_QUANTUM * (n_active - 1), 1.0)


def _simulate_slice(c, m, xb, xl, fx, steps, members, shared_flag):
    """Phase simulation of one slice -> per-lane finish times.

    Completion is detected both by remaining-work underflow (the Python
    criterion, too strict in float32) and by achieving the phase's minimum
    finish time, so the argmin job always completes its phase.
    """
    S = c.shape[-1]

    def cond(carry):
        _, active, _, _, i = carry
        return jnp.any(active) & (i < S)

    def body(carry):
        remaining, active, t, finish, i = carry
        st = _slice_step_times(c, m, xb, xl, fx, active, shared_flag)
        tt = jnp.where(active, remaining * st, jnp.inf)
        dt = jnp.min(tt)
        new_rem = jnp.where(active, remaining - dt / st, remaining)
        done_now = active & ((new_rem <= 1e-9) | (tt <= dt * (1.0 + 1e-6)))
        finish = jnp.where(done_now, t + dt, finish)
        return new_rem, active & ~done_now, t + dt, finish, i + 1

    _, _, _, finish, _ = jax.lax.while_loop(
        cond, body,
        (jnp.where(members, steps, 0.0), members, jnp.float32(0.0),
         jnp.zeros_like(steps), jnp.int32(0)))
    return finish


def group_metrics(table: PartitionTable, qa: QueueArrays,
                  group_idx: jnp.ndarray, group_size: jnp.ndarray,
                  p_idx: jnp.ndarray, units_idx: jnp.ndarray | None = None,
                  with_finish: bool = False):
    """(co-run makespan, Σ solo time, Σ r_i) for the group under partition p_idx.

    The makespan/solo pair is the in-graph mirror of ``corun_time`` /
    ``solo_run_time`` — it powers both the Table VI reward and the
    device-resident evaluation rollout's relative-throughput accumulators.

    ``units_idx`` (per-slot width index, shape (S,)) overrides the
    partition's planned slot widths for the roofline terms only — the
    in-graph mirror of the placement layer's dedicated-slice right-sizing
    (``to_placements`` shrinks a single-share slice to ``requested_units``
    without touching MPS shares or β, and co-run simulates slices
    independently, so swapping the width terms *is* the fitted co-run).
    ``with_finish=True`` additionally returns the per-slot finish times,
    which the vectorized serving engine records per job.
    """
    S = group_idx.shape[0]
    W = qa.steps.shape[0]
    slot_ok = table.slot_valid[p_idx] & (jnp.arange(S) < group_size)
    j = jnp.clip(group_idx, 0, W - 1)
    u = table.slot_units_idx[p_idx] if units_idx is None else units_idx
    beta = table.slot_beta[p_idx]
    c = qa.comp[j, u] / beta
    m, xb, xl, fx = qa.mem[j, u], qa.collb[j, u], qa.colll[j, u], qa.fixedt[j, u]
    steps = qa.steps[j]
    sl = table.slot_slice[p_idx]

    def per_slice(s, finish):
        mem = slot_ok & (sl == s)
        f = _simulate_slice(c, m, xb, xl, fx, steps, mem,
                            table.slice_shared[p_idx, s])
        return jnp.where(mem, f, finish)

    finish = jax.lax.fori_loop(0, S, per_slice, jnp.zeros((S,), jnp.float32))
    makespan = jnp.max(jnp.where(slot_ok, finish, 0.0))
    solo = jnp.sum(jnp.where(slot_ok, qa.solo[j], 0.0))
    sm_alloc = (table.slot_units[p_idx] / N_UNITS) * beta
    mem_alloc = table.slot_units[p_idx] / N_UNITS
    cr = qa.cpct[j] / jnp.maximum(qa.mean_c, 1e-9)
    mr = qa.mpct[j] / jnp.maximum(qa.mean_m, 1e-9)
    dr = qa.solo[j] / jnp.maximum(qa.mean_d, 1e-9)
    ri = (sm_alloc * cr + mem_alloc * mr) * dr ** 2
    ri_sum = jnp.sum(jnp.where(slot_ok, ri, 0.0))
    if with_finish:
        return makespan, solo, ri_sum, jnp.where(slot_ok, finish, 0.0)
    return makespan, solo, ri_sum


def solo_duration_table(jobs: list[JobProfile]) -> np.ndarray:
    """``(J, len(UNIT_SIZES))`` float64 solo makespans per (job, width).

    Host-side, through the float64 reference model: entry ``[j, u]`` is
    ``corun([job_j], solo_partition(UNIT_SIZES[u])).makespan`` — bit-equal
    to the heap simulator's per-group ``corun`` predictions for solo
    placements (a single job's fixed point converges in one iteration, so
    this is exactly ``steps * step_time(width)``).  The vectorized engine
    precomputes this table and casts once to float32, which is what makes
    its discrete decisions identical to the heap's.
    """
    out = np.zeros((len(jobs), len(UNIT_SIZES)), np.float64)
    for i, job in enumerate(jobs):
        for u, w in enumerate(UNIT_SIZES):
            out[i, u] = corun([job], solo_partition(w)).makespan
    return out


def group_reward(table: PartitionTable, qa: QueueArrays,
                 group_idx: jnp.ndarray, group_size: jnp.ndarray,
                 p_idx: jnp.ndarray, r_i_weight: float,
                 r_f_scale: float) -> jnp.ndarray:
    """Paper Table VI close-group reward: r_i_weight * Σ r_i + r_f."""
    makespan, solo, ri = group_metrics(table, qa, group_idx, group_size, p_idx)
    rf = jnp.where(makespan > 0,
                   (solo / jnp.maximum(makespan, 1e-30) - 1.0) * r_f_scale, 0.0)
    return r_i_weight * ri + rf
