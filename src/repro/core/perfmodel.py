"""Co-run performance model: SoloRunTime / CoRunTime (paper Table I functions).

On real hardware these are measurements; in this CPU-only container they are
backed by a roofline contention model over the same per-job artifacts the
dry-run produces (DESIGN.md §5):

  * compute: a job with Level-2 share β gets β of the slice's MXU quanta
    -> compute term / β (static shares = MPS semantics; idle share is wasted
    when a co-resident finishes early, as on real MPS).
  * memory: co-residents on a slice share its HBM bandwidth. Water-filling
    allocation — each job demands its solo bandwidth utilization; low-demand
    jobs keep full speed (complementary CI+MI mixes co-locate well, paper
    Fig. 3), oversubscribed slices inflate everyone else.
  * collective: private per job (its own sub-ring), with the torus factor
    charged on split slices.
  * quantum-switch overhead: multiplicative (1 + sigma*(n_active-1)) — the
    VMEM/cache refill cost of time multiplexing (MPS context overhead
    analogue).

Jobs finish at different times; a phase simulation advances the group through
completion events, re-solving the bandwidth allocation after each (bandwidth
is physically freed; compute shares stay static).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.partition import Partition, Slice
from repro.core.profiles import JobProfile

SIGMA_QUANTUM = 0.03          # per-extra-co-resident switch overhead
KAPPA_INTERFERENCE = 0.35     # shared-slice HBM/ICI efficiency loss per unit
                              # of co-resident demand (stream mixing; the
                              # contention MIG-style isolation removes — paper Fig. 4)


@dataclass
class CoRunResult:
    makespan: float                      # CoRunTime(JS, R)
    finish_times: list[float]            # per job (CoRunAppTime)
    solo_times: list[float]              # per job (SoloRunAppTime)

    @property
    def solo_total(self) -> float:
        return sum(self.solo_times)

    @property
    def throughput_gain(self) -> float:
        return self.solo_total / self.makespan if self.makespan > 0 else 0.0


def water_fill(demands: list[float], capacity: float = 1.0) -> list[float]:
    """Allocate bandwidth fractions: min(demand, fair share), redistributing
    slack to the hungry (classic water-filling)."""
    n = len(demands)
    if n == 0:
        return []
    alloc = [0.0] * n
    remaining = capacity
    active = list(range(n))
    while active and remaining > 1e-12:
        fair = remaining / len(active)
        sated = [i for i in active if demands[i] - alloc[i] <= fair + 1e-15]
        if sated:
            for i in sated:
                remaining -= demands[i] - alloc[i]
                alloc[i] = demands[i]
            active = [i for i in active if i not in sated]
        else:
            for i in active:
                alloc[i] += fair
            remaining = 0.0
    return alloc


def _slice_step_times(jobs: list[JobProfile], betas: list[float], s: Slice,
                      active: list[bool]) -> list[float]:
    """Current per-step time for each active job on slice `s`.

    HBM bandwidth and ICI link bandwidth are physically shared: each job's
    bandwidth *utilization* (busy-time fraction) is water-filled against unit
    capacity, iterated to a fixed point (stretching a job's step lowers its
    utilization, freeing bandwidth). The latency component of the collective
    chain (tiny payloads) and the κ stream-mixing loss contend without
    consuming bandwidth. Compute is divided by the static β shares.
    """
    n_active = sum(active)
    idx = [j for j in range(len(jobs)) if active[j]]
    base = []
    for j in idx:
        c, m, x = jobs[j].terms(s.units, s.torus_factor)
        base.append({
            "c": c / betas[j], "m": m, "xb": x,
            "xl": jobs[j].coll_latency(s.units),
            "fixed": jobs[j].fixed_latency(s.units) + jobs[j].serial_s,
        })
    shared_mem = s.shared_memory and n_active > 1
    multi = n_active > 1
    mem_t = [b["m"] for b in base]        # memory time under current bw grant
    coll_t = [b["xb"] for b in base]      # collective-bytes time, ditto
    mem_u = [0.0] * len(base)
    coll_u = [0.0] * len(base)

    for _ in range(30):
        st = [max(b["c"], mt, ct + b["xl"]) + b["fixed"]
              for b, mt, ct in zip(base, mem_t, coll_t)]
        mem_u = [min(1.0, b["m"] / t) for b, t in zip(base, st)]
        coll_u = [min(1.0, b["xb"] / t) for b, t in zip(base, st)]
        ma = water_fill(mem_u) if shared_mem else mem_u
        ca = water_fill(coll_u) if multi else coll_u
        delta = 0.0
        for i, (b, u_m, a_m, u_x, a_x) in enumerate(zip(base, mem_u, ma, coll_u, ca)):
            tgt_m = b["m"] / a_m if (shared_mem and a_m > 1e-12 and u_m > a_m + 1e-12) else b["m"]
            tgt_x = b["xb"] / a_x if (multi and a_x > 1e-12 and u_x > a_x + 1e-12) else b["xb"]
            delta += abs(tgt_m - mem_t[i]) + abs(tgt_x - coll_t[i])
            mem_t[i] += 0.5 * (tgt_m - mem_t[i])      # damped toward equilibrium
            coll_t[i] += 0.5 * (tgt_x - coll_t[i])
        if delta < 1e-9:
            break

    out = [float("inf")] * len(jobs)
    for i, (b, mt, ct, j) in enumerate(zip(base, mem_t, coll_t, idx)):
        km = 1.0 + KAPPA_INTERFERENCE * (sum(mem_u) - mem_u[i]) if shared_mem else 1.0
        kx = 1.0 + KAPPA_INTERFERENCE * (sum(coll_u) - coll_u[i]) if multi else 1.0
        t = max(b["c"], mt * km, (ct + b["xl"]) * kx) + b["fixed"]
        if n_active > 1:
            t *= 1.0 + SIGMA_QUANTUM * (n_active - 1)
        out[j] = t
    return out


def _simulate_slice(jobs: list[JobProfile], betas: list[float], s: Slice) -> list[float]:
    """Phase simulation of one slice; returns per-job finish times."""
    n = len(jobs)
    remaining = [float(j.steps) for j in jobs]
    active = [True] * n
    finish = [0.0] * n
    t = 0.0
    for _ in range(n):  # at most n phases
        if not any(active):
            break
        st = _slice_step_times(jobs, betas, s, active)
        # time to next completion
        dt = min(remaining[j] * st[j] for j in range(n) if active[j])
        for j in range(n):
            if active[j]:
                remaining[j] -= dt / st[j]
                if remaining[j] <= 1e-9:
                    active[j] = False
                    finish[j] = t + dt
        t += dt
    return finish


def corun(group: list[JobProfile], partition: Partition) -> CoRunResult:
    """CoRunTime for `group` under `partition` (jobs -> slots in order)."""
    slots = partition.slots
    assert len(group) == len(slots), (len(group), partition.label)
    # bucket group positions by slice (positional, so a job object appearing
    # twice in a group keeps both finish times)
    by_slice: dict[int, tuple[list[int], list[float], Slice]] = {}
    for pos, (si, s, beta) in enumerate(slots):
        bucket = by_slice.setdefault(si, ([], [], s))
        bucket[0].append(pos)
        bucket[1].append(beta)
    finish = [0.0] * len(group)
    for si, (positions, betas, s) in by_slice.items():
        fts = _simulate_slice([group[p] for p in positions], betas, s)
        for pos, ft in zip(positions, fts):
            finish[pos] = ft
    solo = [j.solo_time() for j in group]
    return CoRunResult(makespan=max(finish), finish_times=finish, solo_times=solo)


def corun_time(group: list[JobProfile], partition: Partition) -> float:
    return corun(group, partition).makespan


def solo_run_time(group: list[JobProfile]) -> float:
    """Time-sharing: run one by one with the full pod."""
    return sum(j.solo_time() for j in group)


def best_assignment(group: list[JobProfile], partition: Partition) -> tuple[float, tuple[int, ...]]:
    """Min CoRunTime over job->slot orderings (paper's C! assignment space)."""
    import itertools

    best, best_perm = float("inf"), tuple(range(len(group)))
    for perm in itertools.permutations(range(len(group))):
        t = corun_time([group[i] for i in perm], partition)
        if t < best:
            best, best_perm = t, perm
    return best, best_perm
