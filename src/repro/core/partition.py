"""Hierarchical partition space for a TPU pod (paper Fig. 2 / Table VII analogue).

Level 1 (physical, ≈MIG GI): the 16x16 pod is cut along the data axis into
rectangular sub-mesh *slices* measured in units (1 unit = 2 rows = 32 chips,
8 units per pod — the analogue of the A100's 8 GPCs). Valid slice widths are
powers of two (XLA-friendly sub-meshes) — the TPU-native counterpart of MIG's
19-variant restriction. Cutting the torus breaks the wraparound link on the
cut axis (torus_factor 1/2 on data-axis collectives) — the TPU-native cost of
physical partitioning, standing in for MIG's lost GPC.

Level 2 (logical, ≈MPS): jobs co-resident on the same slice receive
fractional compute shares β (time-quantum multiplexing) while *sharing* the
slice's HBM bandwidth — flexible but interference-prone, exactly MPS's
semantics.

A ``Partition`` is an ordered list of slices with per-slot shares; jobs map
to slots in group-selection order (the agent learns the ordering, matching
the paper's C! assignment space).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

N_UNITS = 8              # slice units per pod (1 unit = 32 chips on a 16x16 pod)
CHIPS_PER_UNIT = 32
POD_CHIPS = N_UNITS * CHIPS_PER_UNIT


@dataclass(frozen=True)
class Slice:
    units: int                       # width in units (1,2,4,8)
    shares: tuple[float, ...]        # Level-2 compute shares (sum <= 1)

    def __post_init__(self):
        assert self.units in (1, 2, 4, 8), self.units
        assert all(s > 0 for s in self.shares)
        assert sum(self.shares) <= 1.0 + 1e-9

    @property
    def chips(self) -> int:
        return self.units * CHIPS_PER_UNIT

    @property
    def shared_memory(self) -> bool:
        return len(self.shares) > 1

    @property
    def torus_factor(self) -> float:
        # full-pod slice keeps the data-axis wraparound ring; split slices don't
        return 1.0 if self.units == N_UNITS else 0.5


@dataclass(frozen=True)
class Partition:
    slices: tuple[Slice, ...]
    label: str

    @property
    def arity(self) -> int:
        return sum(len(s.shares) for s in self.slices)

    @property
    def slots(self) -> list[tuple[int, Slice, float]]:
        """Ordered (slice_idx, slice, share) job slots."""
        out = []
        for i, s in enumerate(self.slices):
            for beta in s.shares:
                out.append((i, s, beta))
        return out

    @property
    def total_units(self) -> int:
        return sum(s.units for s in self.slices)

    @property
    def style(self) -> str:
        """mps | mig | hier | solo — for baseline filtering (paper §V-A4)."""
        if self.arity == 1:
            return "solo"
        if len(self.slices) == 1 and self.slices[0].units == N_UNITS:
            return "mps"
        if all(len(s.shares) == 1 for s in self.slices):
            return "mig"
        return "hier"


VALID_WIDTHS = (1, 2, 4, 8)     # MIG-style power-of-two slice widths


def _width_label(units: int) -> str:
    """``1m`` / ``.5m`` / ``.25m`` / ``.125m`` — fraction-of-pod suffix."""
    return "1m" if units == N_UNITS else f"{units / N_UNITS:g}m".lstrip("0")


def slice_label(slices: tuple[Slice, ...]) -> str:
    """Regenerate a label in the table's grammar for derived partitions
    (width-fitted placements are not table entries, so they re-label)."""
    parts = []
    for s in slices:
        w = _width_label(s.units)
        if len(s.shares) == 1:
            parts.append(f"[{{{s.shares[0]:g}}},{w}]")
        else:
            parts.append("[" + "+".join(f"({b:g})" for b in s.shares) + f",{w}]")
    return "+".join(parts)


def _mps(label, *shares) -> Partition:
    return Partition((Slice(N_UNITS, tuple(shares)),), label)


def _p(label, *slices) -> Partition:
    return Partition(tuple(slices), label)


def enumerate_partitions(c_max: int = 4) -> list[Partition]:
    """The curated partition table (Table VII analogue). Stable order —
    the DQN's action indices point into this list."""
    table: list[Partition] = [
        _p("[{1.0},1m]", Slice(8, (1.0,))),                       # C=1 solo
    ]
    # --- C=2 ---------------------------------------------------------------
    table += [
        _mps(f"[({a:.1f})+({1-a:.1f}),1m]", a, round(1 - a, 2))
        for a in (0.1, 0.2, 0.3, 0.4, 0.5)
    ]
    table += [_p("[{.5},.5m]+[{.5},.5m]", Slice(4, (1.0,)), Slice(4, (1.0,)))]
    # --- C=3 ---------------------------------------------------------------
    table += [
        _mps("[(.1)+(.1)+(.8),1m]", 0.1, 0.1, 0.8),
        _mps("[(.2)+(.2)+(.6),1m]", 0.2, 0.2, 0.6),
        _mps("[(.2)+(.3)+(.5),1m]", 0.2, 0.3, 0.5),
        _mps("[(.33)+(.33)+(.34),1m]", 0.33, 0.33, 0.34),
        _p("[{.5},.5m]+[(.5)+(.5),{.5},.5m]", Slice(4, (1.0,)), Slice(4, (0.5, 0.5))),
        _p("[{.5},.5m]+[(.25)+(.75),{.5},.5m]", Slice(4, (1.0,)), Slice(4, (0.25, 0.75))),
        _p("[{.5},.5m]+[{.25},.25m]+[{.25},.25m]",
           Slice(4, (1.0,)), Slice(2, (1.0,)), Slice(2, (1.0,))),
    ]
    # --- C=4 ---------------------------------------------------------------
    table += [
        _mps("[(.1)+(.1)+(.1)+(.7),1m]", 0.1, 0.1, 0.1, 0.7),
        _mps("[(.25)x4,1m]", 0.25, 0.25, 0.25, 0.25),
        _mps("[(.1)+(.2)+(.3)+(.4),1m]", 0.1, 0.2, 0.3, 0.4),
        _p("[(.5)+(.5),{.5},.5m]x2",
           Slice(4, (0.5, 0.5)), Slice(4, (0.5, 0.5))),
        _p("[(.25)+(.75),{.5},.5m]x2",
           Slice(4, (0.25, 0.75)), Slice(4, (0.25, 0.75))),
        _p("[(.5)+(.5),{.5},.5m]+[{.25},.25m]x2",
           Slice(4, (0.5, 0.5)), Slice(2, (1.0,)), Slice(2, (1.0,))),
        _p("[{.25},.25m]x4",
           Slice(2, (1.0,)), Slice(2, (1.0,)), Slice(2, (1.0,)), Slice(2, (1.0,))),
    ]
    return [p for p in table if p.arity <= c_max]


def solo_partition(units: int = N_UNITS) -> Partition:
    """Single-slot partition on a ``units``-wide slice.

    The full-pod default is time sharing's unit and the slot unprofiled
    first-sight jobs run on in the online protocol; narrower widths are the
    placement layer's *right-sized* solo slices (a job whose trace carries a
    ``meta["units"]`` hint occupies only the slice it can actually use,
    leaving the rest of the pod for concurrent groups)."""
    if units == N_UNITS:
        return enumerate_partitions(1)[0]
    s = Slice(units, (1.0,))
    return Partition((s,), slice_label((s,)))


def aligned_offsets(width: int) -> tuple[int, ...]:
    """Valid start offsets for a ``width``-unit slice: buddy alignment (a
    power-of-two slice starts at a multiple of its width), the TPU-native
    counterpart of MIG's fixed GPC-slice anchor points."""
    assert width in VALID_WIDTHS, width
    return tuple(range(0, N_UNITS, width))


def find_offsets(partition: Partition, free) -> tuple[int, ...] | None:
    """First-fit-decreasing placement of ``partition``'s slices onto the
    ``free`` unit mask (length ``N_UNITS``, True = idle).

    Each slice claims a contiguous aligned range (:func:`aligned_offsets`);
    slices are placed widest-first so large slices are not blocked by the
    order smaller ones would claim gaps in.  Returns per-slice start offsets
    in *partition order*, or ``None`` when no first-fit placement exists —
    deterministic, so simulations replay bit-identically."""
    avail = list(free)
    assert len(avail) == N_UNITS, len(avail)
    order = sorted(range(len(partition.slices)),
                   key=lambda i: -partition.slices[i].units)
    starts: list[int | None] = [None] * len(partition.slices)
    for i in order:
        w = partition.slices[i].units
        for off in aligned_offsets(w):
            if all(avail[off:off + w]):
                starts[i] = off
                avail[off:off + w] = [False] * w
                break
        else:
            return None
    return tuple(starts)


def partitions_by_arity(c_max: int = 4) -> dict[int, list[Partition]]:
    out: dict[int, list[Partition]] = {}
    for p in enumerate_partitions(c_max):
        out.setdefault(p.arity, []).append(p)
    return out


def slot_assignments(group_size: int) -> list[tuple[int, ...]]:
    """All orderings of a group over a partition's slots (paper's C!)."""
    return list(itertools.permutations(range(group_size)))
