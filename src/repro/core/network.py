"""Dueling double deep Q-network in pure JAX (paper §IV-D / Table VI).

Architecture (paper Table VI): input W x (f+5) — widened by the
arrival-aware context block (busy-unit mask + per-slot ages + queue depth,
see docs/observation.md) when the environment runs with
``EnvConfig.obs_context``; 3 fully-connected hidden layers 512/256/128,
ReLU; dueling heads V (1) and A (n_actions); Q = V + A - mean(A)
[Wang et al. 2016]. Double-DQN targets use the online network's argmax
with the target network's value [van Hasselt et al. 2016].

``widen_dqn_params`` is the bridge between the two input widths: it
zero-pads the input layer for the appended features, so a profile-only
agent warm-starts a context-aware run while computing the identical
Q-function at zero context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

HIDDEN = (512, 256, 128)


def init_dqn(key, in_dim: int, n_actions: int, hidden=HIDDEN) -> dict:
    params = {}
    dims = (in_dim, *hidden)
    keys = jax.random.split(key, len(hidden) + 2)
    for i in range(len(hidden)):
        params[f"w{i}"] = jax.random.normal(keys[i], (dims[i], dims[i + 1])) * (2.0 / dims[i]) ** 0.5
        params[f"b{i}"] = jnp.zeros((dims[i + 1],))
    params["wV"] = jax.random.normal(keys[-2], (hidden[-1], 1)) * (1.0 / hidden[-1]) ** 0.5
    params["bV"] = jnp.zeros((1,))
    params["wA"] = jax.random.normal(keys[-1], (hidden[-1], n_actions)) * (1.0 / hidden[-1]) ** 0.5
    params["bA"] = jnp.zeros((n_actions,))
    return params


def dqn_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., in_dim) -> Q (..., n_actions)."""
    h = x
    i = 0
    while f"w{i}" in params:
        h = jax.nn.relu(h @ params[f"w{i}"] + params[f"b{i}"])
        i += 1
    v = h @ params["wV"] + params["bV"]                    # (..., 1)
    a = h @ params["wA"] + params["bA"]                    # (..., n_actions)
    return v + a - jnp.mean(a, axis=-1, keepdims=True)


def masked_argmax(q: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(jnp.where(mask, q, -jnp.inf), axis=-1)


def greedy_q_action(params: dict, obs: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Greedy fit-masked action for one observation: () i32.

    The single action-selection implementation shared by
    ``DQNAgent.act(greedy=True)`` (the heap serving path) and the
    vectorized engine's in-graph policy seam — ties break to the first
    maximal index on both, so the two paths pick identical actions on
    identical observations (the property the parity fuzzer pins).
    """
    q = dqn_apply(params, obs[None])[0]
    return masked_argmax(q, mask).astype(jnp.int32)


def widen_dqn_params(params: dict, extra_in: int) -> dict:
    """Zero-pad the input layer for ``extra_in`` *appended* observation dims.

    New observation features are appended at the end of the flat state
    vector (the context block's contract), so the matching new rows of
    ``w0`` go at the end of its input axis and are zero — the widened
    network computes the same Q-values whenever the appended features are
    zero.  This is the warm-start path from a profile-only agent into an
    arrival-aware one: at zero context the two agents are the same
    function, and training only has to learn how context should *modulate*
    an already-competent policy.  Works on any params-shaped tree whose
    only input-anchored leaf is ``w0`` (online/target params and the Adam
    moment trees alike).
    """
    assert extra_in >= 0, extra_in
    out = dict(params)
    w0 = params["w0"]
    pad = jnp.zeros((extra_in, w0.shape[1]), w0.dtype)
    out["w0"] = jnp.concatenate([w0, pad], axis=0)
    return out
