"""Compared scheduling policies (paper §V-A4) + the exhaustive oracle.

All baselines are *exhaustive* over their policy class, as in the paper:
optimal group selection via exact set-partition DP over the window, optimal
partition + slot assignment per group by enumeration.

    time_sharing        — singletons, full pod each (the 1.0 baseline)
    mig_only  (C = 2)   — private-slice pairs only [refs 6, 34]
    mps_only  (C<=Cmax) — full-pod fractional shares only
    mig_mps_default     — one fixed hierarchical layout + equal (default) MPS
    oracle              — full table (the upper bound for the RL agent)
"""
from __future__ import annotations

import itertools
from functools import lru_cache

from repro.core.partition import Partition, enumerate_partitions, solo_partition
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.problem import Schedule
from repro.core.profiles import JobProfile


def _best_for_group(group: list[JobProfile], partitions: list[Partition],
                    max_perms: int | None = None) -> tuple[float, Partition | None, tuple[int, ...]]:
    """Min CoRunTime over partitions of matching arity x slot orderings.

    ``max_perms=None`` enumerates all C! slot orderings — required for the
    oracle to actually be an upper bound (a truncated sweep silently missed
    16 of the 24 orderings for C=4 groups).  Pass a cap only for explicitly
    approximate policies.
    """
    best_t, best_p, best_perm = float("inf"), None, tuple(range(len(group)))
    for p in partitions:
        if p.arity != len(group):
            continue
        perms = itertools.permutations(range(len(group)))
        if max_perms is not None:
            perms = itertools.islice(perms, max_perms)
        for perm in perms:
            t = corun_time([group[i] for i in perm], p)
            if t < best_t:
                best_t, best_p, best_perm = t, p, perm
    return best_t, best_p, best_perm


def exhaustive_schedule(queue: list[JobProfile], c_max: int,
                        partitions: list[Partition],
                        enforce_solo_constraint: bool = True,
                        max_perms: int | None = None) -> Schedule:
    """Exact set-partition DP (O(3^W) submask enumeration) over group costs."""
    W = len(queue)
    solo_part = solo_partition()

    @lru_cache(maxsize=None)
    def group_cost(mask: int) -> tuple[float, object]:
        group = [queue[i] for i in range(W) if mask >> i & 1]
        best_t, best_p, best_perm = _best_for_group(group, partitions, max_perms)
        if len(group) == 1 and best_p is None:
            return solo_run_time(group), (solo_part, (0,))
        if best_p is None:
            return float("inf"), None
        if enforce_solo_constraint and best_t > solo_run_time(group):
            return float("inf"), None
        return best_t, (best_p, best_perm)

    # dp over subsets
    INF = float("inf")
    dp = [INF] * (1 << W)
    choice: list[tuple[int, object] | None] = [None] * (1 << W)
    dp[0] = 0.0
    for mask in range(1, 1 << W):
        low = mask & -mask
        sub = mask
        while sub:
            if sub & low and bin(sub).count("1") <= c_max:
                t, info = group_cost(sub)
                if info is not None and dp[mask ^ sub] + t < dp[mask]:
                    dp[mask] = dp[mask ^ sub] + t
                    choice[mask] = (sub, info)
            sub = (sub - 1) & mask
    # fall back to singletons for any group the policy class can't cover
    sched = Schedule()
    mask = (1 << W) - 1
    while mask:
        if choice[mask] is None:  # pragma: no cover — solo always feasible
            i = mask.bit_length() - 1
            sched.add([queue[i]], solo_part)
            mask ^= 1 << i
            continue
        sub, (p, perm) = choice[mask]
        group = [queue[i] for i in range(W) if sub >> i & 1]
        sched.add([group[i] for i in perm], p)
        mask ^= sub
    return sched


# ---------------------------------------------------------------------------
# Named policies
# ---------------------------------------------------------------------------

def time_sharing(queue: list[JobProfile], c_max: int = 4) -> Schedule:
    solo = solo_partition()
    sched = Schedule()
    for j in queue:
        sched.add([j], solo)
    return sched


def mig_only(queue: list[JobProfile], c_max: int = 4) -> Schedule:
    parts = [p for p in enumerate_partitions(2) if p.style in ("mig",) and p.arity == 2]
    return exhaustive_schedule(queue, 2, parts)


def mps_only(queue: list[JobProfile], c_max: int = 4) -> Schedule:
    parts = [p for p in enumerate_partitions(c_max) if p.style == "mps"]
    return exhaustive_schedule(queue, c_max, parts)


def mig_mps_default(queue: list[JobProfile], c_max: int = 4) -> Schedule:
    """Fixed MIG layout (4+4 units) + default (equal) MPS shares; group
    selection exhaustive (paper: 'MIG partitioning selected so that average
    throughput across queues is maximized; MPS in default mode')."""
    from repro.core.partition import Slice

    parts = [
        Partition((Slice(4, (1.0,)), Slice(4, (1.0,))), "default-C2"),
        Partition((Slice(4, (1.0,)), Slice(4, (0.5, 0.5))), "default-C3"),
        Partition((Slice(4, (0.5, 0.5)), Slice(4, (0.5, 0.5))), "default-C4"),
    ]
    return exhaustive_schedule(queue, c_max, parts)


def oracle(queue: list[JobProfile], c_max: int = 4) -> Schedule:
    return exhaustive_schedule(queue, c_max, enumerate_partitions(c_max))


POLICIES = {
    "time_sharing": time_sharing,
    "mig_only": mig_only,
    "mps_only": mps_only,
    "mig_mps_default": mig_mps_default,
    "oracle": oracle,
}
