"""Evaluation metrics (paper §V-B): relative throughput, slowdown, fairness."""
from __future__ import annotations

import numpy as np

from repro.core.problem import Schedule


def relative_throughput(sched: Schedule) -> float:
    """Fig. 8 metric: SoloRunTime(Q) / Σ CoRunTime — 1.0 = time sharing."""
    return sched.throughput_vs_time_sharing()


def avg_app_slowdown(sched: Schedule) -> float:
    """Fig. 11 metric: mean over jobs of CoRunAppTime/SoloRunAppTime."""
    return float(np.mean(list(sched.app_slowdowns().values())))


def fairness(sched: Schedule) -> float:
    """Fig. 12 metric: min/max AppSlowdown."""
    return sched.fairness()


def summarize(sched: Schedule) -> dict:
    return {
        "throughput": relative_throughput(sched),
        "avg_slowdown": avg_app_slowdown(sched),
        "fairness": fairness(sched),
        "groups": len(sched.groups),
        "partitions": [p.label for p in sched.partitions],
    }
