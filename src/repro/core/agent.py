"""DQN agent: masked ε-greedy action selection + jit'd double-DQN updates.

Two call surfaces share the same parameters and update rule:

  * ``DQNAgent`` — the stateful single-env agent used by ``RLScheduler`` and
    the scalar training loop.  Greedy (evaluation) calls do **not** advance
    ``env_steps``, so evaluation frequency cannot perturb the ε schedule.
  * ``act_batch`` / ``epsilon_at`` — pure functions over (params, key,
    obs, mask) used by the vectorized engine: vmapped ε-greedy selection
    with ``jax.random`` keys and the linear ε schedule computed in-graph,
    so the whole rollout lives inside one ``lax.scan``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.network import (
    dqn_apply, greedy_q_action, init_dqn, masked_argmax,
)
from repro.core.replay import PrioritizedReplayBuffer, ReplayBuffer


@dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    lr: float = 5e-4
    batch_size: int = 128
    buffer_size: int = 100_000
    target_sync: int = 500           # updates between target-network syncs
    eps_start: float = 1.0
    eps_end: float = 0.01
    eps_decay_steps: int = 15_000    # env steps for linear ε decay
    huber_delta: float = 1.0
    reward_scale: float = 0.01       # rewards are O(100); keep TD targets O(1)


def _adam_init(params):
    z = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def _td_and_huber(p, target_params, batch, cfg: DQNConfig):
    """Per-sample double-DQN TD error and its Huber transform."""
    q = dqn_apply(p, batch["s"])                                       # (B, A)
    q_sa = jnp.take_along_axis(q, batch["a"][:, None], axis=1)[:, 0]
    # double DQN: online argmax (masked), target value
    q2_online = dqn_apply(p, batch["s2"])
    a2 = masked_argmax(q2_online, batch["mask2"])
    q2_target = dqn_apply(target_params, batch["s2"])
    v2 = jnp.take_along_axis(q2_target, a2[:, None], axis=1)[:, 0]
    v2 = jnp.where(batch["mask2"].any(axis=1), v2, 0.0)               # terminal: no actions
    y = batch["r"] * cfg.reward_scale + cfg.gamma * (1.0 - batch["done"]) * v2
    y = jax.lax.stop_gradient(y)
    err = q_sa - y
    huber = jnp.where(jnp.abs(err) <= cfg.huber_delta,
                      0.5 * err ** 2,
                      cfg.huber_delta * (jnp.abs(err) - 0.5 * cfg.huber_delta))
    return err, huber


def _adam_step(params, grads, opt, lr: float):
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    lr_t = lr * jnp.sqrt(1 - b2 ** tf) / (1 - b1 ** tf)
    params = jax.tree.map(lambda p, m_, v_: p - lr_t * m_ / (jnp.sqrt(v_) + eps),
                          params, m, v)
    return params, {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("cfg",))
def _dqn_update(params, target_params, opt, batch, cfg: DQNConfig):
    def loss_fn(p):
        _, huber = _td_and_huber(p, target_params, batch, cfg)
        return jnp.mean(huber)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = _adam_step(params, grads, opt, cfg.lr)
    return params, opt, loss


def _grad_norm(grads):
    """Global L2 norm over all gradient leaves (training telemetry)."""
    return jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _dqn_update_aux(params, target_params, opt, batch, cfg: DQNConfig):
    """``_dqn_update`` + telemetry aux -> (params, opt, loss, |td|, gnorm).

    The aux outputs ride ``has_aux`` on the same forward pass, and the
    grad norm is read off the gradients the Adam step consumes anyway —
    the parameter trajectory is bit-identical to ``_dqn_update``
    (pinned by the training-telemetry parity test).
    """
    def loss_fn(p):
        err, huber = _td_and_huber(p, target_params, batch, cfg)
        return jnp.mean(huber), jnp.mean(jnp.abs(err))

    (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    gnorm = _grad_norm(grads)
    params, opt = _adam_step(params, grads, opt, cfg.lr)
    return params, opt, loss, td, gnorm


@functools.partial(jax.jit, static_argnames=("cfg",))
def _dqn_update_per(params, target_params, opt, batch, w, cfg: DQNConfig):
    """Importance-weighted double-DQN update -> (params, opt, loss, |td|).

    ``w`` are per-sample IS weights from the prioritized sampler (applied
    inside the loss); the returned absolute TD errors feed the sum-tree
    priority refresh.  With ``w == 1`` this is bit-identical to
    ``_dqn_update`` — multiplying the Huber terms by exact ones changes no
    float — which is what keeps ``per_alpha = 0`` a true uniform engine.
    """
    def loss_fn(p):
        err, huber = _td_and_huber(p, target_params, batch, cfg)
        return jnp.mean(w * huber), jnp.abs(err)

    (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt = _adam_step(params, grads, opt, cfg.lr)
    return params, opt, loss, td


@functools.partial(jax.jit, static_argnames=("cfg",))
def _dqn_update_per_aux(params, target_params, opt, batch, w, cfg: DQNConfig):
    """``_dqn_update_per`` + grad-norm aux -> (params, opt, loss, td, gnorm)."""
    def loss_fn(p):
        err, huber = _td_and_huber(p, target_params, batch, cfg)
        return jnp.mean(w * huber), jnp.abs(err)

    (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    gnorm = _grad_norm(grads)
    params, opt = _adam_step(params, grads, opt, cfg.lr)
    return params, opt, loss, td, gnorm


@jax.jit
def _greedy_action(params, obs, mask):
    return greedy_q_action(params, obs, mask)


def epsilon_at(cfg: DQNConfig, env_steps):
    """Linear ε schedule as a pure function of the env-step count.

    Accepts a plain int (scalar agent hot path — no jnp dispatch) or a
    traced array (inside the scanned engine)."""
    if isinstance(env_steps, (int, float)):
        frac = min(1.0, env_steps / max(1, cfg.eps_decay_steps))
    else:
        frac = jnp.clip(env_steps / max(1, cfg.eps_decay_steps), 0.0, 1.0)
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


def beta_at(beta0: float, env_steps, decay_steps: int):
    """Linear IS-exponent anneal β0 -> 1 over the ε-decay horizon.

    Prioritized replay's bias correction should be complete (β = 1) by the
    time exploration has settled, so β shares ``eps_decay_steps``.  Accepts
    a plain int (scalar loop) or a traced array (scanned engine), like
    ``epsilon_at``.
    """
    if isinstance(env_steps, (int, float)):
        frac = min(1.0, env_steps / max(1, decay_steps))
    else:
        frac = jnp.clip(env_steps / max(1, decay_steps), 0.0, 1.0)
    return beta0 + (1.0 - beta0) * frac


@jax.jit
def act_batch(params, key, obs, mask, eps):
    """Vmapped masked ε-greedy: one action per env row.

    obs (B, D), mask (B, A) -> (B,) i32.  Exploration draws a uniformly
    random *valid* action (argmax of uniform scores over the mask).
    """
    greedy = masked_argmax(dqn_apply(params, obs), mask)
    k_bern, k_choice = jax.random.split(key)
    explore = jax.random.uniform(k_bern, greedy.shape) < eps
    scores = jax.random.uniform(k_choice, mask.shape)
    rand = jnp.argmax(jnp.where(mask, scores, -1.0), axis=-1)
    return jnp.where(explore, rand, greedy).astype(jnp.int32)


class DQNAgent:
    def __init__(self, state_dim: int, n_actions: int, cfg: DQNConfig | None = None,
                 seed: int = 0, per_alpha: float = 0.0, per_beta0: float = 0.4,
                 per_eps: float = 1e-3):
        self.cfg = cfg or DQNConfig()
        key = jax.random.PRNGKey(seed)
        self.params = init_dqn(key, state_dim, n_actions)
        self.target_params = jax.tree.map(jnp.copy, self.params)
        self.opt = _adam_init(self.params)
        self._replay: ReplayBuffer | None = None   # lazy: ~100 MB at defaults
        self._replay_shape = (state_dim, n_actions, seed)
        self.per_alpha = per_alpha                 # 0 -> uniform replay
        self.per_beta0 = per_beta0
        self.per_eps = per_eps
        self.rng = np.random.default_rng(seed)
        self.env_steps = 0
        self.updates = 0

    @property
    def replay(self) -> ReplayBuffer:
        """Numpy replay for the scalar loop; the vectorized engine keeps its
        own on-device ring, so allocation waits for first use."""
        if self._replay is None:
            d, a, seed = self._replay_shape
            if self.per_alpha > 0:
                self._replay = PrioritizedReplayBuffer(
                    self.cfg.buffer_size, d, a, seed,
                    alpha=self.per_alpha, eps=self.per_eps)
            else:
                self._replay = ReplayBuffer(self.cfg.buffer_size, d, a, seed)
        return self._replay

    # ----------------------------------------------------------------- act
    @property
    def epsilon(self) -> float:
        return epsilon_at(self.cfg, self.env_steps)

    def act(self, state: np.ndarray, mask: np.ndarray, greedy: bool = False) -> int:
        if not greedy:
            # only exploration steps advance the ε-decay schedule; greedy
            # (evaluation) calls must not change exploration behaviour
            self.env_steps += 1
            if self.rng.random() < self.epsilon:
                return int(self.rng.choice(np.flatnonzero(mask)))
        # greedy selection routes through the same jitted kernel the
        # vectorized engine closes over in-graph (see network.greedy_q_action)
        return int(_greedy_action(self.params, jnp.asarray(state),
                                  jnp.asarray(mask)))

    # -------------------------------------------------------------- learn
    def observe(self, s, a, r, s2, done, mask2) -> None:
        self.replay.push(s, a, r, s2, done, mask2)

    def update(self) -> float | None:
        if len(self.replay) < self.cfg.batch_size:
            return None
        if self.per_alpha > 0:
            beta = beta_at(self.per_beta0, self.env_steps, self.cfg.eps_decay_steps)
            batch, idx, w = self.replay.sample(self.cfg.batch_size, beta)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt, loss, td = _dqn_update_per(
                self.params, self.target_params, self.opt, batch,
                jnp.asarray(w), self.cfg)
            self.replay.update_priorities(idx, np.asarray(td))
        else:
            batch = self.replay.sample(self.cfg.batch_size)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt, loss = _dqn_update(
                self.params, self.target_params, self.opt, batch, self.cfg)
        self.updates += 1
        if self.updates % self.cfg.target_sync == 0:
            self.target_params = jax.tree.map(jnp.copy, self.params)
        return float(loss)
