"""The paper's §IV-A optimization problem in executable form.

    given   W, Cmax, Q = {J_1..J_W}
    min     Σ_i CoRunTime(JS_i, R_i)
    s.t.    CoRunTime(JS_i, R_i) <= SoloRunTime(JS_i)      (no worse than time sharing)
            1 <= C_i = |JS_i| <= Cmax
            |L_JS| = |L_R|,  ∪ JS_i = Q,  Σ|JS_i| = W      (exclusive + exhaustive)
    output  L_JS, L_R
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.partition import Partition
from repro.core.perfmodel import corun, corun_time, solo_run_time
from repro.core.profiles import JobProfile


@dataclass
class Schedule:
    """A solution: groups (L_JS) with partitions (L_R), jobs slot-ordered."""

    groups: list[list[JobProfile]] = field(default_factory=list)   # L_JS
    partitions: list[Partition] = field(default_factory=list)      # L_R

    def add(self, group: list[JobProfile], partition: Partition) -> None:
        assert len(group) == partition.arity
        self.groups.append(group)
        self.partitions.append(partition)

    @property
    def total_corun_time(self) -> float:
        return sum(corun_time(g, p) for g, p in zip(self.groups, self.partitions))

    @property
    def total_solo_time(self) -> float:
        return sum(solo_run_time(g) for g in self.groups)

    def throughput_vs_time_sharing(self) -> float:
        """Paper Fig. 8 metric: relative throughput vs pure time sharing."""
        t = self.total_corun_time
        return self.total_solo_time / t if t > 0 else 0.0

    def app_slowdowns(self) -> dict[str, float]:
        """AppSlowdown(J) = CoRunAppTime(J) / SoloRunAppTime(J) (paper §V-B)."""
        out = {}
        for g, p in zip(self.groups, self.partitions):
            res = corun(g, p)
            for job, ft, st in zip(g, res.finish_times, res.solo_times):
                out[job.name] = ft / st if st > 0 else 1.0
        return out

    def fairness(self) -> float:
        """min/max AppSlowdown (paper Fig. 12; 1.0 = perfectly fair)."""
        sl = list(self.app_slowdowns().values())
        return min(sl) / max(sl) if sl and max(sl) > 0 else 1.0


def validate_schedule(queue: list[JobProfile], sched: Schedule, c_max: int,
                      enforce_solo_constraint: bool = True) -> None:
    """Assert every constraint of the §IV-A formulation."""
    assert len(sched.groups) == len(sched.partitions), "|L_JS| != |L_R|"
    names = [j.name for g in sched.groups for j in g]
    assert len(names) == len(queue), "Σ|JS_i| != W"
    assert sorted(names) == sorted(j.name for j in queue), "∪JS_i != Q"
    for g, p in zip(sched.groups, sched.partitions):
        assert 1 <= len(g) <= c_max, f"concurrency {len(g)} outside [1,{c_max}]"
        assert len(g) == p.arity, "group size != partition arity"
        if enforce_solo_constraint:
            ct, st = corun_time(g, p), solo_run_time(g)
            assert ct <= st * (1 + 1e-9), (
                f"CoRunTime {ct:.3f} > SoloRunTime {st:.3f} for {p.label}"
            )
