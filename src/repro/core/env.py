"""RL environment for co-scheduling + hierarchical partitioning (paper §IV-C).

State: W slots x (f profile features + 5 status flags), flattened — exactly
the paper's input layer ``W x (f+5)``.  With ``EnvConfig.obs_context=True``
an **arrival-aware context block** is appended (see ``docs/observation.md``
for the full spec): the pod's busy-unit occupancy mask (``N_UNITS``), the
per-slot queueing age of each window job (``W``), and the normalized depth
of the pending queue beyond this window (1) — the live cluster state the
online dispatch layer observes at each window, so the policy can *learn*
backfill-like behavior instead of inheriting it from the dispatcher.  A
zeroed context (empty pod, fresh queue) makes the prefix bit-identical to
the profile-only observation, and ``obs_context=False`` (default) changes
nothing at all.
Actions: W *select-job-i into the current group* + N_p *close the group with
partition p* (the paper's A = W + N_p decomposition; assignment to partition
slots follows selection order, covering the C! orderings).
Rewards (paper Table VI):
    on close:  Σ_j r_i(j)  +  r_f = (SoloRunTime/CoRunTime - 1) x 100
    r_i = (SmAllocRatio*ComputeRatio + MemoryAllocRatio*MemoryRatio) * DurationRatio^2
Under ``obs_context`` a close is additionally shaped by ``-ctx_fit_weight``
when the chosen partition cannot first-fit the observed free units (the
precomputed :func:`~repro.core.perfmodel_jax.build_fit_table` gather) —
the signal that ties the context features to packing-aware decisions; it
is exactly zero at zero context, preserving regression parity.
Episode: schedule the whole window; terminal when all W jobs are grouped.

The environment has two implementations:

  * **Functional core** — an immutable :class:`EnvState` pytree with pure
    ``reset``/``step`` transition functions whose reward math runs on
    precomputed JAX arrays (:mod:`repro.core.perfmodel_jax`).  Everything is
    jit-able and vmap-able, so the training engine fuses B parallel episodes
    and the DQN update into a single ``lax.scan`` (see ``train.py``).
    :class:`VecCoScheduleEnv` owns the compiled entry points.
  * **Stateful reference wrapper** — :class:`CoScheduleEnv` keeps the
    original mutable gym-style API (used by ``RLScheduler``, the baselines,
    and examples) and computes rewards with the float64 Python perfmodel.
    The parity test pins the functional core to this wrapper step-for-step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import (
    N_UNITS, Partition, aligned_offsets, enumerate_partitions, find_offsets,
)
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.perfmodel_jax import (
    PartitionTable, QueueArrays, build_fit_table, build_partition_table,
    group_metrics, group_reward, queue_arrays, stack_queues,
)
from repro.core.problem import Schedule
from repro.core.profiles import FEATURES, JobProfile

N_FLAGS = 5  # available, in-group, scheduled, padding, group-progress


@dataclass
class EnvConfig:
    window: int = 12                     # W
    c_max: int = 4                       # Cmax
    r_f_scale: float = 100.0             # paper: x100
    r_i_weight: float = 0.2              # r_f carries the true objective
    invalid_penalty: float = -10.0       # masked anyway; safety net
    obs_context: bool = False            # append the arrival-aware block
    ctx_fit_weight: float = 10.0         # close-shaping when the partition
                                         # can't fit the observed free units
                                         # (active only under obs_context)

    def key(self) -> tuple:
        """Hashable identity (EnvConfig is mutable; used for engine caches).
        Derived from the declared fields so it can never go stale."""
        import dataclasses

        return tuple(getattr(self, f.name) for f in dataclasses.fields(self))


def context_dim(cfg: EnvConfig) -> int:
    """Width of the appended context block: busy mask + per-slot ages + depth."""
    return (N_UNITS + cfg.window + 1) if cfg.obs_context else 0


def age_feature(age_s: float) -> float:
    """Queueing age -> feature: log10 compression on the same 1e6-second
    scale as the profile features' ``log_duration`` (docs/observation.md)."""
    return math.log10(1.0 + max(age_s, 0.0)) / 6.0


def depth_feature(depth: int, window: int) -> float:
    """Pending-queue depth -> feature: saturating at 4 windows' worth."""
    return min(depth / (4.0 * window), 1.0)


@dataclass(frozen=True)
class DispatchContext:
    """Cluster-state snapshot the online dispatch layer hands the planner.

    Built by :class:`~repro.online.simulator.ClusterSimulator` at every
    dispatch window and threaded through ``submission_protocol`` down to
    ``RLScheduler.schedule``; the environment normalizes it into the
    observation's context block (:func:`dispatch_obs_context`).
    """

    free_units: tuple[bool, ...]         # (N_UNITS,) True = idle slice unit
    ages_s: tuple[float, ...]            # per-submission wait so far, seconds
    queue_depth: int = 0                 # pending submissions beyond this window
    now_s: float = 0.0                   # simulated dispatch instant


class ObsContext(NamedTuple):
    """Normalized context block appended to the observation (f32 pytree).

    The zero context — empty pod, no queued work, fresh arrivals — is the
    parity anchor: with ``ObsContext`` all-zero the observation prefix
    bit-matches the profile-only layout and the fit shaping is exactly 0.
    ``busy_units`` is therefore stored busy-high (1 = claimed), so "all
    zeros" means "everything free" rather than the pathological opposite.
    """

    busy_units: jnp.ndarray              # (N_UNITS,) f32 — 1 = unit claimed
    ages: jnp.ndarray                    # (W,) f32 — age_feature per slot
    queue_depth: jnp.ndarray             # () f32 — depth_feature


def zero_context(window: int) -> ObsContext:
    """The neutral (empty-cluster) context — the offline/parity default."""
    return ObsContext(
        busy_units=jnp.zeros((N_UNITS,), jnp.float32),
        ages=jnp.zeros((window,), jnp.float32),
        queue_depth=jnp.zeros((), jnp.float32),
    )


def dispatch_obs_context(ctx: DispatchContext, window: int) -> ObsContext:
    """Normalize a simulator snapshot into the observation's context block."""
    busy = np.asarray([0.0 if f else 1.0 for f in ctx.free_units], np.float32)
    assert busy.shape == (N_UNITS,), ctx.free_units
    ages = np.zeros((window,), np.float32)
    for i, a in enumerate(ctx.ages_s[:window]):
        ages[i] = age_feature(a)
    return ObsContext(
        busy_units=jnp.asarray(busy), ages=jnp.asarray(ages),
        queue_depth=jnp.float32(depth_feature(ctx.queue_depth, window)),
    )


_N_CTX_MASKS = 64


def _context_mask_table(n_masks: int = _N_CTX_MASKS, seed: int = 0) -> jnp.ndarray:
    """(K, N_UNITS) f32 — plausible busy masks for training-time sampling.

    Each row is a union of buddy-aligned block claims (the only shapes the
    slice-level dispatcher ever produces) at a uniformly drawn fill target,
    so offline training sees the occupancy distribution serve time will.
    Row 0 is the all-free pod, anchoring the zero-context regime in the
    training data.  Fixed seed: the table is part of the engine's
    deterministic identity.
    """
    rng = np.random.default_rng(seed)
    out = np.zeros((n_masks, N_UNITS), np.float32)
    for i in range(1, n_masks):
        target = rng.uniform()
        busy = np.zeros(N_UNITS, bool)
        for _ in range(16):
            if busy.mean() >= target:
                break
            w = int(rng.choice((1, 2, 4, 8), p=(0.4, 0.3, 0.2, 0.1)))
            off = int(rng.choice(aligned_offsets(w)))
            if not busy[off:off + w].any():
                busy[off:off + w] = True
        out[i] = busy
    return jnp.asarray(out)


class EnvState(NamedTuple):
    """Immutable episode state; ``queue`` is constant through the episode.

    ``ctx`` is the arrival-aware context the episode was reset with; it is
    carried (and tree-mapped) even when ``obs_context=False``, where it is
    all-zero and never read — one pytree shape for both modes."""

    queue: QueueArrays                   # per-queue precomputed job arrays
    scheduled: jnp.ndarray               # (W,) bool
    group_idx: jnp.ndarray               # (c_max,) i32, selection order, -1 pad
    group_size: jnp.ndarray              # () i32
    ctx: ObsContext                      # arrival-aware context block


class VecCoScheduleEnv:
    """Functional env: pure jitted ``reset``/``step`` + vmapped batch forms.

    ``reset(queue_arrays)`` and ``step(state, action)`` are pure functions of
    their inputs — all mutation is in the returned :class:`EnvState`.  The
    batch variants (``reset_batch``/``step_batch``) vmap over a leading env
    axis; ``queue_batch`` builds the stacked :class:`QueueArrays` input.
    """

    def __init__(self, cfg: EnvConfig | None = None):
        self.cfg = cfg or EnvConfig()
        self.partitions: list[Partition] = enumerate_partitions(self.cfg.c_max)
        self.table: PartitionTable = build_partition_table(
            self.partitions, self.cfg.c_max)
        self.n_features = len(FEATURES)
        self.context_dim = context_dim(self.cfg)
        self.state_dim = (self.cfg.window * (self.n_features + N_FLAGS)
                          + self.context_dim)
        self.n_actions = self.cfg.window + len(self.partitions)
        if self.cfg.obs_context:
            # partition-vs-busy-mask fit table (close shaping) + the sampled
            # occupancy distribution offline training draws contexts from
            self._fit_table = build_fit_table(self.partitions)
            self._ctx_masks = _context_mask_table()
            self._pow2 = jnp.asarray(2 ** np.arange(N_UNITS), jnp.int32)
        self._obs_b = jax.vmap(self._obs)
        self.reset = jax.jit(self._reset_zero)
        self.reset_ctx = jax.jit(self._reset)
        self.step = jax.jit(self._step)
        self.reset_batch = jax.jit(jax.vmap(self._reset_zero))
        self.reset_batch_ctx = jax.jit(jax.vmap(self._reset))
        self.step_batch = jax.jit(jax.vmap(self._step))
        self.obs_batch = jax.jit(self._obs_b)
        self.close_metrics_batch = jax.jit(jax.vmap(self._close_metrics))

    # ----------------------------------------------------------- queue prep
    def queue_arrays(self, queue: list[JobProfile]) -> QueueArrays:
        return queue_arrays(queue, self.cfg.window)

    def queue_batch(self, queues: list[list[JobProfile]]) -> QueueArrays:
        return stack_queues([self.queue_arrays(q) for q in queues])

    # ------------------------------------------------------- pure functions
    def _reset(self, qa: QueueArrays,
               ctx: ObsContext) -> tuple[EnvState, jnp.ndarray, jnp.ndarray]:
        state = EnvState(
            queue=qa,
            scheduled=jnp.zeros((self.cfg.window,), bool),
            group_idx=jnp.full((self.cfg.c_max,), -1, jnp.int32),
            group_size=jnp.int32(0),
            ctx=ctx,
        )
        return state, self._obs(state), self._mask(state)

    def _reset_zero(self, qa: QueueArrays):
        """Reset with the neutral zero context — the profile-only default."""
        return self._reset(qa, zero_context(self.cfg.window))

    def sample_context(self, key: jax.Array, mean_d: jnp.ndarray,
                       valid: jnp.ndarray) -> ObsContext:
        """Batched training-time context draw (requires ``obs_context``).

        ``mean_d`` (B,) is each queue's mean solo duration — the natural
        scale for queueing-age draws — and ``valid`` (B, W) masks padding
        slots to zero age.  Busy masks come from the precomputed aligned-
        claim table, ages from an exponential wait model, and queue depth
        from an exponential with mean one window — mirrors of the
        normalizations in :func:`dispatch_obs_context` (the jnp forms of
        :func:`age_feature` / :func:`depth_feature`), so offline training
        and online serving read the same feature distributions.  Pure and
        trace-friendly: the scanned engine resamples at episode auto-reset.
        """
        B, _ = valid.shape
        k_m, k_a, k_d = jax.random.split(key, 3)
        idx = jax.random.randint(k_m, (B,), 0, self._ctx_masks.shape[0])
        # dtype pinned: under JAX_ENABLE_X64 the default draw would be f64
        # and silently promote the whole observation out of f32
        raw = (jax.random.exponential(k_a, valid.shape, dtype=jnp.float32)
               * mean_d[:, None])
        return ObsContext(
            busy_units=self._ctx_masks[idx],
            ages=jnp.where(valid, jnp.log10(1.0 + raw) / 6.0,
                           jnp.float32(0.0)),
            queue_depth=jnp.minimum(
                jax.random.exponential(k_d, (B,), dtype=jnp.float32) / 4.0,
                1.0),
        )

    def _member(self, state: EnvState) -> jnp.ndarray:
        """(W,) bool — job i currently selected into the open group."""
        live = jnp.arange(self.cfg.c_max) < state.group_size
        hits = state.group_idx[None, :] == jnp.arange(self.cfg.window)[:, None]
        return jnp.any(hits & live[None, :], axis=1)

    def _obs(self, state: EnvState) -> jnp.ndarray:
        member = self._member(state)
        valid = state.queue.valid
        progress = state.group_size.astype(jnp.float32) / max(1, self.cfg.c_max)
        flags = jnp.stack([
            (valid & ~state.scheduled & ~member).astype(jnp.float32),
            member.astype(jnp.float32),
            (state.scheduled & valid).astype(jnp.float32),
            (~valid).astype(jnp.float32),
            jnp.where(valid, progress, 0.0),
        ], axis=1)
        flat = jnp.concatenate([state.queue.features, flags], axis=1).reshape(-1)
        if not self.cfg.obs_context:
            return flat
        return jnp.concatenate([flat, state.ctx.busy_units, state.ctx.ages,
                                state.ctx.queue_depth[None]])

    def _mask(self, state: EnvState) -> jnp.ndarray:
        member = self._member(state)
        can_select = (state.queue.valid & ~state.scheduled & ~member
                      & (state.group_size < self.cfg.c_max))
        can_close = (state.group_size >= 1) & (self.table.arity == state.group_size)
        return jnp.concatenate([can_select, can_close])

    def _done(self, state: EnvState) -> jnp.ndarray:
        return (jnp.all(state.scheduled | ~state.queue.valid)
                & (state.group_size == 0))

    def _step(self, state: EnvState, action: jnp.ndarray):
        """Pure transition -> (state', obs', reward, done, mask')."""
        W = self.cfg.window
        mask = self._mask(state)
        valid = mask[action]
        is_select = action < W
        # select branch: append to the open group (selection order preserved)
        sel_state = state._replace(
            group_idx=state.group_idx.at[state.group_size].set(
                action.astype(jnp.int32)),
            group_size=state.group_size + 1,
        )
        # close branch: score the group under partition p, mark scheduled
        p_idx = jnp.clip(action - W, 0, len(self.partitions) - 1)
        r_close = group_reward(self.table, state.queue, state.group_idx,
                               state.group_size, p_idx,
                               self.cfg.r_i_weight, self.cfg.r_f_scale)
        if self.cfg.obs_context and self.cfg.ctx_fit_weight > 0:
            # arrival-aware shaping: closing onto a partition that cannot
            # first-fit the observed free units costs ctx_fit_weight — the
            # learned analogue of "don't plan a placement that must block".
            # At zero context every partition fits, so this subtracts an
            # exact 0.0 and the profile-only rewards are bit-preserved.
            m_idx = jnp.sum(jnp.where(state.ctx.busy_units > 0.5,
                                      self._pow2, 0), dtype=jnp.int32)
            r_close = r_close - self.cfg.ctx_fit_weight * (
                1.0 - self._fit_table[p_idx, m_idx])
        close_state = state._replace(
            scheduled=state.scheduled | self._member(state),
            group_idx=jnp.full((self.cfg.c_max,), -1, jnp.int32),
            group_size=jnp.int32(0),
        )
        branch = jax.tree.map(lambda a, b: jnp.where(is_select, a, b),
                              sel_state, close_state)
        new_state = jax.tree.map(lambda a, b: jnp.where(valid, a, b),
                                 branch, state)
        reward = jnp.where(
            valid,
            jnp.where(is_select, 0.0, r_close),
            jnp.float32(self.cfg.invalid_penalty),
        )
        return (new_state, self._obs(new_state), reward,
                self._done(new_state), self._mask(new_state))

    def _close_metrics(self, state: EnvState, action: jnp.ndarray):
        """(co-run time, solo time, multi-job?) the close `action` realizes.

        Zeros when `action` is not a valid close, so an evaluation scan can
        unconditionally accumulate these alongside ``step``/``step_batch`` —
        the relative-throughput bookkeeping of the greedy rollout stays
        entirely on device (no Python perfmodel in the eval hot path).
        """
        W = self.cfg.window
        ok = self._mask(state)[action] & (action >= W)
        p_idx = jnp.clip(action - W, 0, len(self.partitions) - 1)
        mk, so, _ = group_metrics(self.table, state.queue, state.group_idx,
                                  state.group_size, p_idx)
        zero = jnp.float32(0.0)
        return (jnp.where(ok, mk, zero), jnp.where(ok, so, zero),
                ok & (state.group_size > 1))


class CoScheduleEnv:
    """Gym-style (reset/step) reference wrapper, dependency-free.

    Thin stateful shell over the same action/observation contract as the
    functional core, kept for the scheduler/baselines API.  Rewards use the
    float64 Python perfmodel, making this the ground truth the vectorized
    engine is parity-tested against; it also materializes the
    :class:`Schedule` object the online phase consumes.
    """

    def __init__(self, cfg: EnvConfig | None = None):
        self.cfg = cfg or EnvConfig()
        self.partitions: list[Partition] = enumerate_partitions(self.cfg.c_max)
        self.n_features = len(FEATURES)
        self.context_dim = context_dim(self.cfg)
        self.state_dim = (self.cfg.window * (self.n_features + N_FLAGS)
                          + self.context_dim)
        self.n_actions = self.cfg.window + len(self.partitions)
        self._queue: list[JobProfile] = []
        self._ctx: DispatchContext | None = None

    # ------------------------------------------------------------------ API
    def reset(self, queue: list[JobProfile],
              context: DispatchContext | None = None) -> tuple[np.ndarray, np.ndarray]:
        """``context`` is the dispatch-time cluster snapshot (ignored unless
        ``cfg.obs_context``); ``None`` is the neutral zero context."""
        assert len(queue) <= self.cfg.window
        if context is not None and self.cfg.obs_context:
            assert len(context.ages_s) == len(queue), \
                (len(context.ages_s), len(queue))
        self._queue = list(queue)
        self._ctx = context
        self._scheduled = [False] * len(queue)
        self._in_group: list[int] = []           # selection-ordered indices
        self.schedule = Schedule()
        return self._state(), self.action_mask()

    def step(self, action: int):
        W = self.cfg.window
        reward = 0.0
        if not self._valid(action):
            return self._state(), self.cfg.invalid_penalty, self.done, self.action_mask(), {}
        if action < W:
            self._in_group.append(action)
        else:
            partition = self.partitions[action - W]
            group = [self._queue[i] for i in self._in_group]
            reward = self._close_reward(group, partition)
            self.schedule.add(group, partition)
            for i in self._in_group:
                self._scheduled[i] = True
            self._in_group = []
        return self._state(), reward, self.done, self.action_mask(), {}

    @property
    def done(self) -> bool:
        return all(self._scheduled) and not self._in_group

    # ------------------------------------------------------------- internals
    def _valid(self, action: int) -> bool:
        W = self.cfg.window
        if action < W:
            return (action < len(self._queue)
                    and not self._scheduled[action]
                    and action not in self._in_group
                    and len(self._in_group) < self.cfg.c_max)
        p = self.partitions[action - W]
        return len(self._in_group) >= 1 and p.arity == len(self._in_group)

    def action_mask(self) -> np.ndarray:
        return np.array([self._valid(a) for a in range(self.n_actions)], dtype=bool)

    def _state(self) -> np.ndarray:
        W = self.cfg.window
        out = np.zeros((W, self.n_features + N_FLAGS), np.float32)
        progress = len(self._in_group) / max(1, self.cfg.c_max)
        for i in range(W):
            if i >= len(self._queue):
                out[i, self.n_features + 3] = 1.0       # padding
                continue
            out[i, : self.n_features] = self._queue[i].features()
            out[i, self.n_features + 0] = float(not self._scheduled[i] and i not in self._in_group)
            out[i, self.n_features + 1] = float(i in self._in_group)
            out[i, self.n_features + 2] = float(self._scheduled[i])
            out[i, self.n_features + 4] = progress
        flat = out.reshape(-1)
        if not self.cfg.obs_context:
            return flat
        if self._ctx is None:
            return np.concatenate([flat, np.zeros((self.context_dim,),
                                                  np.float32)])
        # one normalization implementation: the same conversion the
        # vectorized serve path uses (busy, ages, depth — in that order)
        oc = dispatch_obs_context(self._ctx, W)
        return np.concatenate([flat, np.asarray(oc.busy_units),
                               np.asarray(oc.ages),
                               np.asarray(oc.queue_depth)[None]])

    # ------------------------------------------------------------- rewards
    def _close_reward(self, group: list[JobProfile], partition: Partition) -> float:
        means = self._window_means()
        ri = sum(
            self._r_i(job, beta, s.units, means)
            for job, (_, s, beta) in zip(group, partition.slots)
        )
        ct = corun_time(group, partition)
        st = solo_run_time(group)
        rf = (st / ct - 1.0) * self.cfg.r_f_scale if ct > 0 else 0.0
        reward = self.cfg.r_i_weight * ri + rf
        if (self.cfg.obs_context and self.cfg.ctx_fit_weight > 0
                and self._ctx is not None
                and find_offsets(partition, list(self._ctx.free_units)) is None):
            # mirror of the functional env's fit shaping (exact: same
            # first-fit predicate the fit table was built from)
            reward -= self.cfg.ctx_fit_weight
        return reward

    def _window_means(self) -> dict:
        jobs = self._queue
        return {
            "compute": float(np.mean([j.compute_pct for j in jobs])) or 1e-9,
            "memory": float(np.mean([j.memory_pct for j in jobs])) or 1e-9,
            "duration": float(np.mean([j.solo_time() for j in jobs])) or 1e-9,
        }

    def _r_i(self, job: JobProfile, beta: float, units: int, means: dict) -> float:
        """Paper Table VI intermediate reward, TPU-mapped:
        SmAllocRatio = chips fraction x β; MemoryAllocRatio = slice bandwidth
        fraction (co-residents all access the slice's bandwidth, like the
        GI's αm)."""
        sm_alloc = (units / N_UNITS) * beta
        mem_alloc = units / N_UNITS
        compute_ratio = job.compute_pct / max(means["compute"], 1e-9)
        memory_ratio = job.memory_pct / max(means["memory"], 1e-9)
        duration_ratio = job.solo_time() / max(means["duration"], 1e-9)
        return (sm_alloc * compute_ratio + mem_alloc * memory_ratio) * duration_ratio ** 2
