"""RL environment for co-scheduling + hierarchical partitioning (paper §IV-C).

State: W slots x (f profile features + 5 status flags), flattened — exactly
the paper's input layer ``W x (f+5)``.
Actions: W *select-job-i into the current group* + N_p *close the group with
partition p* (the paper's A = W + N_p decomposition; assignment to partition
slots follows selection order, covering the C! orderings).
Rewards (paper Table VI):
    on close:  Σ_j r_i(j)  +  r_f = (SoloRunTime/CoRunTime - 1) x 100
    r_i = (SmAllocRatio*ComputeRatio + MemoryAllocRatio*MemoryRatio) * DurationRatio^2
Episode: schedule the whole window; terminal when all W jobs are grouped.

The environment has two implementations:

  * **Functional core** — an immutable :class:`EnvState` pytree with pure
    ``reset``/``step`` transition functions whose reward math runs on
    precomputed JAX arrays (:mod:`repro.core.perfmodel_jax`).  Everything is
    jit-able and vmap-able, so the training engine fuses B parallel episodes
    and the DQN update into a single ``lax.scan`` (see ``train.py``).
    :class:`VecCoScheduleEnv` owns the compiled entry points.
  * **Stateful reference wrapper** — :class:`CoScheduleEnv` keeps the
    original mutable gym-style API (used by ``RLScheduler``, the baselines,
    and examples) and computes rewards with the float64 Python perfmodel.
    The parity test pins the functional core to this wrapper step-for-step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import N_UNITS, Partition, enumerate_partitions
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.perfmodel_jax import (
    PartitionTable, QueueArrays, build_partition_table, group_metrics,
    group_reward, queue_arrays, stack_queues,
)
from repro.core.problem import Schedule
from repro.core.profiles import FEATURES, JobProfile

N_FLAGS = 5  # available, in-group, scheduled, padding, group-progress


@dataclass
class EnvConfig:
    window: int = 12                     # W
    c_max: int = 4                       # Cmax
    r_f_scale: float = 100.0             # paper: x100
    r_i_weight: float = 0.2              # r_f carries the true objective
    invalid_penalty: float = -10.0       # masked anyway; safety net

    def key(self) -> tuple:
        """Hashable identity (EnvConfig is mutable; used for engine caches).
        Derived from the declared fields so it can never go stale."""
        import dataclasses

        return tuple(getattr(self, f.name) for f in dataclasses.fields(self))


class EnvState(NamedTuple):
    """Immutable episode state; ``queue`` is constant through the episode."""

    queue: QueueArrays                   # per-queue precomputed job arrays
    scheduled: jnp.ndarray               # (W,) bool
    group_idx: jnp.ndarray               # (c_max,) i32, selection order, -1 pad
    group_size: jnp.ndarray              # () i32


class VecCoScheduleEnv:
    """Functional env: pure jitted ``reset``/``step`` + vmapped batch forms.

    ``reset(queue_arrays)`` and ``step(state, action)`` are pure functions of
    their inputs — all mutation is in the returned :class:`EnvState`.  The
    batch variants (``reset_batch``/``step_batch``) vmap over a leading env
    axis; ``queue_batch`` builds the stacked :class:`QueueArrays` input.
    """

    def __init__(self, cfg: EnvConfig | None = None):
        self.cfg = cfg or EnvConfig()
        self.partitions: list[Partition] = enumerate_partitions(self.cfg.c_max)
        self.table: PartitionTable = build_partition_table(
            self.partitions, self.cfg.c_max)
        self.n_features = len(FEATURES)
        self.state_dim = self.cfg.window * (self.n_features + N_FLAGS)
        self.n_actions = self.cfg.window + len(self.partitions)
        self.reset = jax.jit(self._reset)
        self.step = jax.jit(self._step)
        self.reset_batch = jax.jit(jax.vmap(self._reset))
        self.step_batch = jax.jit(jax.vmap(self._step))
        self.close_metrics_batch = jax.jit(jax.vmap(self._close_metrics))

    # ----------------------------------------------------------- queue prep
    def queue_arrays(self, queue: list[JobProfile]) -> QueueArrays:
        return queue_arrays(queue, self.cfg.window)

    def queue_batch(self, queues: list[list[JobProfile]]) -> QueueArrays:
        return stack_queues([self.queue_arrays(q) for q in queues])

    # ------------------------------------------------------- pure functions
    def _reset(self, qa: QueueArrays) -> tuple[EnvState, jnp.ndarray, jnp.ndarray]:
        state = EnvState(
            queue=qa,
            scheduled=jnp.zeros((self.cfg.window,), bool),
            group_idx=jnp.full((self.cfg.c_max,), -1, jnp.int32),
            group_size=jnp.int32(0),
        )
        return state, self._obs(state), self._mask(state)

    def _member(self, state: EnvState) -> jnp.ndarray:
        """(W,) bool — job i currently selected into the open group."""
        live = jnp.arange(self.cfg.c_max) < state.group_size
        hits = state.group_idx[None, :] == jnp.arange(self.cfg.window)[:, None]
        return jnp.any(hits & live[None, :], axis=1)

    def _obs(self, state: EnvState) -> jnp.ndarray:
        member = self._member(state)
        valid = state.queue.valid
        progress = state.group_size.astype(jnp.float32) / max(1, self.cfg.c_max)
        flags = jnp.stack([
            (valid & ~state.scheduled & ~member).astype(jnp.float32),
            member.astype(jnp.float32),
            (state.scheduled & valid).astype(jnp.float32),
            (~valid).astype(jnp.float32),
            jnp.where(valid, progress, 0.0),
        ], axis=1)
        return jnp.concatenate([state.queue.features, flags], axis=1).reshape(-1)

    def _mask(self, state: EnvState) -> jnp.ndarray:
        member = self._member(state)
        can_select = (state.queue.valid & ~state.scheduled & ~member
                      & (state.group_size < self.cfg.c_max))
        can_close = (state.group_size >= 1) & (self.table.arity == state.group_size)
        return jnp.concatenate([can_select, can_close])

    def _done(self, state: EnvState) -> jnp.ndarray:
        return (jnp.all(state.scheduled | ~state.queue.valid)
                & (state.group_size == 0))

    def _step(self, state: EnvState, action: jnp.ndarray):
        """Pure transition -> (state', obs', reward, done, mask')."""
        W = self.cfg.window
        mask = self._mask(state)
        valid = mask[action]
        is_select = action < W
        # select branch: append to the open group (selection order preserved)
        sel_state = state._replace(
            group_idx=state.group_idx.at[state.group_size].set(
                action.astype(jnp.int32)),
            group_size=state.group_size + 1,
        )
        # close branch: score the group under partition p, mark scheduled
        p_idx = jnp.clip(action - W, 0, len(self.partitions) - 1)
        r_close = group_reward(self.table, state.queue, state.group_idx,
                               state.group_size, p_idx,
                               self.cfg.r_i_weight, self.cfg.r_f_scale)
        close_state = state._replace(
            scheduled=state.scheduled | self._member(state),
            group_idx=jnp.full((self.cfg.c_max,), -1, jnp.int32),
            group_size=jnp.int32(0),
        )
        branch = jax.tree.map(lambda a, b: jnp.where(is_select, a, b),
                              sel_state, close_state)
        new_state = jax.tree.map(lambda a, b: jnp.where(valid, a, b),
                                 branch, state)
        reward = jnp.where(
            valid,
            jnp.where(is_select, 0.0, r_close),
            jnp.float32(self.cfg.invalid_penalty),
        )
        return (new_state, self._obs(new_state), reward,
                self._done(new_state), self._mask(new_state))

    def _close_metrics(self, state: EnvState, action: jnp.ndarray):
        """(co-run time, solo time, multi-job?) the close `action` realizes.

        Zeros when `action` is not a valid close, so an evaluation scan can
        unconditionally accumulate these alongside ``step``/``step_batch`` —
        the relative-throughput bookkeeping of the greedy rollout stays
        entirely on device (no Python perfmodel in the eval hot path).
        """
        W = self.cfg.window
        ok = self._mask(state)[action] & (action >= W)
        p_idx = jnp.clip(action - W, 0, len(self.partitions) - 1)
        mk, so, _ = group_metrics(self.table, state.queue, state.group_idx,
                                  state.group_size, p_idx)
        zero = jnp.float32(0.0)
        return (jnp.where(ok, mk, zero), jnp.where(ok, so, zero),
                ok & (state.group_size > 1))


class CoScheduleEnv:
    """Gym-style (reset/step) reference wrapper, dependency-free.

    Thin stateful shell over the same action/observation contract as the
    functional core, kept for the scheduler/baselines API.  Rewards use the
    float64 Python perfmodel, making this the ground truth the vectorized
    engine is parity-tested against; it also materializes the
    :class:`Schedule` object the online phase consumes.
    """

    def __init__(self, cfg: EnvConfig | None = None):
        self.cfg = cfg or EnvConfig()
        self.partitions: list[Partition] = enumerate_partitions(self.cfg.c_max)
        self.n_features = len(FEATURES)
        self.state_dim = self.cfg.window * (self.n_features + N_FLAGS)
        self.n_actions = self.cfg.window + len(self.partitions)
        self._queue: list[JobProfile] = []

    # ------------------------------------------------------------------ API
    def reset(self, queue: list[JobProfile]) -> tuple[np.ndarray, np.ndarray]:
        assert len(queue) <= self.cfg.window
        self._queue = list(queue)
        self._scheduled = [False] * len(queue)
        self._in_group: list[int] = []           # selection-ordered indices
        self.schedule = Schedule()
        return self._state(), self.action_mask()

    def step(self, action: int):
        W = self.cfg.window
        reward = 0.0
        if not self._valid(action):
            return self._state(), self.cfg.invalid_penalty, self.done, self.action_mask(), {}
        if action < W:
            self._in_group.append(action)
        else:
            partition = self.partitions[action - W]
            group = [self._queue[i] for i in self._in_group]
            reward = self._close_reward(group, partition)
            self.schedule.add(group, partition)
            for i in self._in_group:
                self._scheduled[i] = True
            self._in_group = []
        return self._state(), reward, self.done, self.action_mask(), {}

    @property
    def done(self) -> bool:
        return all(self._scheduled) and not self._in_group

    # ------------------------------------------------------------- internals
    def _valid(self, action: int) -> bool:
        W = self.cfg.window
        if action < W:
            return (action < len(self._queue)
                    and not self._scheduled[action]
                    and action not in self._in_group
                    and len(self._in_group) < self.cfg.c_max)
        p = self.partitions[action - W]
        return len(self._in_group) >= 1 and p.arity == len(self._in_group)

    def action_mask(self) -> np.ndarray:
        return np.array([self._valid(a) for a in range(self.n_actions)], dtype=bool)

    def _state(self) -> np.ndarray:
        W = self.cfg.window
        out = np.zeros((W, self.n_features + N_FLAGS), np.float32)
        progress = len(self._in_group) / max(1, self.cfg.c_max)
        for i in range(W):
            if i >= len(self._queue):
                out[i, self.n_features + 3] = 1.0       # padding
                continue
            out[i, : self.n_features] = self._queue[i].features()
            out[i, self.n_features + 0] = float(not self._scheduled[i] and i not in self._in_group)
            out[i, self.n_features + 1] = float(i in self._in_group)
            out[i, self.n_features + 2] = float(self._scheduled[i])
            out[i, self.n_features + 4] = progress
        return out.reshape(-1)

    # ------------------------------------------------------------- rewards
    def _close_reward(self, group: list[JobProfile], partition: Partition) -> float:
        means = self._window_means()
        ri = sum(
            self._r_i(job, beta, s.units, means)
            for job, (_, s, beta) in zip(group, partition.slots)
        )
        ct = corun_time(group, partition)
        st = solo_run_time(group)
        rf = (st / ct - 1.0) * self.cfg.r_f_scale if ct > 0 else 0.0
        return self.cfg.r_i_weight * ri + rf

    def _window_means(self) -> dict:
        jobs = self._queue
        return {
            "compute": float(np.mean([j.compute_pct for j in jobs])) or 1e-9,
            "memory": float(np.mean([j.memory_pct for j in jobs])) or 1e-9,
            "duration": float(np.mean([j.solo_time() for j in jobs])) or 1e-9,
        }

    def _r_i(self, job: JobProfile, beta: float, units: int, means: dict) -> float:
        """Paper Table VI intermediate reward, TPU-mapped:
        SmAllocRatio = chips fraction x β; MemoryAllocRatio = slice bandwidth
        fraction (co-residents all access the slice's bandwidth, like the
        GI's αm)."""
        sm_alloc = (units / N_UNITS) * beta
        mem_alloc = units / N_UNITS
        compute_ratio = job.compute_pct / max(means["compute"], 1e-9)
        memory_ratio = job.memory_pct / max(means["memory"], 1e-9)
        duration_ratio = job.solo_time() / max(means["duration"], 1e-9)
        return (sm_alloc * compute_ratio + mem_alloc * memory_ratio) * duration_ratio ** 2
