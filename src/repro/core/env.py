"""RL environment for co-scheduling + hierarchical partitioning (paper §IV-C).

State: W slots x (f profile features + 5 status flags), flattened — exactly
the paper's input layer ``W x (f+5)``.
Actions: W *select-job-i into the current group* + N_p *close the group with
partition p* (the paper's A = W + N_p decomposition; assignment to partition
slots follows selection order, covering the C! orderings).
Rewards (paper Table VI):
    on close:  Σ_j r_i(j)  +  r_f = (SoloRunTime/CoRunTime - 1) x 100
    r_i = (SmAllocRatio*ComputeRatio + MemoryAllocRatio*MemoryRatio) * DurationRatio^2
Episode: schedule the whole window; terminal when all W jobs are grouped.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.partition import N_UNITS, Partition, enumerate_partitions
from repro.core.perfmodel import corun_time, solo_run_time
from repro.core.problem import Schedule
from repro.core.profiles import FEATURES, JobProfile

N_FLAGS = 5  # available, in-group, scheduled, padding, group-progress


@dataclass
class EnvConfig:
    window: int = 12                     # W
    c_max: int = 4                       # Cmax
    r_f_scale: float = 100.0             # paper: x100
    r_i_weight: float = 0.2              # r_f carries the true objective
    invalid_penalty: float = -10.0       # masked anyway; safety net


class CoScheduleEnv:
    """Gym-style (reset/step) but dependency-free."""

    def __init__(self, cfg: EnvConfig | None = None):
        self.cfg = cfg or EnvConfig()
        self.partitions: list[Partition] = enumerate_partitions(self.cfg.c_max)
        self.n_features = len(FEATURES)
        self.state_dim = self.cfg.window * (self.n_features + N_FLAGS)
        self.n_actions = self.cfg.window + len(self.partitions)
        self._queue: list[JobProfile] = []

    # ------------------------------------------------------------------ API
    def reset(self, queue: list[JobProfile]) -> tuple[np.ndarray, np.ndarray]:
        assert len(queue) <= self.cfg.window
        self._queue = list(queue)
        self._scheduled = [False] * len(queue)
        self._in_group: list[int] = []           # selection-ordered indices
        self.schedule = Schedule()
        return self._state(), self.action_mask()

    def step(self, action: int):
        W = self.cfg.window
        reward = 0.0
        if not self._valid(action):
            return self._state(), self.cfg.invalid_penalty, self.done, self.action_mask(), {}
        if action < W:
            self._in_group.append(action)
        else:
            partition = self.partitions[action - W]
            group = [self._queue[i] for i in self._in_group]
            reward = self._close_reward(group, partition)
            self.schedule.add(group, partition)
            for i in self._in_group:
                self._scheduled[i] = True
            self._in_group = []
        return self._state(), reward, self.done, self.action_mask(), {}

    @property
    def done(self) -> bool:
        return all(self._scheduled) and not self._in_group

    # ------------------------------------------------------------- internals
    def _valid(self, action: int) -> bool:
        W = self.cfg.window
        if action < W:
            return (action < len(self._queue)
                    and not self._scheduled[action]
                    and action not in self._in_group
                    and len(self._in_group) < self.cfg.c_max)
        p = self.partitions[action - W]
        return len(self._in_group) >= 1 and p.arity == len(self._in_group)

    def action_mask(self) -> np.ndarray:
        return np.array([self._valid(a) for a in range(self.n_actions)], dtype=bool)

    def _state(self) -> np.ndarray:
        W = self.cfg.window
        out = np.zeros((W, self.n_features + N_FLAGS), np.float32)
        progress = len(self._in_group) / max(1, self.cfg.c_max)
        for i in range(W):
            if i >= len(self._queue):
                out[i, self.n_features + 3] = 1.0       # padding
                continue
            out[i, : self.n_features] = self._queue[i].features()
            out[i, self.n_features + 0] = float(not self._scheduled[i] and i not in self._in_group)
            out[i, self.n_features + 1] = float(i in self._in_group)
            out[i, self.n_features + 2] = float(self._scheduled[i])
            out[i, self.n_features + 4] = progress
        return out.reshape(-1)

    # ------------------------------------------------------------- rewards
    def _close_reward(self, group: list[JobProfile], partition: Partition) -> float:
        means = self._window_means()
        ri = sum(
            self._r_i(job, beta, s.units, means)
            for job, (_, s, beta) in zip(group, partition.slots)
        )
        ct = corun_time(group, partition)
        st = solo_run_time(group)
        rf = (st / ct - 1.0) * self.cfg.r_f_scale if ct > 0 else 0.0
        return self.cfg.r_i_weight * ri + rf

    def _window_means(self) -> dict:
        jobs = self._queue
        return {
            "compute": float(np.mean([j.compute_pct for j in jobs])) or 1e-9,
            "memory": float(np.mean([j.memory_pct for j in jobs])) or 1e-9,
            "duration": float(np.mean([j.solo_time() for j in jobs])) or 1e-9,
        }

    def _r_i(self, job: JobProfile, beta: float, units: int, means: dict) -> float:
        """Paper Table VI intermediate reward, TPU-mapped:
        SmAllocRatio = chips fraction x β; MemoryAllocRatio = slice bandwidth
        fraction (co-residents all access the slice's bandwidth, like the
        GI's αm)."""
        sm_alloc = (units / N_UNITS) * beta
        mem_alloc = units / N_UNITS
        compute_ratio = job.compute_pct / max(means["compute"], 1e-9)
        memory_ratio = job.memory_pct / max(means["memory"], 1e-9)
        duration_ratio = job.solo_time() / max(means["duration"], 1e-9)
        return (sm_alloc * compute_ratio + mem_alloc * memory_ratio) * duration_ratio ** 2
