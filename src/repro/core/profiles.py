"""Job profiles — the TPU analogue of the paper's Nsight hardware counters.

A ``JobProfile`` stores per-slice-size roofline terms (compute/memory/
collective seconds per step), derived either from dry-run compiled artifacts
(``from_dryrun_record``) or analytically (``analytic_profile``).  From these
the paper's counter-derived features follow directly:

    Compute (SM) [%]  -> compute_pct  = compute term / step time
    Memory [%]        -> memory_pct   = memory term / step time
    Duration          -> steps x solo step time
    scalability       -> solo(1 unit) / solo(8 units) ratio

Classification (paper §V-A2, verbatim procedure):
    US if 1-unit-private run degrades < 10% vs the full 8-unit run;
    else CI if compute_pct / memory_pct > 0.80;
    else MI.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field

from repro.core.partition import CHIPS_PER_UNIT, N_UNITS, VALID_WIDTHS
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_bytes_min, model_flops

# fixed per-step overhead (dispatch); plus per-collective ring latency that
# GROWS with slice width — small latency-bound jobs therefore run *better* on
# small slices, reproducing the paper's US (unscalable) class on TPU.
LAUNCH_LATENCY_S = 75e-6
HOP_LATENCY_S = 1.2e-6
COLL_BASE_LAT_S = 6e-6           # per sequential collective, fixed part
COLL_HOP_LAT_S = 1.0e-6          # per ring hop

FEATURES = (
    "compute_pct", "memory_pct", "coll_pct", "scalability",
    "log_duration", "log_flops", "serial_frac",
)


@dataclass
class JobProfile:
    name: str
    arch: str
    shape: str
    steps: int                                # job length in steps
    flops_total: float                        # per step, whole job
    bytes_total: float                        # per step, minimum HBM traffic
    coll_bytes_chip_pod: float                # per step per chip at full pod
    n_coll_step: int = 0                      # sequential collectives per step
    serial_s: float = 0.0                     # non-parallelizable per-step time
    meta: dict = field(default_factory=dict)

    # ---- per-slice roofline terms -----------------------------------------
    def terms(self, units: int, torus_factor: float | None = None) -> tuple[float, float, float]:
        chips = units * CHIPS_PER_UNIT
        tf = (1.0 if units == N_UNITS else 0.5) if torus_factor is None else torus_factor
        compute = self.flops_total / (chips * PEAK_FLOPS)
        memory = self.bytes_total / (chips * HBM_BW)
        # ring all-reduce payload per chip is ~size-independent of ring width;
        # add per-hop latency that grows with the ring (data axis rows).
        coll = self.coll_bytes_chip_pod / (ICI_BW * tf)
        return compute, memory, coll

    def fixed_latency(self, units: int) -> float:
        rows = units * 2                       # data-axis ring length in the slice
        return LAUNCH_LATENCY_S + HOP_LATENCY_S * (rows + 16)

    def coll_latency(self, units: int) -> float:
        """Latency of the per-step chain of sequential collectives (ring
        perimeter grows with slice width: wider slice = slower small-payload
        collectives)."""
        ring = 2 * units + 16                  # data-axis rows + model-axis ring
        return self.n_coll_step * (COLL_BASE_LAT_S + COLL_HOP_LAT_S * ring)

    def step_time(self, units: int, beta: float = 1.0, mem_factor: float = 1.0,
                  torus_factor: float | None = None, coll_bytes_factor: float = 1.0,
                  coll_lat_factor: float = 1.0) -> float:
        c, m, x = self.terms(units, torus_factor)
        x_tot = x * coll_bytes_factor + self.coll_latency(units) * coll_lat_factor
        return max(c / beta, m * mem_factor, x_tot) + self.fixed_latency(units) + self.serial_s

    # ---- paper counters ------------------------------------------------------
    def solo_step_time(self, units: int = N_UNITS) -> float:
        return self.step_time(units)

    def solo_time(self) -> float:
        return self.steps * self.solo_step_time()

    @property
    def compute_pct(self) -> float:
        c, _, _ = self.terms(N_UNITS)
        return c / self.solo_step_time()

    @property
    def memory_pct(self) -> float:
        _, m, _ = self.terms(N_UNITS)
        return m / self.solo_step_time()

    @property
    def coll_pct(self) -> float:
        _, _, x = self.terms(N_UNITS)
        return (x + self.coll_latency(N_UNITS)) / self.solo_step_time()

    @property
    def scalability(self) -> float:
        """step(1 unit) / step(8 units): 8 = perfect scaling, ~1 = unscalable."""
        return self.step_time(1) / self.step_time(N_UNITS)

    @property
    def serial_frac(self) -> float:
        return self.serial_s / self.solo_step_time()

    @property
    def requested_units(self) -> int:
        """Slice width the submission asks for (``meta["units"]``, default
        full pod).  This is the placement hint honored by the online
        dispatch layer — right-sized traces set it so unscalable jobs
        occupy only the slice they can actually use."""
        u = int(self.meta.get("units", N_UNITS))
        return u if u in VALID_WIDTHS else N_UNITS

    def right_size(self, tol: float = 1.25) -> int:
        """Narrowest slice width whose solo step time stays within ``tol``
        of the full-pod step time (MISO-style right-sizing).  US jobs
        right-size to 1 unit at any tolerance (they run *faster* on small
        slices — shorter collective rings), MI decode lands on 2-4 units at
        looser tolerances, scalable CI training stays full-pod."""
        full = self.step_time(N_UNITS)
        for u in (1, 2, 4):
            if self.step_time(u) <= tol * full:
                return u
        return N_UNITS

    @property
    def job_class(self) -> str:
        if self.step_time(1) / self.step_time(N_UNITS) < 1.1:
            return "US"
        if self.memory_pct > 0 and self.compute_pct / self.memory_pct > 0.80:
            return "CI"
        return "MI"

    def features(self, window_means: dict | None = None) -> list[float]:
        st = self.solo_step_time()
        vals = {
            "compute_pct": self.compute_pct,
            "memory_pct": self.memory_pct,
            "coll_pct": self.coll_pct,
            "scalability": self.scalability / N_UNITS,
            "log_duration": math.log10(max(self.solo_time(), 1e-9)) / 6.0,
            "log_flops": math.log10(max(self.flops_total, 1.0)) / 20.0,
            "serial_frac": self.serial_frac,
        }
        _ = st, window_means
        return [float(vals[k]) for k in FEATURES]


# ---------------------------------------------------------------------------
# Profile sources
# ---------------------------------------------------------------------------

def analytic_profile(cfg, shape, steps: int = 100, name: str | None = None) -> JobProfile:
    """Profile from the analytic cost model (no dry-run files needed)."""
    from repro.launch.roofline import model_coll_bytes_chip

    flops = model_flops(cfg, shape)
    byts = model_bytes_min(cfg, shape)
    coll = model_coll_bytes_chip(cfg, shape)
    layers = max(1, cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0))
    if shape.kind == "train":
        n_coll = 4 * layers + 8          # 2 fwd + 2 bwd TP ARs/layer + step-level
    else:
        n_coll = 2 * layers + 2
    serial = 0.0
    if cfg.family == "ssm" and shape.kind != "decode":
        # sLSTM sequential recurrence: per-token latency floor
        serial = shape.seq_len * (cfg.n_layers // 2) * 0.2e-6
    if shape.kind == "decode":
        # decode latency floor: one serial pass through the stack
        serial = cfg.n_layers * 2.0e-6
    return JobProfile(
        name=name or f"{cfg.name}:{shape.name}",
        arch=cfg.name, shape=shape.name, steps=steps,
        flops_total=flops, bytes_total=byts, coll_bytes_chip_pod=coll,
        n_coll_step=n_coll, serial_s=serial, meta={"source": "analytic"},
    )


def from_dryrun_record(rec: dict, cfg, shape, steps: int = 100) -> JobProfile:
    """Profile from a dry-run JSON record (compiled-artifact counters)."""
    chips = rec["chips"]
    prof = analytic_profile(cfg, shape, steps)
    prof.flops_total = rec["flops_per_chip"] * chips
    prof.bytes_total = rec["bytes_per_chip"] * chips
    prof.coll_bytes_chip_pod = rec["coll_bytes_weighted"]
    if rec.get("coll_count_unit"):
        prof.n_coll_step = int(rec["coll_count_unit"]) * int(rec.get("scan_units", 1))
    prof.meta = {"source": "dryrun", "mesh": rec["mesh"], "dominant": rec.get("dominant")}
    return prof


def load_dryrun_profiles(dryrun_dir: str, steps: int = 100) -> dict[str, JobProfile]:
    """All pod-mesh dry-run records -> profiles keyed by "arch:shape"."""
    from repro.configs import get_config, get_shape

    out: dict[str, JobProfile] = {}
    if not os.path.isdir(dryrun_dir):
        return out
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fn)) as f:
            rec = json.load(f)
        if not rec.get("ok") or rec.get("mesh") != "pod" or rec.get("rules") != "baseline":
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        prof = from_dryrun_record(rec, cfg, shape, steps)
        out[f"{rec['arch']}:{rec['shape']}"] = prof
    return out


# ---------------------------------------------------------------------------
# ProfileRepository (paper §IV-B online protocol)
# ---------------------------------------------------------------------------

class ProfileRepository:
    """Keyed by job binary path+name (paper's matching function).

    Besides the lookup/insert protocol the online scheduler uses, the
    repository is the *training corpus* of the MISO-style periodic
    re-training loop (``repro.online.retrain``): ``jobs()`` snapshots the
    profiles collected so far so ``train_agent`` can refresh the agent
    against exactly the applications the cluster has actually seen.
    """

    def __init__(self):
        self._store: dict[str, JobProfile] = {}

    def key(self, binary_path: str) -> str:
        return binary_path

    def lookup(self, binary_path: str) -> JobProfile | None:
        return self._store.get(self.key(binary_path))

    def insert(self, binary_path: str, profile: JobProfile) -> None:
        self._store[self.key(binary_path)] = profile

    def jobs(self) -> list[JobProfile]:
        """Insertion-ordered snapshot of every profiled application."""
        return list(self._store.values())

    def class_counts(self) -> dict[str, int]:
        """CI/MI/US population of the repository (re-training gate input)."""
        out = {"CI": 0, "MI": 0, "US": 0}
        for p in self._store.values():
            out[p.job_class] += 1
        return out

    def __contains__(self, binary_path: str) -> bool:
        return self.key(binary_path) in self._store

    def __len__(self) -> int:
        return len(self._store)
