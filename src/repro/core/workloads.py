"""The benchmark-job zoo and queue construction (paper §V-A2 analogue).

Jobs are training/serving steps of the 10 assigned architectures at scaled
shape variants — the role Rodinia/CORAL play in the paper.  Profiles come
from dry-run artifacts when available (experiments/dryrun), else from the
analytic model.  Jobs are classified CI/MI/US with the paper's procedure and
queues are drawn per the paper's mix recipes (X-dominant = 50% X, rest
round-robin; Balanced = round-robin).
"""
from __future__ import annotations

import numpy as np

from repro.configs import SHAPES, get_config, scaled_shape
from repro.core.profiles import JobProfile, analytic_profile, load_dryrun_profiles

# (arch, shape-id, batch_div, seq_div) — spans CI / MI / US behaviors.
# Job lengths (steps) are auto-balanced to a per-job target duration so that
# solo durations are comparable-but-varied (paper jobs run minutes each; the
# DurationRatio^2 reward presumes comparable scales).
_ZOO_SPEC: list[tuple[str, str, int, int]] = [
    # big dense training: compute-intensive (CI)
    ("qwen2.5-14b", "train_4k", 1, 1),
    ("llama3-8b", "train_4k", 1, 1),
    ("command-r-35b", "train_4k", 1, 1),
    ("mistral-nemo-12b", "train_4k", 1, 1),
    ("chameleon-34b", "train_4k", 1, 1),
    ("llama3-8b", "train_4k", 2, 1),
    ("jamba-v0.1-52b", "train_4k", 1, 1),
    # prefill: compute-bound inference (CI)
    ("llama3-8b", "prefill_32k", 1, 1),
    ("command-r-35b", "prefill_32k", 1, 1),
    ("mistral-nemo-12b", "prefill_32k", 1, 1),
    # MoE training / decode: bandwidth-leaning (MI)
    ("deepseek-moe-16b", "train_4k", 1, 1),
    ("qwen2-moe-a2.7b", "train_4k", 1, 1),
    ("llama3-8b", "decode_32k", 1, 1),
    ("qwen2.5-14b", "decode_32k", 1, 1),
    ("command-r-35b", "decode_32k", 1, 1),
    ("mistral-nemo-12b", "decode_32k", 1, 1),
    ("deepseek-moe-16b", "decode_32k", 1, 1),
    ("jamba-v0.1-52b", "decode_32k", 1, 1),
    ("chameleon-34b", "decode_32k", 1, 1),
    ("qwen2-moe-a2.7b", "decode_32k", 1, 1),
    # small / latency-bound: unscalable (US)
    ("xlstm-125m", "train_4k", 8, 4),
    ("xlstm-125m", "decode_32k", 1, 1),
    ("xlstm-125m", "long_500k", 1, 1),
    ("seamless-m4t-large-v2", "train_4k", 8, 8),
    ("seamless-m4t-large-v2", "decode_32k", 8, 4),
    ("jamba-v0.1-52b", "long_500k", 1, 1),
    ("llama3-8b", "decode_32k", 32, 8),
    ("qwen2-moe-a2.7b", "decode_32k", 16, 8),
    ("seamless-m4t-large-v2", "long_500k", 1, 32),
]

# deterministic varied target durations (seconds) — 3x spread like real queues
_TARGETS = (90.0, 150.0, 120.0, 60.0, 180.0, 75.0, 135.0)


def make_zoo(dryrun_dir: str | None = "experiments/dryrun") -> list[JobProfile]:
    """All zoo jobs with profiles; dry-run-backed where records exist."""
    dr = load_dryrun_profiles(dryrun_dir) if dryrun_dir else {}
    jobs: list[JobProfile] = []
    for i, (arch, shape_id, bd, sd) in enumerate(_ZOO_SPEC):
        cfg = get_config(arch)
        base = SHAPES[shape_id]
        if bd == 1 and sd == 1 and f"{arch}:{shape_id}" in dr:
            ref = dr[f"{arch}:{shape_id}"]
            prof = JobProfile(
                name=f"{arch}:{shape_id}#{i}", arch=arch, shape=shape_id,
                steps=1, flops_total=ref.flops_total, bytes_total=ref.bytes_total,
                coll_bytes_chip_pod=ref.coll_bytes_chip_pod, serial_s=ref.serial_s,
                meta=dict(ref.meta),
            )
        else:
            shape = scaled_shape(base, bd, sd)
            prof = analytic_profile(cfg, shape, 1, name=f"{arch}:{shape.name}#{i}")
        target = _TARGETS[i % len(_TARGETS)]
        prof.steps = max(1, int(round(target / prof.solo_step_time())))
        jobs.append(prof)
    return jobs


def zoo_by_class(jobs: list[JobProfile]) -> dict[str, list[JobProfile]]:
    out: dict[str, list[JobProfile]] = {"CI": [], "MI": [], "US": []}
    for j in jobs:
        out[j.job_class].append(j)
    return out


def make_queue(jobs: list[JobProfile], kind: str, window: int, rng: np.random.Generator,
               exclude: set[str] | None = None, strict: bool = True) -> list[JobProfile]:
    """Paper §V-A2 queue recipes: CI/MI/US-dominant or Balanced.

    ``strict=True`` (the default) demands every class be represented and
    raises otherwise — the historical contract for the curated zoo.  With
    ``strict=False`` missing classes are remapped round-robin onto the
    classes that *are* present, preserving the recipe's proportions as far
    as the pool allows; the online re-training loop needs this because the
    live :class:`~repro.core.profiles.ProfileRepository` grows one observed
    application at a time and may not cover all three classes yet.
    """
    by_cls = zoo_by_class([j for j in jobs if not exclude or j.name not in exclude])
    classes = ["CI", "MI", "US"]
    missing = [c for c in classes if not by_cls[c]]
    if missing:
        if strict or len(missing) == len(classes):
            raise ValueError(f"zoo has no {missing[0]} jobs")
        avail = [c for c in classes if by_cls[c]]
        by_cls.update({m: by_cls[avail[i % len(avail)]]
                       for i, m in enumerate(missing)})
    picks: list[JobProfile] = []
    if kind == "balanced":
        seq = [classes[i % 3] for i in range(window)]
    else:
        dom = kind.upper()
        assert dom in classes, kind
        others = [c for c in classes if c != dom]
        seq = [dom] * (window // 2)
        seq += [others[i % 2] for i in range(window - len(seq))]
    for c in seq:
        pool = by_cls[c]
        picks.append(pool[int(rng.integers(0, len(pool)))])
    return picks


QUEUE_KINDS = ("ci", "mi", "us", "balanced")


def paper_queues(jobs: list[JobProfile], window: int = 12, seed: int = 0,
                 per_kind: int = 3) -> dict[str, list[JobProfile]]:
    """Q1..Q12 analogue: per_kind queues per category (paper Table V)."""
    rng = np.random.default_rng(seed)
    out: dict[str, list[JobProfile]] = {}
    qi = 1
    for kind in QUEUE_KINDS:
        for _ in range(per_kind):
            out[f"Q{qi}"] = make_queue(jobs, kind, window, rng)
            qi += 1
    return out
