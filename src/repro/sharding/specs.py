"""Logical-axis sharding rules (MaxText-style) -> jax.sharding PartitionSpecs.

Model code never names mesh axes directly; it annotates tensors with *logical*
axes ("act_batch", "tp", "fsdp", ...).  A rules table maps logical axes onto
mesh axes, and mesh axes that do not exist on the active mesh are dropped —
the same model code therefore runs on the single-pod ("data", "model") mesh,
the multi-pod ("pod", "data", "model") mesh, scheduler sub-slice meshes, and
the 1-device CPU test mesh.

Hillclimbing perf = swapping the rules table, not editing the model.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Mapping[str, Any]  # logical axis -> mesh axis | tuple | None

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Paper-faithful / baseline rules: TP on "model", FSDP (param+opt sharding) on
# "data", batch DP over ("pod", "data").
DEFAULT_RULES: AxisRules = {
    # parameter axes
    "fsdp": "data",            # ZeRO/FSDP dim of every weight
    "fsdp_e": "data",          # FSDP dim of expert weights (never overlaps ep)
    "tp": "model",             # tensor-parallel dim of every weight
    "ep": "model",             # expert-parallel dim (routed experts)
    "vocab_tp": "model",
    # activation axes
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_expert": "model",
    "act_state": None,
    "act_seq_cache": None,       # decode KV-cache sequence dim
}

# Megatron-SP-style variant: activations sequence-sharded on "model" between
# blocks (all-gather in, reduce-scatter out). Enabled via ModelConfig.seq_parallel.
# act_vocab must come off "model" (logits chunks are seq-sharded there).
SEQ_PARALLEL_RULES: AxisRules = dict(DEFAULT_RULES, act_seq="model", act_vocab=None)

# FSDP+SP variant (hillclimb): no tensor parallelism — weights fully sharded
# over BOTH mesh axes (pure ZeRO-3), activations batch-sharded over "data"
# and sequence-sharded over "model". Replaces the per-layer O(B*S*M)
# activation all-reduces of TP with per-layer O(params) all-gathers.
FSDP_SP_RULES: AxisRules = {
    **DEFAULT_RULES,
    "tp": None,
    "fsdp": ("data", "model"),
    "fsdp_e": "data",            # expert dim keeps "model" for ep
    "act_heads": None,
    "act_kv_heads": None,
    "act_mlp": None,
    "act_expert": "model",
    "act_seq": "model",
    "act_seq_cache": "model",    # decode caches sequence-sharded too
    "act_vocab": None,           # logits seq-sharded instead (seq is on "model")
}

# ---------------------------------------------------------------------------
# Active mesh/rules context
# ---------------------------------------------------------------------------

_ctx: contextvars.ContextVar[tuple[Mesh | None, AxisRules]] = contextvars.ContextVar(
    "repro_mesh_rules", default=(None, DEFAULT_RULES)
)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: AxisRules = DEFAULT_RULES):
    token = _ctx.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _ctx.reset(token)


def active_mesh() -> Mesh | None:
    return _ctx.get()[0]


def current_rules() -> AxisRules:
    return _ctx.get()[1]


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _resolve(axis: Any, mesh: Mesh, rules: AxisRules):
    """Map one logical axis to mesh axes present on `mesh` (or None)."""
    if axis is None:
        return None
    mapped = rules.get(axis, None) if isinstance(axis, str) else axis
    if mapped is None:
        return None
    if isinstance(mapped, str):
        return mapped if mapped in mesh.axis_names else None
    # tuple of mesh axes: keep the ones this mesh has
    kept = tuple(a for a in mapped if a in mesh.axis_names)
    return kept if kept else None


def logical_spec(axes: Sequence[Any], mesh: Mesh | None = None, rules: AxisRules | None = None) -> P:
    mesh = mesh or active_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return P()
    return P(*(_resolve(a, mesh, rules) for a in axes))


def named_sharding(axes: Sequence[Any], mesh: Mesh | None = None, rules: AxisRules | None = None) -> NamedSharding:
    mesh = mesh or active_mesh()
    assert mesh is not None, "named_sharding requires an active mesh"
    return NamedSharding(mesh, logical_spec(axes, mesh, rules))


def constrain(x: jax.Array, axes: Sequence[Any]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without an active mesh."""
    mesh = active_mesh()
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, named_sharding(axes))


# ---------------------------------------------------------------------------
# Parameter spec tree (path-pattern rules)
# ---------------------------------------------------------------------------

# Pattern -> logical axes for the *trailing* dims of the parameter.  Scanned
# stacks (leading layer dim) get None prepended automatically.  First match
# wins; order matters.
#
# GQA note: when n_kv_heads < n_heads (TP degree exceeds kv heads), the K/V
# projections are *replicated* on the model axis (Megatron GQA strategy):
# redundant tiny kv-proj compute instead of a replicate+repartition collective
# per layer (measured ~20 GB/chip/layer on the pod dry-run otherwise).
_PARAM_RULES_KV_REPLICATED: list[tuple[str, tuple[Any, ...]]] = [
    (r"(wk|wv)$", ("fsdp", None)),
    (r"(bk|bv)$", (None,)),
]

# TP-of-experts fallback when n_routed is not divisible by the model axis
# (e.g. qwen2-moe's 60 experts on a 16-wide axis): shard the expert FFN dim
# instead of the expert dim.
_PARAM_RULES_EXPERT_TP: list[tuple[str, tuple[Any, ...]]] = [
    (r"experts_(wg|wu)$", (None, "fsdp", "tp")),
    (r"experts_wd$", (None, "tp", "fsdp")),
]

_PARAM_RULES: list[tuple[str, tuple[Any, ...]]] = [
    # MoE routed experts: (E, d_in, d_out)
    (r"experts_(wg|wu)$", ("ep", "fsdp_e", None)),
    (r"experts_wd$", ("ep", None, "fsdp_e")),
    (r"router$", ("fsdp", None)),
    # embedding / unembedding: vocab-sharded ONLY. Sharding the d_model dim
    # over "data" puts the logits-matmul contraction dim on the batch axis —
    # GSPMD then full-rematerializes (measured: replicated-batch f32 gathers).
    (r"(^|/)emb$", ("vocab_tp", None)),
    (r"lm_head$", (None, "vocab_tp")),
    # attention / general projections: in -> out(tp)
    (r"(wq|wk|wv|wqkv|wg|wu|w_in|w_up|w_i|w_gates)$", ("fsdp", "tp")),
    (r"(wo|wd|w_out|w_down)$", ("tp", "fsdp")),
    (r"(bq|bk|bv|bqkv|b_in|b_up)$", ("tp",)),
    # mamba internals (d_inner is the tp-sharded dim)
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"w_x$", ("tp", None)),
    (r"w_dt$", (None, "tp")),
    (r"b_dt$", ("tp",)),
    (r"A_log$", ("tp", None)),
    (r"(^|/)D$", ("tp",)),
    # sLSTM recurrent weights are tiny -> replicate
    (r"slstm_", ()),
    # norms, small biases, gates: replicate
    (r".*", ()),
]


def _spec_for_path(path: str, ndim: int, scanned: bool, replicate_kv: bool = False,
                   ep_experts: bool = True) -> tuple[Any, ...]:
    rules = list(_PARAM_RULES)
    if replicate_kv:
        rules = _PARAM_RULES_KV_REPLICATED + rules
    if not ep_experts:
        rules = _PARAM_RULES_EXPERT_TP + rules
    for pat, axes in rules:
        if re.search(pat, path):
            base = list(axes)
            break
    else:  # pragma: no cover
        base = []
    want = ndim - (1 if scanned else 0)
    # pad/trim to the parameter's trailing rank
    if len(base) > want:
        base = base[-want:] if want > 0 else []
    while len(base) < want:
        base.insert(0, None)
    if scanned:
        base.insert(0, None)  # stacked layer dim: never sharded
    return tuple(base)


_SCAN_KEYS = ("layers", "blocks", "enc_layers", "dec_layers", "pairs")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def build_param_specs(params: Any, replicate_kv: bool = False,
                      ep_experts: bool = True) -> Any:
    """PartitionSpec pytree (logical axes resolved later) matching `params`.

    Returns a pytree of *logical axis tuples*; resolve with `logical_spec`
    against a concrete mesh/rules.  ``replicate_kv``: GQA kv-projection
    replication; ``ep_experts=False``: TP-of-experts fallback for expert
    counts not divisible by the model axis.
    """

    def leaf_spec(path, leaf):
        s = _path_str(path)
        scanned = any(f"{k}/" in s or s.startswith(f"{k}/") for k in _SCAN_KEYS)
        return _spec_for_path(s, leaf.ndim if hasattr(leaf, "ndim") else 0, scanned,
                              replicate_kv, ep_experts)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def specs_to_shardings(logical_tree: Any, mesh: Mesh, rules: AxisRules | None = None,
                       abstract_tree: Any = None) -> Any:
    """Resolve a logical-axes pytree into NamedShardings for a mesh.

    With ``abstract_tree`` (matching ShapeDtypeStructs), any dimension whose
    size is not divisible by its resolved mesh-axes product is dropped to
    replicated — the production-safe fallback for odd head/gate/expert counts
    and batch-1 decode cells."""
    rules = rules or DEFAULT_RULES
    axis_size = dict(zip(mesh.axis_names, np.asarray(mesh.devices).shape))

    def spec_size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, str):
            return axis_size.get(entry, 1)
        n = 1
        for a in entry:
            n *= axis_size.get(a, 1)
        return n

    def resolve(axes, leaf=None):
        spec = logical_spec(axes, mesh, rules)
        if leaf is not None and hasattr(leaf, "shape"):
            fixed = []
            for i, entry in enumerate(spec):
                if i < len(leaf.shape) and leaf.shape[i] % spec_size(entry) != 0:
                    fixed.append(None)
                else:
                    fixed.append(entry)
            spec = P(*fixed)
        return NamedSharding(mesh, spec)

    is_leaf = lambda x: isinstance(x, tuple)
    if abstract_tree is None:
        return jax.tree.map(resolve, logical_tree, is_leaf=is_leaf)
    return jax.tree.map(resolve, logical_tree, abstract_tree, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# Inference-cache spec tree (path-pattern rules, trailing-dim aligned)
# ---------------------------------------------------------------------------

_CACHE_RULES: list[tuple[str, tuple[Any, ...]]] = [
    (r"cross/len$", ("act_batch",)),
    (r"(^|/)(k|v)$", ("act_batch", "act_seq_cache", "act_kv_heads", None)),
    (r"mamba/h$", ("act_batch", "tp", None)),
    (r"mamba/conv$", ("act_batch", None, "tp")),
    (r"mlstm/C$", ("act_batch", "act_heads", None, None)),
    (r"mlstm/n$", ("act_batch", "act_heads", None)),
    (r"mlstm/m$", ("act_batch", "act_heads")),
    (r"mlstm/conv$", ("act_batch", None, "tp")),
    (r"slstm/", ("act_batch", None, None)),
    (r".*", ("act_batch",)),
]


def build_cache_specs(cache: Any, replicate_kv: bool = False) -> Any:
    """Logical-axes pytree for an inference cache (leading stack dims -> None).

    ``replicate_kv``: GQA caches keep heads replicated (batch-sharded only),
    matching the replicated kv projections."""

    def leaf_spec(path, leaf):
        s = _path_str(path)
        for pat, axes in _CACHE_RULES:
            if re.search(pat, s):
                base = list(axes)
                if replicate_kv and re.search(r"(^|/)(k|v)$", s):
                    base = ["act_batch", "act_seq_cache", None, None]
                break
        ndim = leaf.ndim if hasattr(leaf, "ndim") else 0
        if len(base) > ndim:
            base = base[-ndim:] if ndim else []
        while len(base) < ndim:
            base.insert(0, None)
        return tuple(base)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
