"""Elastic scaling + failure recovery for the training runtime.

Strategy (pure-JAX, checkpoint-based — the robust production pattern):
  * Failures are detected per data-axis *row* of the pod mesh (a TPU host
    owns whole rows; host loss removes its rows).
  * Recovery = rebuild a rectangular mesh from the surviving rows (the mesh
    must stay rectangular for XLA SPMD), re-resolve shardings against the new
    mesh, restore the last committed checkpoint onto it, and re-partition the
    global batch over the shrunken data axis.
  * The data pipeline is counter-based (repro.data), so batch re-partitioning
    is a pure function of (step, new row range) — no iterator state to
    migrate.

`ElasticTrainer` drives this loop and is exercised on CPU in the tests with
simulated failure events.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
from jax.sharding import Mesh

from repro import checkpoint as ckpt_lib


@dataclass
class FailureEvent:
    step: int
    failed_rows: list[int]            # data-axis rows lost at this step


def surviving_mesh(mesh: Mesh, failed_rows: list[int]) -> Mesh:
    """Largest rectangular mesh from surviving data-axis rows.

    XLA SPMD needs a rectangular device array; we keep all surviving rows
    (contiguity is not required — rows are re-indexed) but truncate to a
    power-of-two row count so power-of-two shardings stay divisible.
    """
    devices = np.asarray(mesh.devices)
    assert devices.ndim == 2
    keep = [r for r in range(devices.shape[0]) if r not in set(failed_rows)]
    if not keep:
        raise RuntimeError("all data rows failed")
    n = 1
    while n * 2 <= len(keep):
        n *= 2
    return Mesh(devices[keep[:n], :], mesh.axis_names)


def rebalance_bounds(global_batch: int, n_rows: int, row: int) -> tuple[int, int]:
    """Row's [lo, hi) slice of the global batch after elastic resize."""
    per = global_batch // n_rows
    rem = global_batch % n_rows
    lo = row * per + min(row, rem)
    return lo, lo + per + (1 if row < rem else 0)


@dataclass
class ElasticTrainer:
    """Checkpoint-restart elastic loop. `make_step(mesh)` builds the jitted
    step for a mesh; `init_state(mesh)` materializes fresh state on it."""

    make_step: object
    init_state: object
    ckpt_dir: str
    ckpt_every: int = 10
    log: list = field(default_factory=list)

    def run(self, mesh: Mesh, n_steps: int, batch_fn,
            failures: list[FailureEvent] | None = None):
        failures = list(failures or [])
        step_fn = self.make_step(mesh)
        state = self.init_state(mesh)
        step = 0
        # resume if a committed checkpoint exists (restart-after-crash path)
        latest = ckpt_lib.latest_step(self.ckpt_dir)
        if latest is not None:
            tree, extra, step = ckpt_lib.restore(self.ckpt_dir)
            state = self._load(state, tree, mesh)
            self.log.append(f"resumed@{step}")

        while step < n_steps:
            pending = [f for f in failures if f.step == step]
            if pending:
                # failure: shrink mesh, restore last commit, rebalance.
                # Remove the handled events BY IDENTITY before the restore
                # rewinds `step` — filtering by step equality after the rewind
                # would leave the event armed and re-fire it forever.
                failures = [f for f in failures if f not in pending]
                mesh = surviving_mesh(mesh, [r for f in pending for r in f.failed_rows])
                step_fn = self.make_step(mesh)
                state = self.init_state(mesh)
                latest = ckpt_lib.latest_step(self.ckpt_dir)
                if latest is not None:
                    tree, _, step = ckpt_lib.restore(self.ckpt_dir)
                    state = self._load(state, tree, mesh)
                self.log.append(f"shrunk_to_{np.asarray(mesh.devices).shape}@{step}")
                continue
            batch = batch_fn(step, mesh)
            state = step_fn(state, batch)
            step += 1
            if step % self.ckpt_every == 0:
                ckpt_lib.save(self.ckpt_dir, step, self._dump(state))
                self.log.append(f"ckpt@{step}")
        return state, mesh

    # state <-> host pytree (override for sharded state)
    @staticmethod
    def _dump(state):
        import jax

        return jax.device_get(state)

    @staticmethod
    def _load(state_template, tree, mesh):
        import jax

        flat_t, treedef = jax.tree.flatten(state_template)
        flat_n = jax.tree.leaves(tree)
        assert len(flat_t) == len(flat_n)
        out = [
            jax.device_put(np.asarray(n).astype(t.dtype).reshape(t.shape), t.sharding)
            if hasattr(t, "sharding") else np.asarray(n)
            for t, n in zip(flat_t, flat_n)
        ]
        return jax.tree.unflatten(treedef, out)
