from repro.runtime.steps import (
    abstract_state,
    batch_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_input_specs,
)

__all__ = [
    "abstract_state",
    "batch_specs",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
    "train_input_specs",
]
