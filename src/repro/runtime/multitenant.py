"""Level-2 (logical, ≈MPS) co-residency executor.

Mechanism (DESIGN.md §2): co-resident tenants on one slice are executed as a
*fused program* — one jitted callable that issues every tenant's step — so
XLA's scheduler overlaps tenant A's MXU work with tenant B's HBM/ICI streams
(the TPU analogue of MPS's concurrent SM sharing; pure time-slicing could
never beat time-sharing). Fractional compute shares β map to per-tenant
*quantum counts*: within one fused macro-step, tenant i advances ceil(β_i * Q)
micro-steps.

A quantum-level round-robin fallback (`QuantumExecutor`) covers tenants whose
programs cannot be fused (e.g. incompatible meshes), and doubles as the
straggler-mitigation point: a tenant whose step lags its expected time gets
its quanta rebalanced away (work stealing).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax


@dataclass
class Tenant:
    name: str
    step_fn: Callable                 # state -> state  (jit-able, closed over batch src)
    state: Any
    share: float = 1.0                # Level-2 β
    steps_done: int = 0
    time_spent: float = 0.0


def fuse_tenants(tenants: list[Tenant], quanta_per_cycle: int = 4):
    """One jitted macro-step advancing each tenant round-robin-interleaved
    according to its share. Returns (fused_fn, quanta list)."""
    total = sum(t.share for t in tenants)
    quanta = [max(1, round(t.share / total * quanta_per_cycle * len(tenants)))
              for t in tenants]

    def macro(states):
        out = []
        for t, st, q in zip(tenants, states, quanta):
            for _ in range(q):
                st = t.step_fn(st)
            out.append(st)
        return tuple(out)

    return jax.jit(macro), quanta


class FusedCoRunner:
    """Run a co-scheduled group to completion with a fused program."""

    def __init__(self, tenants: list[Tenant], total_steps: dict[str, int],
                 quanta_per_cycle: int = 4):
        self.tenants = tenants
        self.total_steps = total_steps
        self.macro, self.quanta = fuse_tenants(tenants, quanta_per_cycle)

    def run(self) -> dict[str, float]:
        """Returns per-tenant finish times (wall clock)."""
        states = tuple(t.state for t in self.tenants)
        finish: dict[str, float] = {}
        t0 = time.perf_counter()
        active = list(range(len(self.tenants)))
        while active:
            states = self.macro(states)
            jax.block_until_ready(states)
            now = time.perf_counter() - t0
            for i in list(active):
                t = self.tenants[i]
                t.steps_done += self.quanta[i]
                if t.steps_done >= self.total_steps[t.name]:
                    finish[t.name] = now
                    active.remove(i)
        for t, st in zip(self.tenants, states):
            t.state = st
        return finish


class QuantumExecutor:
    """Round-robin quantum scheduler with straggler-aware work rebalancing."""

    def __init__(self, tenants: list[Tenant], total_steps: dict[str, int],
                 straggler_factor: float = 2.0):
        self.tenants = tenants
        self.total_steps = total_steps
        self.straggler_factor = straggler_factor
        self.events: list[str] = []

    def _quanta(self) -> dict[str, int]:
        total = sum(t.share for t in self.tenants)
        return {t.name: max(1, round(4 * t.share / total * len(self.tenants)))
                for t in self.tenants}

    def run(self) -> dict[str, float]:
        finish: dict[str, float] = {}
        t0 = time.perf_counter()
        quanta = self._quanta()
        active = {t.name: t for t in self.tenants}
        expected: dict[str, float] = {}
        while active:
            for name, t in list(active.items()):
                q = quanta[name]
                qt0 = time.perf_counter()
                for _ in range(q):
                    t.state = t.step_fn(t.state)
                jax.block_until_ready(t.state)
                dt = time.perf_counter() - qt0
                t.steps_done += q
                t.time_spent += dt
                per_step = dt / q
                # straggler mitigation: a tenant running far beyond its own
                # historical per-step time gets one quantum stolen this cycle
                hist = expected.setdefault(name, per_step)
                if per_step > self.straggler_factor * hist and quanta[name] > 1:
                    quanta[name] -= 1
                    self.events.append(f"straggler:{name} quanta->{quanta[name]}")
                expected[name] = 0.8 * hist + 0.2 * per_step
                if t.steps_done >= self.total_steps[name]:
                    finish[name] = time.perf_counter() - t0
                    del active[name]
        return finish
