"""pjit step factories: train_step / prefill_step / decode_step.

Each factory returns (jitted_fn, shardings) where shardings carry the full
NamedSharding trees for inputs/outputs — the same trees drive the multi-pod
dry-run (``.lower`` on ShapeDtypeStructs) and real execution.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.models.model import decode_step, init_cache, init_params, loss_fn, prefill
from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.sharding import (
    DEFAULT_RULES,
    SEQ_PARALLEL_RULES,
    build_cache_specs,
    build_param_specs,
    logical_spec,
    specs_to_shardings,
    use_mesh_rules,
)


MODEL_AXIS_SIZE = 16  # model-axis width of both production meshes


def _rules_for(cfg, rules=None):
    if rules is not None:
        return rules
    return SEQ_PARALLEL_RULES if cfg.seq_parallel else DEFAULT_RULES


def _ep_ok(cfg) -> bool:
    return cfg.moe is None or cfg.moe.n_routed % MODEL_AXIS_SIZE == 0


# ---------------------------------------------------------------------------
# Abstract state + sharding trees
# ---------------------------------------------------------------------------

def abstract_state(cfg, with_opt: bool = True):
    """eval_shape'd (params, opt_state) — no allocation."""
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    if not with_opt:
        return params, None
    opt = jax.eval_shape(init_opt_state, params)
    return params, opt


def state_shardings(cfg, mesh: Mesh, rules=None, with_opt: bool = True):
    rules = _rules_for(cfg, rules)
    params, opt = abstract_state(cfg, with_opt)
    pspecs = build_param_specs(params, replicate_kv=cfg.n_kv_heads < cfg.n_heads,
                               ep_experts=_ep_ok(cfg))
    psh = specs_to_shardings(pspecs, mesh, rules, abstract_tree=params)
    if not with_opt:
        return params, psh, None, None
    osh = {
        "master": psh,
        "m": psh,
        "v": psh,
        "count": NamedSharding(mesh, logical_spec((), mesh, rules)),
    }
    return params, psh, opt, osh


def batch_specs(cfg, shape, mesh: Mesh, rules=None):
    """(abstract batch, shardings) for a training/prefill batch."""
    rules = _rules_for(cfg, rules)
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    sh = {
        "tokens": NamedSharding(mesh, logical_spec(("act_batch", None), mesh, rules)),
        "labels": NamedSharding(mesh, logical_spec(("act_batch", None), mesh, rules)),
    }
    if cfg.enc_dec:
        Se = min(cfg.enc_len, S)
        batch["frames"] = jax.ShapeDtypeStruct((B, Se, cfg.d_model), jnp.dtype(cfg.dtype))
        sh["frames"] = NamedSharding(mesh, logical_spec(("act_batch", None, None), mesh, rules))
    return batch, sh


def train_input_specs(cfg, shape, mesh: Mesh, rules=None):
    """All abstract inputs + shardings for train_step (dry-run entry)."""
    params, psh, opt, osh = state_shardings(cfg, mesh, rules)
    batch, bsh = batch_specs(cfg, shape, mesh, rules)
    return {"params": params, "opt_state": opt, "batch": batch}, \
           {"params": psh, "opt_state": osh, "batch": bsh}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: OptConfig, mesh: Mesh, rules=None, donate: bool = True):
    rules = _rules_for(cfg, rules)
    _, psh, _, osh = state_shardings(cfg, mesh, rules)
    _, bsh = batch_specs_like(cfg, mesh, rules)

    def step_fn(params, opt_state, batch):
        with use_mesh_rules(mesh, rules):
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
            new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
            metrics.update(om)
        return new_params, new_opt, metrics

    jit_kw = dict(
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, None),
    )
    if donate:
        jit_kw["donate_argnums"] = (0, 1)
    return jax.jit(step_fn, **jit_kw)


def batch_specs_like(cfg, mesh: Mesh, rules=None):
    """Shardings for a batch of unknown shape (shape-polymorphic jit reuse)."""
    rules = _rules_for(cfg, rules)
    sh = {
        "tokens": NamedSharding(mesh, logical_spec(("act_batch", None), mesh, rules)),
        "labels": NamedSharding(mesh, logical_spec(("act_batch", None), mesh, rules)),
    }
    if cfg.enc_dec:
        sh["frames"] = NamedSharding(mesh, logical_spec(("act_batch", None, None), mesh, rules))
    return None, sh


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def cache_shardings(cfg, mesh: Mesh, batch: int, max_len: int, rules=None):
    rules = _rules_for(cfg, rules)
    params, _ = abstract_state(cfg, with_opt=False)
    cache = jax.eval_shape(lambda p: init_cache(p, cfg, batch, max_len), params)
    cspecs = build_cache_specs(cache, replicate_kv=cfg.n_kv_heads < cfg.n_heads)
    return cache, specs_to_shardings(cspecs, mesh, rules, abstract_tree=cache)


def make_decode_step(cfg, mesh: Mesh, batch: int, max_len: int, rules=None, donate: bool = True):
    rules = _rules_for(cfg, rules)
    _, psh, _, _ = state_shardings(cfg, mesh, rules, with_opt=False)
    _, csh = cache_shardings(cfg, mesh, batch, max_len, rules)
    from repro.sharding import specs_to_shardings as _sts
    import jax.numpy as _jnp

    vec_abs = jax.ShapeDtypeStruct((batch,), _jnp.int32)
    vec = _sts(("act_batch",), mesh, rules, abstract_tree=vec_abs)
    logits_abs = jax.ShapeDtypeStruct((batch, cfg.vocab_size), _jnp.float32)
    logits_sh = _sts(("act_batch", "act_vocab"), mesh, rules, abstract_tree=logits_abs)

    def step_fn(params, cache, token, pos):
        with use_mesh_rules(mesh, rules):
            return decode_step(params, cache, token, pos, cfg)

    jit_kw = dict(
        in_shardings=(psh, csh, vec, vec),
        out_shardings=(logits_sh, csh),
    )
    if donate:
        jit_kw["donate_argnums"] = (1,)
    return jax.jit(step_fn, **jit_kw)


def make_prefill_step(cfg, mesh: Mesh, shape, rules=None):
    rules = _rules_for(cfg, rules)
    _, psh, _, _ = state_shardings(cfg, mesh, rules, with_opt=False)
    B, S = shape.global_batch, shape.seq_len

    if cfg.enc_dec:
        # enc-dec prefill == encoder pass + cross-KV build
        from repro.models.encdec import init_encdec_cache
        from repro.models.model import _embed  # noqa: F401

        def step_fn(params, frames, enc_lens):
            with use_mesh_rules(mesh, rules):
                from repro.models.encdec import encoder_apply
                from repro.models.layers import rmsnorm

                pos = jnp.arange(frames.shape[1])[None, :]
                enc_out = encoder_apply(params["enc_layers"], frames.astype(jnp.dtype(cfg.dtype)), cfg, pos)
                enc_out = rmsnorm(enc_out, params["enc_norm"], cfg.norm_eps)
                cache = init_encdec_cache(params, cfg, frames.shape[0], S, enc_out, enc_lens)
            return cache

        frames_sh = NamedSharding(mesh, logical_spec(("act_batch", None, None), mesh, rules))
        vec = NamedSharding(mesh, logical_spec(("act_batch",), mesh, rules))
        return jax.jit(step_fn, in_shardings=(psh, frames_sh, vec), out_shardings=None)

    def step_fn(params, tokens):
        with use_mesh_rules(mesh, rules):
            return prefill(params, tokens, cfg, max_len=S)

    tok_sh = NamedSharding(mesh, logical_spec(("act_batch", None), mesh, rules))
    return jax.jit(step_fn, in_shardings=(psh, tok_sh), out_shardings=None)
