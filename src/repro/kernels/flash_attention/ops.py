"""jit-ready wrapper around the flash-attention Pallas kernel.

``impl``:
  - "kernel": Pallas TPU kernel (compiled on TPU; interpret=True elsewhere)
  - "ref": pure-jnp oracle (what the CPU dry-run lowers; same math/FLOPs)
  - "auto": kernel on TPU backends, ref otherwise
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_chunked, flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("causal", "impl", "block_q", "block_k", "interpret", "unroll")
)
def flash_attention(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    impl: str = "auto",
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
    unroll: bool = False,
) -> jax.Array:
    if impl == "auto":
        # TPU: the Pallas kernel. CPU (tests + dry-run lowering): the chunked
        # jnp form — same math/FLOPs as the kernel with a flash-style working
        # set, so memory_analysis/cost_analysis reflect the TPU execution.
        impl = "kernel" if _on_tpu() else "chunked"
    if impl == "ref":
        return flash_attention_ref(q, k, v, causal=causal)
    if impl == "chunked":
        return flash_attention_chunked(q, k, v, causal=causal, block_k=block_k, unroll=unroll)

    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Skv))

    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    qf = qp.transpose(0, 2, 1, 3).reshape(B * Hq, qp.shape[1], D)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * Hkv, kp.shape[1], D)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * Hkv, vp.shape[1], D)

    out = flash_attention_kernel(
        qf, kf, vf,
        group=g, heads_q=Hq, heads_kv=Hkv, scale=scale, causal=causal,
        seq_q=Sq, seq_kv=Skv,
        block_q=block_q, block_k=block_k,
        q_offset=Skv - Sq,  # right-aligned causal (prefill continuation)
        interpret=not _on_tpu() if interpret is None else interpret,
    )
    out = out.reshape(B, Hq, qp.shape[1], D).transpose(0, 2, 1, 3)
    return out[:, :Sq]
