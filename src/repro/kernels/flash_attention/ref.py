"""Pure-jnp oracles for blocked flash attention (GQA, optional causal).

Two forms:
  * ``flash_attention_ref``      — dense (B,H,Sq,Skv) scores; ground truth.
  * ``flash_attention_chunked``  — online-softmax over kv blocks with a
    *static* python loop.  Same math, O(Sq * block) score memory; this is
    what the CPU dry-run lowers so memory_analysis reflects a flash-style
    working set, and the static loop keeps every block's FLOPs visible to
    XLA cost analysis (a lax.scan body would be counted once).
"""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); Hq % Hkv == 0 -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    logits = logits * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned queries
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunk_body(q32, kb, vb, m, l, acc, qpos, kpos, causal, g):
    """One kv-block online-softmax update (fp32 score tile).

    Grouped-query einsums: the kv block is read once, never repeated g-x
    (matching the Pallas kernel's HBM traffic)."""
    B, Sq, Hq, D = q32.shape
    Hkv = kb.shape[2]
    qg = q32.reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bqkhg", qg, kb.astype(jnp.float32))
    s = s.reshape(B, Sq, kb.shape[1], Hq)                  # (B,Sq,bk,Hq)
    mask = kpos[None, :] >= 0                              # kv padding (kpos=-1)
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos[None, :])
    s = jnp.where(mask[None, :, :, None], s, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(s, axis=2))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    p = jnp.exp(s - m_safe[:, :, None, :])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = alpha * l + jnp.sum(p, axis=2)
    pg = p.reshape(B, Sq, kb.shape[1], Hkv, g)
    pv = jnp.einsum("bqkhg,bkhd->bqhgd", pg, vb.astype(jnp.float32))
    acc = acc * alpha[..., None] + pv.reshape(B, Sq, Hq, D)
    return m_new, l, acc


def flash_attention_chunked(q, k, v, *, causal: bool = True,
                            scale: float | None = None, block_k: int = 512,
                            unroll: bool = False):
    """Online-softmax over kv blocks; matches flash_attention_ref.

    Two modes:
      * unroll=False (default): lax.scan over blocks with a remat'd body —
        the backward recomputes each block (flash-style O(block) memory).
      * unroll=True: static python loop with causal block skipping — every
        FLOP visible to XLA cost analysis (dry-run cost extraction).
    """
    import jax

    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_k = min(block_k, Skv)
    q32 = q.astype(jnp.float32) * scale
    q_off = Skv - Sq                                      # right-aligned queries
    qpos = jnp.arange(Sq)[:, None] + q_off

    m0 = jnp.full((B, Sq, Hq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hq, D), jnp.float32)
    n_blocks = -(-Skv // block_k)

    if unroll:
        m, l, acc = m0, l0, acc0
        for bi in range(n_blocks):
            lo = bi * block_k
            hi = min(Skv, lo + block_k)
            if causal and lo > Sq - 1 + q_off:
                continue                                   # block above the diagonal
            kpos = jnp.arange(lo, hi)
            m, l, acc = _chunk_body(q32, k[:, lo:hi], v[:, lo:hi], m, l, acc,
                                    qpos, kpos, causal, g)
    else:
        pad = n_blocks * block_k - Skv
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
        kb = kp.reshape(B, n_blocks, block_k, Hkv, D).swapaxes(0, 1)
        vb = vp.reshape(B, n_blocks, block_k, Hkv, D).swapaxes(0, 1)
        kpos_all = jnp.arange(n_blocks * block_k)
        kpos_all = jnp.where(kpos_all < Skv, kpos_all, -1).reshape(n_blocks, block_k)

        @jax.checkpoint
        def body(carry, xs):
            m, l, acc = carry
            kb_i, vb_i, kpos = xs
            m, l, acc = _chunk_body(q32, kb_i, vb_i, m, l, acc, qpos, kpos, causal, g)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, kpos_all))

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
