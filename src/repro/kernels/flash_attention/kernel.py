"""Pallas TPU flash-attention kernel (blocked online softmax, GQA, causal).

TPU mapping
-----------
Grid ``(B * Hq, num_q_blocks, num_kv_blocks)`` — the trailing grid dim is
innermost and executes *sequentially* on a TPU core, so fp32 VMEM scratch
(running max / denominator / accumulator) persists across the kv sweep for
one (head, q-block). Block shapes keep the MXU fed: q/k tiles are
``(block_q, d_head)`` / ``(block_k, d_head)`` with ``d_head`` a multiple of
128 on the lane axis; the score tile ``(block_q, block_k)`` is fp32 in VMEM.
Causal blocks strictly above the diagonal are skipped with ``pl.when``
(on TPU the skipped iteration costs only grid bookkeeping).

VMEM budget per step (defaults block_q = block_k = 256, D = 128):
q 256x128x4 + k/v 2x256x128x4 + scores 256x256x4 + acc 256x128x4 ~ 0.8 MB,
comfortably inside the ~16 MB/core VMEM envelope, leaving room for
double-buffered HBM->VMEM pipelining of the k/v streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_k: int,
    seq_q: int, seq_kv: int, q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    last_k = pl.num_programs(2) - 1

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks entirely above the diagonal (q global pos < k pos)
    if causal:
        run = (qi * block_q + block_q - 1 + q_offset) >= ki * block_k
    else:
        run = True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (bq, bk)

        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_kv                               # kv padding
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == last_k)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,        # (BH, Sq_pad, D) -- batch*heads flattened
    k: jax.Array,        # (BHkv, Skv_pad, D)
    v: jax.Array,
    *,
    group: int,          # Hq // Hkv
    heads_q: int,
    heads_kv: int,
    scale: float,
    causal: bool,
    seq_q: int,
    seq_kv: int,
    block_q: int = 256,
    block_k: int = 256,
    q_offset: int = 0,   # global position of q[0] (right-aligned causal prefill)
    interpret: bool = True,
) -> jax.Array:
    bh, sq_pad, d = q.shape
    _, skv_pad, _ = k.shape
    block_q = min(block_q, sq_pad)
    block_k = min(block_k, skv_pad)
    grid = (bh, sq_pad // block_q, skv_pad // block_k)

    def q_map(b, qi, ki):
        return (b, qi, 0)

    def kv_map(b, qi, ki):
        # map flattened (batch, q-head) index -> (batch, kv-head) index
        batch = b // heads_q
        h = b % heads_q
        return (batch * heads_kv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, block_q=block_q, block_k=block_k,
        seq_q=seq_q, seq_kv=seq_kv, q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # fp32 VMEM scratch: running max, denominator, output accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
