"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

TPU mapping
-----------
Decode attention is *memory-bound*: the whole KV cache (bytes ~ 2*S*Hkv*D)
streams HBM->VMEM once while compute is tiny, so the kernel's job is to keep
the streams dense and the online-softmax state resident in VMEM.

Grid ``(B, Hkv, num_kv_blocks)`` — kv sweep innermost/sequential. For each
(batch, kv-head) the ``g = Hq/Hkv`` grouped query heads form the MXU row
block: scores tile is ``(g_pad, block_k)`` where ``g_pad`` pads the GQA group
to the 8-row sublane minimum. Running (m, l, acc) live in fp32 VMEM scratch.
Ragged sequence lengths are masked via an iota compare against a per-batch
length scalar (SMEM-resident (1,1) block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, block_k: int,
):
    ki = pl.program_id(2)
    last_k = pl.num_programs(2) - 1

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]

    # Skip blocks entirely past the valid prefix (dense stream otherwise).
    @pl.when(ki * block_k < length)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (g_pad, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (g_pad, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == last_k)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array,         # (B*Hkv, g_pad, D)  grouped query heads
    k: jax.Array,         # (B*Hkv, Smax_pad, D)
    v: jax.Array,
    lengths: jax.Array,   # (B*Hkv,) int32
    *,
    scale: float,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    bh, g_pad, d = q.shape
    _, smax, _ = k.shape
    block_k = min(block_k, smax)
    grid = (bh, 1, smax // block_k)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, qi, ki: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g_pad, d), lambda b, qi, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g_pad, d), lambda b, qi, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
