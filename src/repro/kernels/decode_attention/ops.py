"""jit-ready wrapper for flash-decode; GQA grouping + padding handled here."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,          # (B, Hq, D)
    k_cache: jax.Array,    # (B, Smax, Hkv, D)
    v_cache: jax.Array,
    lengths: jax.Array,    # (B,) int32 valid prefix lengths
    *,
    impl: str = "auto",
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if impl == "auto":
        impl = "kernel" if _on_tpu() else "ref"
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths)

    B, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    g_pad = max(8, g)  # sublane minimum
    scale = 1.0 / (D ** 0.5)

    # (B, Hq, D) -> (B, Hkv, g, D) -> pad group rows -> (B*Hkv, g_pad, D)
    qg = q.reshape(B, Hkv, g, D)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    qf = qg.reshape(B * Hkv, g_pad, D)

    # pad cache seq to block multiple
    pad_s = (-Smax) % block_k if Smax >= block_k else block_k - Smax
    kf = jnp.pad(k_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    vf = jnp.pad(v_cache, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    kf = kf.transpose(0, 2, 1, 3).reshape(B * Hkv, Smax + pad_s, D)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * Hkv, Smax + pad_s, D)

    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)

    out = decode_attention_kernel(
        qf, kf, vf, lens,
        scale=scale, block_k=min(block_k, Smax + pad_s),
        interpret=not _on_tpu() if interpret is None else interpret,
    )
    out = out.reshape(B, Hkv, g_pad, D)[:, :, :g, :]
    return out.reshape(B, Hq, D)
