"""Pure-jnp oracle for single-token KV-cache decode attention (GQA, ragged lengths)."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """q: (B, Hq, D); k/v_cache: (B, Smax, Hkv, D); lengths: (B,) valid prefix.

    Grouped-query einsum — the cache is read ONCE (like the Pallas kernel),
    not materialized g-x via repeat. Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(Smax)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
