"""jit-ready fused RMSNorm wrapper (padding + reshape to row-major slab)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "impl", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,           # (..., d)
    scale: jax.Array,       # (d,)
    *,
    eps: float = 1e-5,
    impl: str = "auto",
    block_rows: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if impl == "auto":
        impl = "kernel" if _on_tpu() else "ref"
    if impl == "ref":
        return rmsnorm_ref(x, scale, eps)

    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)

    d_pad = (-d) % 128
    r_block = min(block_rows, max(8, rows))
    r_pad = (-rows) % r_block
    if d_pad or r_pad:
        xf = jnp.pad(xf, ((0, r_pad), (0, d_pad)))
    sp = jnp.pad(scale, (0, d_pad)) if d_pad else scale

    out = rmsnorm_kernel(
        xf, sp, eps=eps, d_valid=d, block_rows=r_block,
        interpret=not _on_tpu() if interpret is None else interpret,
    )
    return out[:rows, :d].reshape(orig_shape)
