"""Pallas TPU fused RMSNorm kernel.

One HBM round-trip for a (rows, d) slab: the row block is normalized and
scaled entirely in VMEM (vs. the naive lowering's separate square/mean/
rsqrt/mul HBM passes). Block rows chosen so block_rows*d*4B fits VMEM with
double-buffering; d (lane axis) should be a multiple of 128 for dense loads
— padding is handled by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, d_valid: int):
    x = x_ref[...].astype(jnp.float32)                    # (br, d)
    d = x.shape[-1]
    if d_valid != d:  # padded lanes contribute zeros; renormalize the mean
        mean_sq = jnp.sum(jnp.square(x), axis=-1, keepdims=True) / d_valid
    else:
        mean_sq = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(mean_sq + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(
    x: jax.Array,          # (rows_pad, d_pad)
    scale: jax.Array,      # (d_pad,)
    *,
    eps: float,
    d_valid: int,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d_valid=d_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
