"""Heap-vs-vectorized engine parity on randomized traces.

The vectorized engine (``repro.online.vecsim``) must be a drop-in for the
Python event heap on everything it claims to serve: randomized
concurrent-mode traces produce matching per-job records (wait /
turnaround / slice range / backfill flag), matching dispatch/backfill
counts, and a matching placement-ordered timeline.  Decisions are
compared exactly; times to f32 resolution (the device engine carries f32
lanes, the heap is the f64 reference).  Capacity overflow must raise
eagerly — a silently dropped arrival would corrupt every downstream
metric.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from strategies import ZOO, assert_parity, close, make_trace, trace_specs

from repro.online import (
    Arrival, ClusterSimulator, GreedyPackerPolicy, TRACE_FAMILIES,
    TimeSharingPolicy, VectorizedClusterSimulator,
)

# engines cached per configuration: each instance owns its jitted program,
# so reuse across examples keeps the suite's compile count bounded
_ENGINES: dict = {}


def _vec_engine(window=8, backfill=True, capacity=96):
    key = (window, backfill, capacity)
    if key not in _ENGINES:
        _ENGINES[key] = VectorizedClusterSimulator(
            TimeSharingPolicy(), window=window, backfill=backfill,
            capacity=capacity)
    return _ENGINES[key]


def _heap(trace, window=8, backfill=True):
    return ClusterSimulator(TimeSharingPolicy(), window=window,
                            backfill=backfill).run(trace)


# parity helpers shared with test_fleet / test_parity_fuzz
_close = close
_assert_parity = assert_parity


@settings(max_examples=20, deadline=None, derandomize=True)
@given(spec=trace_specs())
def test_parity_randomized_traces(spec):
    trace = make_trace(*spec)
    _assert_parity(_heap(trace), _vec_engine().run(trace))


def test_parity_backfill_heavy():
    """Overloaded fragmented traces exercise the EASY-backfill scan; the
    engines must agree on which groups jump the blocked head."""
    total = 0
    for seed in range(4):
        trace = TRACE_FAMILIES["fragmented"](ZOO, n=40, load=1.6, seed=seed)
        h = _heap(trace)
        _assert_parity(h, _vec_engine().run(trace))
        total += h.backfills
    assert total > 0  # the property must actually be exercised


@pytest.mark.parametrize("window", [2, 4])
def test_parity_small_windows(window):
    trace = TRACE_FAMILIES["mmpp"](ZOO, n=30, load=1.3, seed=7)
    _assert_parity(_heap(trace, window=window),
                   _vec_engine(window=window).run(trace))


def test_parity_backfill_disabled():
    trace = TRACE_FAMILIES["fragmented"](ZOO, n=40, load=1.6, seed=1)
    _assert_parity(_heap(trace, backfill=False),
                   _vec_engine(backfill=False).run(trace))


def test_coincident_arrivals_share_one_dispatch_window():
    trace = [Arrival(t=10.0, binary=f"bin://co{i}", profile=ZOO[i])
             for i in range(4)]
    v = _vec_engine(window=4).run(trace)
    _assert_parity(_heap(trace, window=4), v)
    assert v.dispatches == 1


def test_percentile_fields_populated_by_both_engines():
    """Satellite metric: p50/p99 wait in summary(), equal to numpy's
    percentile of the per-job waits, from either engine."""
    trace = TRACE_FAMILIES["poisson"](ZOO, n=40, load=1.4, seed=9)
    for res in (_heap(trace), _vec_engine().run(trace)):
        s = res.summary()
        waits = [j.wait for j in res.jobs]
        assert _close(s["p50_wait_s"], float(np.percentile(waits, 50)))
        assert _close(s["p99_wait_s"], float(np.percentile(waits, 99)))
        assert s["p50_wait_s"] <= s["p99_wait_s"]


def test_sweep_rows_match_single_trace_runs():
    """Each row of the vmapped sweep equals the corresponding single-trace
    run — vmap must not change the program, only batch it."""
    eng = _vec_engine(capacity=64)
    traces = [TRACE_FAMILIES["poisson"](ZOO, n=24, load=1.2, seed=s)
              for s in range(4)]
    summ = eng.sweep(traces)
    for i, trace in enumerate(traces):
        res = eng.run(trace)
        s = res.summary()
        np.testing.assert_allclose(float(summ.makespan[i]), s["makespan_s"],
                                   rtol=1e-4)
        np.testing.assert_allclose(float(summ.mean_wait[i]), s["mean_wait_s"],
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(summ.p99_wait[i]), s["p99_wait_s"],
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(summ.throughput[i]), s["throughput"],
                                   rtol=1e-4)
        assert int(summ.dispatches[i]) == s["dispatches"]
        assert int(summ.backfills[i]) == res.backfills


def test_sweep_sharded_matches_unsharded():
    """``devices=jax.devices()`` shards the batch via pmap when the CI job
    forces 8 host devices (XLA_FLAGS=--xla_force_host_platform_device_count);
    on a single device it falls back to vmap.  Results must be identical."""
    eng = _vec_engine(capacity=64)
    traces = [TRACE_FAMILIES["diurnal"](ZOO, n=24, load=1.2, seed=s)
              for s in range(8)]
    base = eng.sweep(traces)
    shard = eng.sweep(traces, devices=jax.devices())
    for a, b in zip(base, shard):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_capacity_overflow_raises_eagerly():
    """A trace longer than the event table must raise before the device
    program runs — never silently drop arrivals."""
    trace = TRACE_FAMILIES["poisson"](ZOO, n=20, load=1.0, seed=0)
    eng = VectorizedClusterSimulator(TimeSharingPolicy(), capacity=16)
    with pytest.raises(ValueError, match="capacity"):
        eng.run(trace)
    with pytest.raises(ValueError, match="capacity"):
        eng.sweep([trace])


def test_error_lanes_raise():
    check = VectorizedClusterSimulator._check_err
    with pytest.raises(RuntimeError, match="ready ring"):
        check(1)
    with pytest.raises(RuntimeError, match="budget"):
        check(2)
    check(0)  # clean run is silent


def test_unsupported_policy_rejected():
    with pytest.raises(ValueError, match="TimeSharingPolicy or "
                                         "RLDispatchPolicy"):
        VectorizedClusterSimulator(GreedyPackerPolicy())


def test_empty_trace_and_empty_sweep():
    res = _vec_engine().run([])
    assert res.jobs == [] and res.makespan == 0.0
    with pytest.raises(ValueError, match="empty"):
        _vec_engine().sweep([])
