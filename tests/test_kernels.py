"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
shape/dtype sweeps via hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import (
    flash_attention,
    flash_attention_chunked,
    flash_attention_ref,
)
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref


def _qkv(key, B, Sq, Skv, Hq, Hkv, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@given(
    B=st.integers(1, 3),
    Sq=st.integers(1, 70),
    extra_kv=st.integers(0, 40),
    Hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25)
def test_flash_kernel_matches_ref(B, Sq, extra_kv, Hkv, group, D, causal, seed):
    Skv = Sq + extra_kv
    q, k, v = _qkv(jax.random.PRNGKey(seed), B, Sq, Skv, Hkv * group, Hkv, D, jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, impl="kernel",
                          block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


@given(
    Sq=st.integers(1, 80),
    Hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 3]),
    causal=st.booleans(),
    block=st.sampled_from([16, 32, 64]),
    unroll=st.booleans(),
    seed=st.integers(0, 2**30),
)
def test_flash_chunked_matches_ref(Sq, Hkv, group, causal, block, unroll, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 2, Sq, Sq, Hkv * group, Hkv, 16, jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal)
    out = flash_attention_chunked(q, k, v, causal=causal, block_k=block, unroll=unroll)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_flash_kernel_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 64, 4, 2, 32, jnp.bfloat16)
    ref = flash_attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, impl="kernel",
                          block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_grad_path():
    """The chunked (scan+remat) form must be differentiable."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 32, 32, 2, 1, 8, jnp.float32)

    def loss(q, k, v):
        return flash_attention_chunked(q, k, v, causal=True, block_k=16).sum()

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert all(bool(jnp.isfinite(x).all()) for x in g)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@given(
    B=st.integers(1, 4),
    Smax=st.integers(4, 300),
    Hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 8]),
    D=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25)
def test_decode_kernel_matches_ref(B, Smax, Hkv, group, D, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    Hq = Hkv * group
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, D), jnp.float32)
    lens = jax.random.randint(ks[3], (B,), 1, Smax + 1)
    ref = decode_attention_ref(q, kc, vc, lens)
    out = decode_attention(q, kc, vc, lens, impl="kernel", block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_decode_masks_beyond_length():
    """Entries past `lengths` must not affect the output."""
    B, Smax, H, D = 2, 64, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, Smax, H, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, Smax, H, D))
    lens = jnp.array([10, 20])
    out1 = decode_attention(q, kc, vc, lens, impl="kernel", block_k=16)
    kc2 = kc.at[:, 30:].set(99.0)
    vc2 = vc.at[:, 30:].set(-99.0)
    out2 = decode_attention(q, kc2, vc2, lens, impl="kernel", block_k=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@given(
    rows=st.integers(1, 40),
    d=st.integers(3, 300),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25)
def test_rmsnorm_kernel_matches_ref(rows, d, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d), jnp.dtype(dtype))
    s = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,), jnp.dtype(dtype))
    ref = rmsnorm_ref(x, s, 1e-5)
    out = rmsnorm(x, s, eps=1e-5, impl="kernel", block_rows=8)
    tol = 1e-5 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_rmsnorm_3d_shape():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 100))
    s = jnp.ones((100,))
    out = rmsnorm(x, s, impl="kernel")
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, s)), atol=1e-5)
