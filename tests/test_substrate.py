"""Substrate tests: optimizer, data pipeline, checkpointing, elastic, multitenant."""
import os

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, restore, save
from repro.data import DataPipeline
from repro.optim import OptConfig, adamw_update, init_opt_state, lr_at


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=1, decay_steps=1000, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_engages():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
    _, _, m = adamw_update(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (1, 10, 50, 100, 1000)]
    assert lrs[0] < lrs[1]                       # warmup
    assert lrs[1] >= lrs[2] >= lrs[3]            # decay
    np.testing.assert_allclose(lrs[4], 1e-4, rtol=1e-2)  # floor


def test_bias_not_decayed():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.5, warmup_steps=1)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(params, zeros, state, cfg)
    assert float(p2["w"][0, 0]) < 1.0            # decayed
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)  # not decayed


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@given(step=st.integers(0, 1000), seed=st.integers(0, 100))
@settings(max_examples=10)
def test_pipeline_deterministic(step, seed):
    p = DataPipeline(100, 16, 8, seed=seed)
    b1, b2 = p.batch(step), p.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_shards_compose_to_global():
    p = DataPipeline(1000, 8, 10, seed=3)
    full = p.batch(5)
    parts = [p.batch(5, *p.shard_bounds(i, 3)) for i in range(3)]
    np.testing.assert_array_equal(np.concatenate([x["tokens"] for x in parts]), full["tokens"])


def test_pipeline_labels_shifted():
    p = DataPipeline(97, 12, 4, seed=1)
    b = p.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_is_learnable():
    """Markov mode: next token is a deterministic function of current."""
    p = DataPipeline(50, 32, 4, seed=2, mode="markov")
    b = p.batch(7)
    toks, labs = b["tokens"], b["labels"]
    # for any repeated token within a row, the successor must repeat too
    for r in range(4):
        seen = {}
        for t in range(32):
            cur = int(toks[r, t])
            if cur in seen:
                assert seen[cur] == int(labs[r, t])
            seen[cur] = int(labs[r, t])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.float32(1.5)}}
    save(str(tmp_path), 3, tree, extra={"data_step": 7})
    got, extra, step = restore(str(tmp_path))
    assert step == 3 and extra["data_step"] == 7
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_allclose(got["b"]["c"], 1.5)


def test_checkpoint_ignores_uncommitted(tmp_path):
    save(str(tmp_path), 1, {"x": np.ones(2)})
    # fake a torn checkpoint: directory without .done marker
    os.makedirs(tmp_path / "step_9")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_prunes_old(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, {"x": np.full(2, s)}, keep_last=2)
    from repro.checkpoint.checkpoint import committed_steps

    assert committed_steps(str(tmp_path)) == [4, 5]


# ---------------------------------------------------------------------------
# elastic runtime
# ---------------------------------------------------------------------------

def test_surviving_mesh_rectangular_power_of_two():
    from repro.runtime.elastic import surviving_mesh

    devs = np.array(jax.devices() * 8).reshape(8, 1)  # fake 8x1 mesh rows
    from jax.sharding import Mesh

    mesh = Mesh(devs, ("data", "model"))
    m2 = surviving_mesh(mesh, failed_rows=[3])
    assert np.asarray(m2.devices).shape == (4, 1)  # 7 survivors -> 4 (pow2)


def test_rebalance_bounds_cover_batch():
    from repro.runtime.elastic import rebalance_bounds

    for n_rows in (3, 4, 7):
        spans = [rebalance_bounds(26, n_rows, r) for r in range(n_rows)]
        assert spans[0][0] == 0 and spans[-1][1] == 26
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c


def test_elastic_trainer_recovers_from_failure(tmp_path):
    from jax.sharding import Mesh

    from repro.runtime.elastic import ElasticTrainer, FailureEvent

    devs = np.array(jax.devices() * 4).reshape(4, 1)
    mesh = Mesh(devs, ("data", "model"))

    def make_step(mesh):
        @jax.jit
        def step(state, batch):
            return {"w": state["w"] + batch.mean(), "n": state["n"] + 1}
        return step

    def init_state(mesh):
        return {"w": jnp.zeros(()), "n": jnp.zeros((), jnp.int32)}

    def batch_fn(step, mesh):
        return jnp.ones((4,))

    tr = ElasticTrainer(make_step, init_state, str(tmp_path), ckpt_every=5)
    state, final_mesh = tr.run(mesh, 20, batch_fn,
                               failures=[FailureEvent(step=12, failed_rows=[1])])
    assert int(state["n"]) == 20
    assert np.asarray(final_mesh.devices).shape[0] == 2  # 3 survivors -> 2
    assert any(e.startswith("shrunk") for e in tr.log)
    assert any(e.startswith("ckpt") for e in tr.log)


# ---------------------------------------------------------------------------
# multitenant executor
# ---------------------------------------------------------------------------

def test_quantum_executor_completes_all():
    from repro.runtime.multitenant import QuantumExecutor, Tenant

    def mk(name, share):
        @jax.jit
        def step(s):
            return s + 1
        return Tenant(name, step, jnp.zeros(()), share)

    tenants = [mk("a", 0.75), mk("b", 0.25)]
    ex = QuantumExecutor(tenants, {"a": 30, "b": 10})
    finish = ex.run()
    assert set(finish) == {"a", "b"}
    assert tenants[0].steps_done >= 30 and tenants[1].steps_done >= 10


def test_fused_corunner_shares_map_to_quanta():
    from repro.runtime.multitenant import FusedCoRunner, Tenant

    def mk(name, share):
        @jax.jit
        def step(s):
            return s + 1
        return Tenant(name, step, jnp.zeros(()), share)

    runner = FusedCoRunner([mk("big", 0.75), mk("small", 0.25)], {"big": 24, "small": 8})
    assert runner.quanta[0] > runner.quanta[1]
    finish = runner.run()
    assert set(finish) == {"big", "small"}
