"""Scheduler + baseline policy invariants."""
import numpy as np
import pytest

from repro.core import (
    DQNAgent,
    DQNConfig,
    EnvConfig,
    POLICIES,
    RLScheduler,
    make_zoo,
    summarize,
    validate_schedule,
)
from repro.core.env import CoScheduleEnv
from repro.core.profiles import ProfileRepository
from repro.core.workloads import make_queue

ZOO = make_zoo(dryrun_dir=None)
RNG = np.random.default_rng(0)
QUEUE = make_queue(ZOO, "balanced", 6, RNG)


def _fresh_agent(env_cfg):
    env = CoScheduleEnv(env_cfg)
    return DQNAgent(env.state_dim, env.n_actions, DQNConfig(), seed=0)


def test_time_sharing_is_identity():
    sched = POLICIES["time_sharing"](QUEUE, 4)
    s = summarize(sched)
    assert abs(s["throughput"] - 1.0) < 1e-9
    assert abs(s["avg_slowdown"] - 1.0) < 1e-9
    assert abs(s["fairness"] - 1.0) < 1e-9


@pytest.mark.parametrize("policy", ["mig_only", "mps_only", "mig_mps_default", "oracle"])
def test_baselines_valid_and_no_worse_than_time_sharing(policy):
    sched = POLICIES[policy](QUEUE, 4)
    validate_schedule(QUEUE, sched, 4)
    assert summarize(sched)["throughput"] >= 1.0 - 1e-9


def test_oracle_dominates_restricted_policies():
    tp = {p: summarize(POLICIES[p](QUEUE, 4))["throughput"]
          for p in ("mig_only", "mps_only", "mig_mps_default", "oracle")}
    for p in ("mig_only", "mps_only", "mig_mps_default"):
        assert tp["oracle"] >= tp[p] - 1e-9, tp


def test_untrained_rl_scheduler_still_valid():
    """Even an untrained agent must emit constraint-respecting schedules
    (the constraint guard enforces CoRunTime <= SoloRunTime)."""
    env_cfg = EnvConfig(window=6, c_max=4)
    sched = RLScheduler(_fresh_agent(env_cfg), env_cfg).schedule(QUEUE)
    validate_schedule(QUEUE, sched, 4)


def test_scheduler_online_protocol_unprofiled_jobs_run_solo():
    env_cfg = EnvConfig(window=6, c_max=4)
    repo = ProfileRepository()
    repo.insert("/bin/jobA", QUEUE[0])
    repo.insert("/bin/jobB", QUEUE[1])
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg, repo)
    subs = [("/bin/jobA", None), ("/bin/jobB", None), ("/bin/new", QUEUE[2])]
    sched = sched_obj.schedule_submissions(subs)
    # the unknown job ran solo and entered the repository
    assert sched_obj.stats.unprofiled_jobs == 1
    assert repo.lookup("/bin/new") is not None
    names = [j.name for g in sched.groups for j in g]
    assert QUEUE[2].name in names


def test_best_for_group_defaults_to_full_permutation_sweep():
    """The oracle's per-group search must cover all C! slot orderings —
    a truncated sweep (the old max_perms=8) is not an upper bound."""
    import itertools

    from repro.core.baselines import _best_for_group
    from repro.core.partition import enumerate_partitions
    from repro.core.perfmodel import corun_time

    group = [ZOO[i] for i in (0, 12, 20, 25)]     # mixed CI/MI/US 4-group
    parts = [p for p in enumerate_partitions(4) if p.arity == 4]
    t_default, p_default, _ = _best_for_group(group, parts)
    brute = min(
        corun_time([group[i] for i in perm], p)
        for p in parts
        for perm in itertools.permutations(range(4))
    )
    assert t_default == brute
    # a truncated sweep can only be worse or equal
    t_trunc, _, _ = _best_for_group(group, parts, max_perms=1)
    assert t_default <= t_trunc


def test_window_scaling_monotone_for_oracle():
    """Paper Fig. 9: more window -> no less throughput (oracle)."""
    rng = np.random.default_rng(1)
    q4 = make_queue(ZOO, "balanced", 4, rng)
    q8 = q4 + make_queue(ZOO, "balanced", 4, rng)
    tp4 = summarize(POLICIES["oracle"](q4, 4))["throughput"]
    tp8 = summarize(POLICIES["oracle"](q8, 4))["throughput"]
    assert tp8 >= tp4 * 0.9  # larger window has at least comparable headroom
