"""Scheduler + baseline policy invariants."""
import numpy as np
import pytest

from repro.core import (
    DQNAgent,
    DQNConfig,
    EnvConfig,
    POLICIES,
    RLScheduler,
    make_zoo,
    summarize,
    validate_schedule,
)
from repro.core.env import CoScheduleEnv
from repro.core.profiles import ProfileRepository
from repro.core.workloads import make_queue

ZOO = make_zoo(dryrun_dir=None)
RNG = np.random.default_rng(0)
QUEUE = make_queue(ZOO, "balanced", 6, RNG)


def _fresh_agent(env_cfg):
    env = CoScheduleEnv(env_cfg)
    return DQNAgent(env.state_dim, env.n_actions, DQNConfig(), seed=0)


def test_time_sharing_is_identity():
    sched = POLICIES["time_sharing"](QUEUE, 4)
    s = summarize(sched)
    assert abs(s["throughput"] - 1.0) < 1e-9
    assert abs(s["avg_slowdown"] - 1.0) < 1e-9
    assert abs(s["fairness"] - 1.0) < 1e-9


@pytest.mark.parametrize("policy", ["mig_only", "mps_only", "mig_mps_default", "oracle"])
def test_baselines_valid_and_no_worse_than_time_sharing(policy):
    sched = POLICIES[policy](QUEUE, 4)
    validate_schedule(QUEUE, sched, 4)
    assert summarize(sched)["throughput"] >= 1.0 - 1e-9


def test_oracle_dominates_restricted_policies():
    tp = {p: summarize(POLICIES[p](QUEUE, 4))["throughput"]
          for p in ("mig_only", "mps_only", "mig_mps_default", "oracle")}
    for p in ("mig_only", "mps_only", "mig_mps_default"):
        assert tp["oracle"] >= tp[p] - 1e-9, tp


def test_untrained_rl_scheduler_still_valid():
    """Even an untrained agent must emit constraint-respecting schedules
    (the constraint guard enforces CoRunTime <= SoloRunTime)."""
    env_cfg = EnvConfig(window=6, c_max=4)
    sched = RLScheduler(_fresh_agent(env_cfg), env_cfg).schedule(QUEUE)
    validate_schedule(QUEUE, sched, 4)


def test_scheduler_online_protocol_unprofiled_jobs_run_solo():
    env_cfg = EnvConfig(window=6, c_max=4)
    repo = ProfileRepository()
    repo.insert("/bin/jobA", QUEUE[0])
    repo.insert("/bin/jobB", QUEUE[1])
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg, repo)
    subs = [("/bin/jobA", None), ("/bin/jobB", None), ("/bin/new", QUEUE[2])]
    sched = sched_obj.schedule_submissions(subs)
    # the unknown job ran solo and entered the repository
    assert sched_obj.stats.unprofiled_jobs == 1
    assert repo.lookup("/bin/new") is not None
    names = [j.name for g in sched.groups for j in g]
    assert QUEUE[2].name in names


def test_schedule_submissions_fresh_none_is_skipped_but_counted():
    """An unprofiled job with no fresh measurement cannot run (nothing to
    schedule) — it is counted, not silently dropped into the schedule."""
    env_cfg = EnvConfig(window=6, c_max=4)
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg)
    sched = sched_obj.schedule_submissions([("/bin/ghost", None)])
    assert sched.groups == []
    assert sched_obj.stats.unprofiled_jobs == 1
    assert len(sched_obj.repository) == 0


def test_schedule_submissions_unprofiled_runs_solo_full_pod():
    env_cfg = EnvConfig(window=6, c_max=4)
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg)
    sched = sched_obj.schedule_submissions([("/bin/new", QUEUE[0])])
    assert len(sched.groups) == 1 and len(sched.groups[0]) == 1
    p = sched.partitions[0]
    assert p.arity == 1 and p.slices[0].units == 8     # full pod, solo
    assert sched_obj.repository.lookup("/bin/new") is QUEUE[0]


def test_schedule_submissions_chunks_oversized_windows():
    """More profiled jobs than W run as successive RL windows, all covered."""
    env_cfg = EnvConfig(window=4, c_max=3)
    repo = ProfileRepository()
    subs = []
    for i in range(10):
        repo.insert(f"/bin/j{i}", QUEUE[i % len(QUEUE)])
        subs.append((f"/bin/j{i}", None))
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg, repo)
    sched = sched_obj.schedule_submissions(subs)
    assert sched_obj.stats.windows == 3                # ceil(10 / 4)
    assert sched_obj.stats.unprofiled_jobs == 0
    assert sum(len(g) for g in sched.groups) == 10
    for g, p in zip(sched.groups, sched.partitions):
        assert len(g) == p.arity <= 3


def test_scheduler_shares_caller_repository_even_when_empty():
    """Regression: an empty repository is falsy — `or` used to replace it,
    severing the caller's handle to the shared profile store."""
    env_cfg = EnvConfig(window=6, c_max=4)
    repo = ProfileRepository()
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg, repo)
    assert sched_obj.repository is repo
    sched_obj.schedule_submissions([("/bin/a", QUEUE[0])])
    assert "/bin/a" in repo


def test_enforce_constraints_counts_fallback_groups():
    """A group whose co-run loses to time sharing is split back into solo
    runs and tallied in stats.fallback_groups."""
    from repro.core.partition import enumerate_partitions
    from repro.core.perfmodel import corun_time, solo_run_time
    from repro.core.problem import Schedule

    bad = None
    for p in (q for q in enumerate_partitions(4) if q.arity == 2):
        for i in range(len(ZOO)):
            for j in range(i, len(ZOO)):
                g = [ZOO[i], ZOO[j]]
                if corun_time(g, p) > solo_run_time(g):
                    bad = (g, p)
                    break
            if bad:
                break
        if bad:
            break
    assert bad is not None, "zoo has no losing co-run pair to test with"
    env_cfg = EnvConfig(window=6, c_max=4)
    sched_obj = RLScheduler(_fresh_agent(env_cfg), env_cfg)
    raw = Schedule()
    raw.add(*bad)
    out = sched_obj._enforce_constraints(raw)
    assert sched_obj.stats.fallback_groups == 1
    assert [len(g) for g in out.groups] == [1, 1]
    assert all(p.arity == 1 for p in out.partitions)


def test_best_for_group_defaults_to_full_permutation_sweep():
    """The oracle's per-group search must cover all C! slot orderings —
    a truncated sweep (the old max_perms=8) is not an upper bound."""
    import itertools

    from repro.core.baselines import _best_for_group
    from repro.core.partition import enumerate_partitions
    from repro.core.perfmodel import corun_time

    group = [ZOO[i] for i in (0, 12, 20, 25)]     # mixed CI/MI/US 4-group
    parts = [p for p in enumerate_partitions(4) if p.arity == 4]
    t_default, p_default, _ = _best_for_group(group, parts)
    brute = min(
        corun_time([group[i] for i in perm], p)
        for p in parts
        for perm in itertools.permutations(range(4))
    )
    assert t_default == brute
    # a truncated sweep can only be worse or equal
    t_trunc, _, _ = _best_for_group(group, parts, max_perms=1)
    assert t_default <= t_trunc


def test_window_scaling_monotone_for_oracle():
    """Paper Fig. 9: more window -> no less throughput (oracle)."""
    rng = np.random.default_rng(1)
    q4 = make_queue(ZOO, "balanced", 4, rng)
    q8 = q4 + make_queue(ZOO, "balanced", 4, rng)
    tp4 = summarize(POLICIES["oracle"](q4, 4))["throughput"]
    tp8 = summarize(POLICIES["oracle"](q8, 4))["throughput"]
    assert tp8 >= tp4 * 0.9  # larger window has at least comparable headroom
