"""Partition-space invariants (the Table VII analogue)."""
import pytest

from repro.core.partition import (
    CHIPS_PER_UNIT,
    N_UNITS,
    Slice,
    enumerate_partitions,
    partitions_by_arity,
)


def test_table_covers_all_arities():
    by = partitions_by_arity(4)
    assert set(by) == {1, 2, 3, 4}
    assert all(len(v) >= 1 for v in by.values())


def test_partitions_respect_cmax():
    for c_max in (1, 2, 3, 4):
        assert all(p.arity <= c_max for p in enumerate_partitions(c_max))


def test_slice_invariants():
    for p in enumerate_partitions(4):
        assert p.total_units <= N_UNITS, p.label
        for s in p.slices:
            assert s.units in (1, 2, 4, 8)
            assert sum(s.shares) <= 1.0 + 1e-9, p.label
            assert s.chips == s.units * CHIPS_PER_UNIT
        assert len(p.slots) == p.arity


def test_torus_factor_only_full_pod():
    full = Slice(8, (1.0,))
    half = Slice(4, (1.0,))
    assert full.torus_factor == 1.0
    assert half.torus_factor == 0.5


def test_styles_partition_the_table():
    styles = {p.style for p in enumerate_partitions(4)}
    assert styles == {"solo", "mps", "mig", "hier"}


def test_action_space_size_matches_paper_scale():
    """W + N_p should land near the paper's A = 29 output head."""
    n_p = len(enumerate_partitions(4))
    assert 15 <= n_p <= 25, n_p          # paper: 17
    assert 25 <= 12 + n_p <= 37          # paper: 29


def test_invalid_slices_rejected():
    with pytest.raises(AssertionError):
        Slice(3, (1.0,))                  # non-power-of-two width
    with pytest.raises(AssertionError):
        Slice(4, (0.7, 0.6))              # shares exceed 1
