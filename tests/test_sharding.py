"""Sharding-spec rules + roofline parsing unit tests (no multi-device needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.sharding import build_cache_specs, build_param_specs
from repro.sharding.specs import _spec_for_path


def test_param_spec_rules():
    assert _spec_for_path("layers/attn/wq", 3, scanned=True) == (None, "fsdp", "tp")
    assert _spec_for_path("layers/attn/wo", 3, scanned=True) == (None, "tp", "fsdp")
    assert _spec_for_path("emb", 2, scanned=False) == ("vocab_tp", None)
    assert _spec_for_path("lm_head", 2, scanned=False) == (None, "vocab_tp")
    assert _spec_for_path("layers/moe/experts_wg", 4, scanned=True) == (None, "ep", "fsdp_e", None)
    assert _spec_for_path("layers/ln1", 2, scanned=True) == (None, None)
    # GQA replicated-kv rule
    assert _spec_for_path("layers/attn/wk", 3, True, replicate_kv=True) == (None, "fsdp", None)
    assert _spec_for_path("layers/attn/wk", 3, True, replicate_kv=False) == (None, "fsdp", "tp")


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-moe-16b", "jamba-v0.1-52b",
                                  "xlstm-125m", "seamless-m4t-large-v2"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_smoke_config(arch)
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = build_param_specs(params, replicate_kv=True)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == p.ndim, (p.shape, s)


def test_cache_specs_structure():
    from repro.models.model import init_cache

    cfg = get_smoke_config("jamba-v0.1-52b")
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda p: init_cache(p, cfg, 2, 16), params)
    specs = build_cache_specs(cache, replicate_kv=True)
    flat_c = jax.tree.leaves(cache)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_c) == len(flat_s)
    for c, s in zip(flat_c, flat_s):
        assert len(s) == c.ndim


def test_constrain_noop_without_mesh():
    from repro.sharding import constrain

    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(constrain(x, ("act_batch", None))), 1.0)


def test_logical_spec_drops_missing_axes():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.sharding import logical_spec

    mesh = Mesh(np.array(jax.devices())[:1].reshape(1, 1), ("data", "model"))
    # "pod" axis absent on the single-pod mesh -> dropped from the tuple rule
    spec = logical_spec(("act_batch", None), mesh)
    assert spec == P(("data",), None)


# ---------------------------------------------------------------------------
# roofline parsing (pure functions over HLO text)
# ---------------------------------------------------------------------------

HLO_SNIPPET = """
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[16,4096]{1,0} all-gather(%p0), replica_groups={}
  %ar = (f32[8,8]{1,0}, f32[4]{0}) all-reduce(%x, %y), to_apply=%add
  %a2a = bf16[2,64]{1,0} all-to-all(%z), dimensions={0}
  %d = f32[8,8]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}
"""


def test_parse_collectives():
    from repro.launch.roofline import parse_collectives

    stats = parse_collectives(HLO_SNIPPET)
    ag = 16 * 4096 * 2
    ar = 8 * 8 * 4 + 4 * 4
    a2a = 2 * 64 * 2
    assert stats.bytes_raw == ag + ar + a2a
    assert stats.bytes_weighted == ag + 2 * ar + a2a
    assert stats.count == 3
    assert set(stats.by_op) == {"all-gather", "all-reduce", "all-to-all"}


def test_roofline_terms_dominance():
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms

    r = roofline_terms(PEAK_FLOPS, HBM_BW * 0.5, ICI_BW * 0.1)
    assert r["dominant"] == "compute"
    assert abs(r["compute_term_s"] - 1.0) < 1e-9
    r2 = roofline_terms(PEAK_FLOPS * 0.01, HBM_BW, ICI_BW * 2)
    assert r2["dominant"] == "collective"
    assert r2["step_time_lb_s"] == r2["collective_term_s"]


def test_fusion_adjusted_bytes_counts_major_ops_only():
    from repro.launch.roofline import fusion_adjusted_bytes

    hlo = """
  %p0 = f32[4,4]{1,0} parameter(0)
  %c = f32[4,4]{1,0} convert(%p0)
  %e = f32[4,4]{1,0} add(%c, %c)
  %d = f32[4,2]{1,0} dot(%e, %e), lhs_contracting_dims={1}
"""
    # only the dot counts: operands (e twice: 64+64) + result 32
    assert fusion_adjusted_bytes(hlo) == 64 + 64 + 32


def test_model_flops_scales_with_tokens():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import model_flops

    cfg = get_config("llama3-8b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    # 6*N*D lower bound
    assert f_train > 6 * 6e9 * SHAPES["train_4k"].tokens
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train / 100
