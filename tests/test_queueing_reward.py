"""Property fuzz: in-graph queueing-reward accumulation vs heap totals.

The training engine (``vecsim._build_run_rl(train=True)``) attributes
every placed entry's member waits and turnarounds to the bucket of the
window that *formed* it (``TrainRollout.w_wait`` / ``w_turn``).  Every
arrival is served by exactly one entry and every entry is placed exactly
once, so the buckets must partition the serving outcome: summed over
windows they equal the heap ``SimResult``'s total wait and turnaround —
the invariant that makes the per-decision reward the *real* queueing
outcome rather than a shaped estimate.  This suite fuzzes that identity
across randomized traces x engine knobs x fleet topologies (split with
the same quiescent-view hash routing ``VectorizedFleetSimulator`` uses).

With ``eps=0`` the training engine's decisions are the serving engine's
bit-for-bit (decision-level heap parity, ``test_parity_fuzz``), so the
only drift left is the engine's float32 clock vs the heap's float64;
totals are compared as per-job means under ``strategies.close``'s
tolerance.  A failing example's report names the drawn spec and the RNG
seed pair that regenerates it (see ``_hypothesis_compat``).
"""
import numpy as np

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
except ImportError:
    from _hypothesis_compat import given, settings

from strategies import close, engine_knobs, fleet_topologies, make_trace, \
    trace_specs

from repro.core.agent import DQNAgent
from repro.core.env import CoScheduleEnv, EnvConfig
from repro.core.partition import N_UNITS
from repro.online import (
    ClusterSimulator, FleetView, PodView, SimConfig, make_rollout_collector,
    make_router,
)
from repro.online.policies import RLDispatchPolicy
from repro.online.vecsim import build_rl_job_table, compile_trace

ENV_CFG = EnvConfig()
_ENV = CoScheduleEnv(ENV_CFG)
_AGENT = DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0)

_COLLECTORS: dict = {}


def _collector(window=8, backfill=True, capacity=96):
    key = (window, backfill, capacity)
    if key not in _COLLECTORS:
        _COLLECTORS[key] = make_rollout_collector(
            ENV_CFG, window=window, backfill=backfill, capacity=capacity)
    return _COLLECTORS[key]


def _rl_policy():
    """Fresh policy per heap run (the profile repository fills as jobs
    run; reuse would leak first-sight state across examples)."""
    return RLDispatchPolicy(DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0),
                            ENV_CFG)


def _collect(traces, window=8, backfill=True, capacity=96,
             widths=None, eps=0.0, seed=0):
    """Roll ``traces`` through the training engine against one shared job
    table; returns (summary, rollout) with leading trace axis."""
    names: dict[str, int] = {}
    jobs: list = []
    compiled = [compile_trace(t, capacity, names, jobs)[0] for t in traces]
    batch = jax.tree.map(lambda *xs: jnp.stack(xs), *compiled)
    rjt = build_rl_job_table(jobs)
    if widths is None:
        widths = [N_UNITS] * len(traces)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(traces))
    summ, roll = _collector(window, backfill, capacity)(
        batch, rjt, _AGENT.params, keys, jnp.float32(eps),
        jnp.asarray(np.array(widths, np.int32)))
    assert int(np.max(np.asarray(summ.err))) == 0
    return summ, roll


def _bucket_totals(roll, lane=0):
    """f64 sums of one lane's in-graph per-window reward buckets."""
    return (float(np.asarray(roll.w_wait[lane], np.float64).sum()),
            float(np.asarray(roll.w_turn[lane], np.float64).sum()))


def _heap_totals(res):
    return (sum(r.wait for r in res.jobs),
            sum(r.turnaround for r in res.jobs))


def _assert_totals(vec_tot, heap_tot, n):
    """Totals compared as per-job means: decisions are exact, so only the
    f32 clock separates the accumulators."""
    for a, b in zip(vec_tot, heap_tot):
        assert close(a / max(1, n), b / max(1, n)), (
            f"bucket total {a} vs heap {b} over {n} jobs")


# --------------------------------------------------------- single-pod totals

@settings(max_examples=8, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=40))
def test_reward_buckets_sum_to_heap_totals(spec):
    trace = make_trace(*spec)
    _, roll = _collect([trace])
    h = ClusterSimulator(_rl_policy(), window=8).run(trace)
    _assert_totals(_bucket_totals(roll), _heap_totals(h), len(h.jobs))


@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=30), knobs=engine_knobs())
def test_reward_buckets_sum_across_engine_knobs(spec, knobs):
    window, backfill = knobs
    trace = make_trace(*spec)
    _, roll = _collect([trace], window=window, backfill=backfill)
    h = ClusterSimulator(_rl_policy(), window=window,
                         backfill=backfill).run(trace)
    _assert_totals(_bucket_totals(roll), _heap_totals(h), len(h.jobs))


@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=40))
def test_eps_zero_reproduces_serving_decisions(spec):
    """ε=0 must reproduce the serving engine's plan: same windows, same
    makespan/backfills, and every logged action inside a formed window is
    a decision the serving heap also took (summary-level check)."""
    trace = make_trace(*spec)
    summ, roll = _collect([trace])
    h = ClusterSimulator(_rl_policy(), window=8).run(trace)
    assert int(summ.dispatches[0]) == h.dispatches
    assert int(summ.backfills[0]) == h.backfills
    assert close(float(summ.makespan[0]), h.makespan)
    assert close(float(summ.p99_wait[0]), h.p99_wait)


# -------------------------------------------------------------- fleet totals

@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=40), pods=fleet_topologies(max_pods=3))
def test_reward_buckets_sum_to_heap_fleet_totals(spec, pods):
    """Hash-routed fleets: split the trace with the same quiescent-view
    router the vectorized fleet uses, roll every pod lane through the
    training engine with its pod width, and sum buckets across lanes."""
    trace = make_trace(*spec, capacity=sum(pods) / N_UNITS)
    cfg = SimConfig(pods=pods, window=8, router="hash")
    h = ClusterSimulator(_rl_policy(), cfg).run(trace)

    router = make_router(cfg.router, cfg.router_seed)
    view = FleetView(pods=tuple(
        PodView(idx=i, width=w, free=(True,) * w, pending=0, ready=0,
                queue_units=0, busy_units=0)
        for i, w in enumerate(cfg.pods)))
    sub: list[list] = [[] for _ in cfg.pods]
    for a in sorted(trace, key=lambda a: a.t):
        sub[router.route(a, view)].append(a)

    lanes = [(s, w) for s, w in zip(sub, cfg.pods) if s]
    _, roll = _collect([s for s, _ in lanes], widths=[w for _, w in lanes])
    wait = sum(_bucket_totals(roll, lane=i)[0] for i in range(len(lanes)))
    turn = sum(_bucket_totals(roll, lane=i)[1] for i in range(len(lanes)))
    _assert_totals((wait, turn), _heap_totals(h), len(h.jobs))


# ----------------------------------------------- exploration keeps the books

@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=30))
def test_reward_buckets_consistent_under_exploration(spec):
    """ε>0 changes the plan, not the accounting: buckets must still sum
    to the *training engine's own* record totals (its SweepSummary means),
    and the run must stay error-free and key-deterministic."""
    trace = make_trace(*spec)
    summ, roll = _collect([trace], eps=0.5, seed=11)
    n = len(trace)
    wait, turn = _bucket_totals(roll)
    assert close(wait / n, float(summ.mean_wait[0]))
    assert close(turn / n, float(summ.mean_turnaround[0]))
    summ2, roll2 = _collect([trace], eps=0.5, seed=11)
    assert np.array_equal(np.asarray(roll.act), np.asarray(roll2.act))


# ------------------------------------------------------------ log structure

def test_rollout_logs_chain_into_transitions():
    """The logged seam is stitchable: valid steps exist exactly for formed
    windows with profiled submissions, every valid step's mask admits its
    logged action, and windows beyond ``dispatches`` are empty."""
    trace = make_trace("poisson", 30, 3, 1.3)
    summ, roll = _collect([trace])
    n_win = int(summ.dispatches[0])
    valid = np.asarray(roll.valid[0])
    act = np.asarray(roll.act[0])
    mask = np.asarray(roll.mask[0])
    assert valid.shape[0] >= n_win and valid[n_win:].sum() == 0
    assert valid[:n_win].any()
    idx = np.argwhere(valid[:n_win])
    assert len(idx) > 0
    for w, t in idx:
        assert mask[w, t, act[w, t]], (w, t, act[w, t])
    # buckets of formed windows only
    assert np.asarray(roll.w_wait[0])[n_win:].sum() == 0.0
