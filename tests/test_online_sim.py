"""Online cluster simulator: traces, event loop, policies, re-training.

Determinism contract: a trace is fully determined by its seed and the
simulator adds no randomness of its own, so (trace, policy) pairs replay
bit-identically.  Accounting contract: every arrival is dispatched exactly
once, time sharing's busy time equals the summed solo work, and any policy
honoring the constraint-1 guard retires the trace with no more pod-busy
time than time sharing.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import DQNAgent, DQNConfig, EnvConfig, TrainConfig, make_zoo, train_agent
from repro.core.env import CoScheduleEnv
from repro.online import (
    Arrival, ClusterSimulator, GreedyPackerPolicy, OnlineRetrainer,
    RLDispatchPolicy, StaticPartitionPolicy, TRACE_FAMILIES,
    TimeSharingPolicy, heavy_tailed_trace, poisson_trace,
)

ZOO = make_zoo(dryrun_dir=None)
ENV_CFG = EnvConfig(window=4, c_max=3)


def _fresh_agent(seed=0):
    env = CoScheduleEnv(ENV_CFG)
    return DQNAgent(env.state_dim, env.n_actions, DQNConfig(), seed=seed)


def _tiny_train_cfg(seed=0, episodes=20):
    # mirrors the engine shape of the other suites so the compiled scan is
    # shared across test files (same dqn/batch/update cadence)
    return TrainConfig(episodes=episodes, eval_every=episodes,
                       n_train_queues=2, n_heldout_queues=0,
                       strict_classes=False, batch_envs=4,
                       update_every=4, seed=seed,
                       dqn=DQNConfig(buffer_size=512, batch_size=32,
                                     eps_decay_steps=400))


@functools.lru_cache(maxsize=1)
def _trained_agent():
    agent, _ = train_agent(ZOO, ENV_CFG, _tiny_train_cfg(episodes=40),
                           heldout=set())
    return agent


# ------------------------------------------------------------------- traces

@pytest.mark.parametrize("family", sorted(TRACE_FAMILIES))
def test_trace_families_deterministic_sorted_and_sized(family):
    fn = TRACE_FAMILIES[family]
    t1 = fn(ZOO, n=30, seed=5)
    t2 = fn(ZOO, n=30, seed=5)
    assert [a.t for a in t1] == [a.t for a in t2]
    assert [a.binary for a in t1] == [a.binary for a in t2]
    assert len(t1) == 30
    times = [a.t for a in t1]
    assert times == sorted(times) and times[0] > 0
    assert all(a.binary.startswith("bin://") for a in t1)
    # different seed -> different arrivals
    t3 = fn(ZOO, n=30, seed=6)
    assert [a.t for a in t3] != times


def test_trace_mix_weights_dominant_class():
    trace = poisson_trace(ZOO, n=600, mix="ci", seed=1)
    frac = np.mean([a.profile.job_class == "CI" for a in trace])
    assert 0.4 < frac < 0.6, frac


def test_heavy_tailed_trace_scales_job_steps():
    trace = heavy_tailed_trace(ZOO, n=200, seed=2)
    scaled = [a for a in trace if "@x" in a.profile.name]
    assert scaled, "no elephants drawn in 200 arrivals"
    base = {j.name: j.steps for j in ZOO}
    for a in scaled:
        root, _, sfx = a.profile.name.rpartition("@x")
        assert a.profile.steps == base[root] * int(sfx)
    # one profile object per (binary, scale): repository keys stay coherent
    by_bin = {}
    for a in trace:
        assert by_bin.setdefault(a.binary, a.profile) is a.profile


# ---------------------------------------------------------------- simulator

def test_simulator_deterministic_given_seeded_trace():
    trace = poisson_trace(ZOO, n=25, seed=3)
    r1 = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    r2 = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    assert r1.summary() == r2.summary()
    assert [(j.dispatch, j.finish) for j in r1.jobs] == \
           [(j.dispatch, j.finish) for j in r2.jobs]


def test_time_sharing_accounting_invariants():
    trace = poisson_trace(ZOO, n=25, seed=3)
    res = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    assert len(res.jobs) == 25
    assert all(j.group_size == 1 for j in res.jobs)
    assert np.isclose(res.busy_time, res.total_solo_time, rtol=1e-9)
    for j in res.jobs:
        assert j.dispatch >= j.arrival - 1e-9
        assert j.finish > j.dispatch
    assert 0.0 < res.utilization <= 1.0 + 1e-9
    assert res.makespan >= res.busy_time - 1e-6
    # timeline covers exactly the busy span
    assert np.isclose(sum(s.t1 - s.t0 for s in res.timeline), res.busy_time)


def test_coincident_arrivals_share_one_dispatch_window():
    """All events at one timestamp drain before dispatching: a batch
    submission must be visible to a single policy window, not split."""
    trace = [Arrival(t=10.0, binary=f"bin://co{i}", profile=ZOO[i])
             for i in range(4)]
    res = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    assert res.dispatches == 1
    assert all(j.dispatch >= 10.0 for j in res.jobs)


def test_reused_arrival_object_keeps_distinct_records():
    """Records are keyed by trace position, not object identity: submitting
    the same Arrival instance twice must yield two complete job records."""
    a = Arrival(t=10.0, binary="bin://dup", profile=ZOO[0])
    res = ClusterSimulator(TimeSharingPolicy(), window=4).run([a, a])
    assert len(res.jobs) == 2
    for j in res.jobs:
        assert np.isfinite(j.dispatch) and np.isfinite(j.finish)
    assert np.isfinite(res.makespan) and res.throughput > 0


def test_first_sight_jobs_run_solo_and_enter_repository():
    trace = poisson_trace(ZOO, n=30, seed=4)
    pol = RLDispatchPolicy(_fresh_agent(), ENV_CFG)
    res = ClusterSimulator(pol, window=4).run(trace)
    distinct = {a.binary for a in trace}
    assert len(pol.repository) == len(distinct)
    # PolicyStats stay live through the delegated RL protocol: every binary
    # is profiled exactly once, everything else is planned
    assert pol.stats.unprofiled_jobs == len(distinct)
    assert pol.stats.planned_jobs == len(trace) - len(distinct)
    assert pol.scheduler.stats.unprofiled_jobs == len(distinct)
    first_seen: dict[str, object] = {}
    for j in sorted(res.jobs, key=lambda j: j.dispatch):
        first_seen.setdefault(j.binary, j)
    for j in first_seen.values():
        assert j.group_size == 1, f"{j.binary} first sight not solo"


@pytest.mark.parametrize("make_policy", [
    lambda: RLDispatchPolicy(_fresh_agent(), ENV_CFG),
    lambda: GreedyPackerPolicy(c_max=3),
    lambda: StaticPartitionPolicy("mig_only", c_max=3),
])
def test_guarded_policies_use_no_more_busy_time_than_time_sharing(make_policy):
    """Constraint 1 (CoRunTime <= SoloRunTime per group) bounds total pod
    work by time sharing's, regardless of dispatch boundaries."""
    trace = poisson_trace(ZOO, n=25, seed=5)
    ts = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    res = ClusterSimulator(make_policy(), window=4).run(trace)
    assert len(res.jobs) == len(ts.jobs)
    assert res.busy_time <= ts.busy_time * (1.0 + 1e-9)


def test_trained_rl_beats_time_sharing_on_poisson_throughput():
    """Makespan-derived throughput: the acceptance-criterion shape, small."""
    trace = poisson_trace(ZOO, n=40, load=1.3, seed=6)
    ts = ClusterSimulator(TimeSharingPolicy(), window=4).run(trace)
    rl = ClusterSimulator(RLDispatchPolicy(_trained_agent(), ENV_CFG),
                          window=4).run(trace)
    assert rl.throughput >= ts.throughput * 0.99, (
        rl.throughput, ts.throughput)


# --------------------------------------------------------------- re-training

def test_retrainer_fires_and_hot_swaps_params():
    trace = poisson_trace(ZOO, n=30, load=1.3, seed=7)
    agent = _trained_agent()
    before = [np.asarray(x).copy() for x in jax.tree.leaves(agent.params)]
    pol = RLDispatchPolicy(agent, ENV_CFG)
    rt = OnlineRetrainer(policy=pol, train_cfg=_tiny_train_cfg(episodes=20),
                         interval_s=trace[-1].t / 3.0, min_jobs=3)
    res = ClusterSimulator(pol, window=4, tick_interval_s=rt.interval_s,
                           on_tick=rt).run(trace)
    assert res.ticks >= 1
    assert len(rt.history) >= 1
    for h in rt.history:
        assert h["repository_jobs"] >= 3
        assert np.isfinite(h["train_eval_throughput"])
    # the policy now serves a different (re-trained) agent...
    assert pol.agent is not agent
    # ...and warm-start copied rather than donated: original params intact
    after = jax.tree.leaves(agent.params)
    for x, y in zip(before, after):
        assert np.array_equal(x, np.asarray(y))


def test_retrainer_waits_for_min_jobs():
    trace = poisson_trace(ZOO, n=12, seed=8)
    pol = RLDispatchPolicy(_fresh_agent(), ENV_CFG)
    rt = OnlineRetrainer(policy=pol, train_cfg=_tiny_train_cfg(),
                         interval_s=1.0, min_jobs=10**6)
    res = ClusterSimulator(pol, window=4, tick_interval_s=rt.interval_s,
                           on_tick=rt).run(trace)
    assert res.ticks > 0 and rt.history == []
