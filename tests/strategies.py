"""Shared randomized generators + parity helpers for the property suites.

One place owns the shapes the heap-vs-vectorized parity tests range over:

* **Trace specs** — every :data:`TRACE_FAMILIES` family x size x seed x
  load, built through one :func:`make_trace` so fleet suites can scale
  the rate to their capacity.
* **Adversarial traces** — same-instant bursts of *duplicate-tenant*
  submissions (one binary popped several times into one window), the
  shape that pins pop-order tie-breaking and the name-keyed FIFO record
  attribution of ``_form_window``.
* **Job profiles** — zoo rows with and without the ``meta["units"]``
  placement hint (``JobProfile.requested_units``).
* **Fleet topologies** — pod-width tuples led by the mandatory
  full-width pod; **engine knobs** — (window, backfill) pairs.
* **Parity assertions** — :func:`close` (f32-device vs f64-heap
  tolerance) and :func:`assert_parity` (decision-level equality),
  shared by ``test_vecsim.py``, ``test_fleet.py``, and
  ``test_parity_fuzz.py``.

Import through the same hypothesis-or-shim seam as the test modules; the
generators only use the surface ``_hypothesis_compat`` implements
(``composite``/``tuples``/``sampled_from``/scalars), so the suite runs
with or without the real package.
"""
import dataclasses

try:
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_compat import st

from repro.core import make_zoo
from repro.core.partition import N_UNITS
from repro.online import Arrival, TRACE_FAMILIES

ZOO = make_zoo(dryrun_dir=None)

FAMILIES = tuple(sorted(TRACE_FAMILIES))
HINT_WIDTHS = (1, 2, 4, 8)


def make_trace(fam: str, n: int, seed: int, load: float,
               capacity: float = 1.0) -> list:
    """The one trace constructor the suites share (fleet tests pass the
    fleet's full-pod-equivalent ``capacity`` so nominal load is
    comparable across topologies)."""
    return TRACE_FAMILIES[fam](ZOO, n=n, load=load, seed=seed,
                               capacity=capacity)


# ------------------------------------------------------------- strategies

def trace_specs(max_n: int = 60, families=FAMILIES):
    """(family, n, seed, load) — the argument tuple of :func:`make_trace`."""
    return st.tuples(st.sampled_from(families),
                     st.integers(5, max_n),
                     st.integers(0, 50),
                     st.floats(min_value=0.5, max_value=1.8))


@st.composite
def job_profiles(draw, units_hint=None):
    """A zoo profile, optionally re-keyed with a ``meta["units"]`` request.

    ``units_hint=None`` draws the presence of the hint too; hinted
    variants get the ``@u{w}`` name/binary suffix the fragmented family
    uses, so the profile repository sees a distinct application per
    requested width.
    """
    j = draw(st.sampled_from(ZOO))
    hinted = draw(st.booleans()) if units_hint is None else units_hint
    if not hinted:
        return j
    w = draw(st.sampled_from(HINT_WIDTHS))
    return dataclasses.replace(j, name=f"{j.name}@u{w}",
                               meta={**j.meta, "units": w})


@st.composite
def adversarial_traces(draw, max_bursts: int = 5):
    """Same-instant duplicate-tenant bursts.

    Each burst submits one binary 2-4 times at one timestamp (plus an
    optional hinted bystander), so a single dispatch window holds several
    pops of the same name: the shape that distinguishes row-identity
    attribution from the heap's name-keyed FIFO, and that exercises
    same-instant pop ordering.  Inter-burst gaps are drawn wide enough
    that bursts can also pile into one window under load.
    """
    out, t = [], 0.0
    for _ in range(draw(st.integers(2, max_bursts))):
        t += draw(st.floats(min_value=0.0, max_value=400.0))
        dup = draw(job_profiles(units_hint=False))
        for _ in range(draw(st.integers(2, 4))):
            out.append(Arrival(t=t, binary=f"bin://{dup.name}", profile=dup))
        if draw(st.booleans()):
            by = draw(job_profiles(units_hint=True))
            out.append(Arrival(t=t, binary=f"bin://{by.name}", profile=by))
    return out


@st.composite
def fleet_topologies(draw, max_pods: int = 4):
    """Pod-width tuples; ``SimConfig`` requires one full-width pod."""
    n_extra = draw(st.integers(0, max_pods - 1))
    extra = tuple(draw(st.sampled_from((2, 4, 8))) for _ in range(n_extra))
    return (N_UNITS, *extra)


def engine_knobs():
    """(window, backfill) — the formation-seam knobs both engines share."""
    return st.tuples(st.sampled_from((2, 4, 8)), st.booleans())


# ------------------------------------------------------ parity assertions

def close(a, b):
    # f32 lanes vs f64 heap: absolute floor for near-zero waits, relative
    # for late-horizon timestamps
    return abs(a - b) <= max(0.05, 1e-4 * max(abs(a), abs(b)))


def assert_parity(h, v):
    """Decision-level equality + f32-resolution times between engines."""
    assert len(v.jobs) == len(h.jobs)
    key = lambda r: (r.arrival, r.name)  # noqa: E731
    for a, b in zip(sorted(h.jobs, key=key), sorted(v.jobs, key=key)):
        assert a.name == b.name and a.binary == b.binary
        assert a.units == b.units, (a.name, a.units, b.units)
        assert a.partition == b.partition, (a.name, a.partition, b.partition)
        assert a.group_size == b.group_size, (a.name, a.group_size,
                                              b.group_size)
        assert a.backfilled == b.backfilled
        assert a.pod == b.pod, (a.name, a.pod, b.pod)
        assert close(a.dispatch, b.dispatch), (a.name, a.dispatch, b.dispatch)
        assert close(a.finish, b.finish), (a.name, a.finish, b.finish)
        assert close(a.wait, b.wait)
        assert close(a.turnaround, b.turnaround)
    assert v.dispatches == h.dispatches
    assert v.backfills == h.backfills
    assert v.refits == h.refits
    # timeline in placement order: same slice ranges, same backfill flags
    assert len(v.timeline) == len(h.timeline)
    for s, t in zip(h.timeline, v.timeline):
        assert t.slices == s.slices
        assert t.partition == s.partition
        assert t.backfilled == s.backfilled
        assert close(s.t0, t.t0) and close(s.t1, t.t1)
    assert close(h.busy_time, v.busy_time)
