"""Telemetry layer invariants (docs/observability.md).

Four guarantees:

* **span chains** — on a drained trace every arrived job's lifecycle
  chain ``arrive -> window -> place`` completes in order and its claim
  reaches ``free`` at the predicted end;
* **aggregate fidelity** — the streaming registry's counters and the
  vectorized engine's in-graph ``MetricsState`` agree with each other
  (heap-vs-vec parity) and with the post-hoc ``SimResult.summary()``;
  the bucketed histogram matches the numpy reference;
* **observes, never steers** — enabling telemetry changes no decision:
  heap ``SimResult``\\ s and vectorized summaries are bit-identical with
  the flag on and off, and the scanned training engine's parameter
  trajectory is exactly unchanged under ``TrainConfig(telemetry=True)``;
* **drift signals** — the EMA monitor seeds, fires on mix-entropy /
  idle-fraction shifts, respects ``min_arrivals``, and rebases.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import EnvConfig, TrainConfig, make_zoo, train_agent
from repro.core.agent import DQNConfig
from repro.online import (
    ClusterSimulator, DriftMonitor, GreedyPackerPolicy, OnlineRetrainer,
    RLDispatchPolicy, SimConfig, TRACE_FAMILIES, Telemetry,
    TimeSharingPolicy, VectorizedClusterSimulator, WAIT_BUCKETS_S,
)
from repro.online.telemetry import Histogram, entropy_bits
from repro.online.vecsim import metrics_dict

ZOO = make_zoo(dryrun_dir=None)

_ENGINES: dict = {}


def _vec_engine(window=8, capacity=96, telemetry=False):
    key = (window, capacity, telemetry)
    if key not in _ENGINES:
        _ENGINES[key] = VectorizedClusterSimulator(
            TimeSharingPolicy(), window=window, capacity=capacity,
            telemetry=telemetry)
    return _ENGINES[key]


def _trace(family="poisson", n=40, seed=3, **kw):
    return TRACE_FAMILIES[family](ZOO, n=n, load=1.3, seed=seed, **kw)


# ---------------------------------------------------------------------------
# Span chains
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pods", [(8,), (8, 4)])
def test_span_chain_completes_for_every_job(pods):
    tel = Telemetry()
    cfg = SimConfig(window=8, pods=pods, router="hash")
    res = ClusterSimulator(GreedyPackerPolicy(), cfg, telemetry=tel).run(
        _trace(n=40))
    spans = tel.recorder.job_spans()
    assert len(spans) == len(res.jobs) == 40
    for rec in res.jobs:
        s = spans[rec.idx]
        assert s["arrive"] == rec.arrival
        assert s["window"] is not None and s["window"] >= s["arrive"]
        assert s["place"] is not None and s["place"] >= s["window"]
        assert s["run_end"] is not None and s["run_end"] > s["place"]
        # concurrent mode: the claim's FREE lands exactly at run_end
        assert s["free"] == pytest.approx(s["run_end"])
        assert s["pod"] == rec.pod
        assert s["backfilled"] == rec.backfilled


def test_span_events_are_ordered_and_jsonable(tmp_path):
    tel = Telemetry()
    ClusterSimulator(TimeSharingPolicy(), window=8, telemetry=tel).run(
        _trace(family="fragmented", n=30))
    ts = [e["t_s"] for e in tel.recorder.events]
    assert ts == sorted(ts)
    p = tmp_path / "events.jsonl"
    tel.recorder.write_jsonl(str(p))
    lines = [json.loads(line) for line in p.read_text().splitlines()]
    assert len(lines) == len(tel.recorder)
    assert {line["kind"] for line in lines} >= {"arrive", "window",
                                                "place", "free"}


def test_chrome_trace_is_valid_trace_event_json(tmp_path):
    tel = Telemetry()
    cfg = SimConfig(window=8, pods=(8, 4), router="hash")
    ClusterSimulator(GreedyPackerPolicy(), cfg, telemetry=tel).run(
        _trace(n=30))
    p = tmp_path / "trace.json"
    tel.recorder.write_chrome_trace(str(p), pods=(8, 4))
    doc = json.loads(p.read_text())
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"M", "X", "i"}
    xs = [e for e in evs if e["ph"] == "X"]
    # one complete event per claimed unit per placement
    claimed_units = sum(sum(w for _, w in e["slices"])
                       for e in tel.recorder.by_kind("place"))
    assert len(xs) == claimed_units
    for e in xs:
        assert e["dur"] >= 0 and e["pid"] in (0, 1)


# ---------------------------------------------------------------------------
# Aggregate fidelity
# ---------------------------------------------------------------------------


def test_histogram_matches_numpy_reference():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=4.0, sigma=2.0, size=500)
    h = Histogram("wait_s", WAIT_BUCKETS_S)
    for x in xs:
        h.observe(float(x))
    edges = np.asarray(WAIT_BUCKETS_S)
    ref = np.array([np.count_nonzero(
        (xs <= edges[i]) & ((xs > edges[i - 1]) if i else True))
        for i in range(len(edges))] + [np.count_nonzero(xs > edges[-1])])
    assert h.counts == ref.tolist()
    assert h.count == 500 and h.sum == pytest.approx(xs.sum())
    assert h.mean == pytest.approx(xs.mean())
    # bucket-interpolated percentile lands within one bucket of the truth
    for q in (50, 95, 99):
        est, true = h.percentile(q), float(np.percentile(xs, q))
        idx = int(np.searchsorted(edges, true, side="left"))
        lo = 0.0 if idx == 0 else float(edges[idx - 1])
        hi = float(edges[idx]) if idx < len(edges) else true
        assert lo <= est <= max(hi, est)


def test_registry_counters_match_summary():
    tel = Telemetry()
    cfg = SimConfig(window=8, pods=(8, 4), router="hash")
    res = ClusterSimulator(GreedyPackerPolicy(), cfg, telemetry=tel).run(
        _trace(family="fragmented", n=40))
    summ = res.summary()
    m = {d["name"]: d for d in tel.metrics.to_dicts()}
    assert m["jobs_arrived"]["value"] == summ["jobs"]
    assert m["windows_formed"]["value"] == summ["dispatches"]
    assert m["groups_placed"]["value"] == summ["groups"]
    assert m["backfills"]["value"] == summ["backfills"]
    assert m["refits"]["value"] == summ["refits"]
    assert m["busy_unit_s"]["value"] == pytest.approx(
        sum(res.slice_busy_s), rel=1e-9)
    assert m["wait_s"]["count"] == summ["jobs"]
    assert m["wait_s"]["sum"] == pytest.approx(
        sum(r.wait for r in res.jobs), rel=1e-9)


@pytest.mark.parametrize("seed", [1, 5, 11])
def test_heap_vs_vectorized_metric_parity(seed):
    trace = _trace(n=40, seed=seed)
    tel = Telemetry()
    ClusterSimulator(TimeSharingPolicy(), window=8, telemetry=tel).run(trace)
    eng = _vec_engine(telemetry=True)
    eng.run(trace)
    vm = eng.last_metrics
    hh = tel.metrics.histogram("wait_s")
    assert vm["wait_s"]["counts"] == hh.counts
    assert vm["wait_s"]["count"] == hh.count
    assert vm["groups_placed"] == tel.metrics.counter("groups_placed").value
    assert vm["wait_s"]["sum"] == pytest.approx(hh.sum, rel=1e-3, abs=0.5)
    assert vm["queue_depth_integral_s"] == pytest.approx(
        tel.metrics.counter("queue_depth_integral_s").value,
        rel=1e-3, abs=1.0)
    assert vm["busy_unit_s"] == pytest.approx(
        tel.metrics.counter("busy_unit_s").value, rel=1e-3, abs=1.0)


def test_rl_vectorized_metrics_match_summary():
    """The in-graph RL serving path feeds the same MetricsState lanes the
    time-sharing path does: its streaming counters must agree with the
    post-hoc ``SimResult.summary()`` exactly like the heap path's
    registry does."""
    from repro.core import CoScheduleEnv
    from repro.core.agent import DQNAgent

    env_cfg = EnvConfig()
    env = CoScheduleEnv(env_cfg)
    policy = RLDispatchPolicy(
        DQNAgent(env.state_dim, env.n_actions, seed=0), env_cfg)
    eng = VectorizedClusterSimulator(policy, window=8, capacity=96,
                                     telemetry=True)
    res = eng.run(_trace(n=40, seed=5))
    summ = res.summary()
    vm = eng.last_metrics
    assert vm["wait_s"]["count"] == summ["jobs"]
    assert vm["wait_s"]["sum"] == pytest.approx(
        sum(r.wait for r in res.jobs), rel=1e-3, abs=0.5)
    assert vm["groups_placed"] == summ["groups"]
    assert vm["busy_unit_s"] == pytest.approx(
        sum(res.slice_busy_s), rel=1e-3, abs=1.0)
    # streaming histogram == numpy reference over the same records
    ref = Histogram("wait_s", WAIT_BUCKETS_S)
    for r in res.jobs:
        ref.observe(r.wait)
    assert vm["wait_s"]["counts"] == ref.counts


def test_sweep_with_metrics_returns_lane_tensors():
    traces = [_trace(n=30, seed=s) for s in (0, 1, 2)]
    eng = _vec_engine(telemetry=True)
    summ, ms = eng.sweep(traces, with_metrics=True)
    assert ms.wait_hist.shape == (3, len(WAIT_BUCKETS_S) + 1)
    for i in range(3):
        lane = metrics_dict(jax.tree.map(lambda x: x[i], ms))
        assert lane["wait_s"]["count"] == 30
    with pytest.raises(ValueError):
        _vec_engine(telemetry=False).sweep(traces, with_metrics=True)


# ---------------------------------------------------------------------------
# Observes, never steers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,pods", [("poisson", (8,)),
                                         ("fragmented", (8, 4))])
def test_heap_disabled_vs_enabled_results_identical(family, pods):
    trace = _trace(family=family, n=40)
    cfg = SimConfig(window=8, pods=pods, router="hash")
    r0 = ClusterSimulator(GreedyPackerPolicy(), cfg).run(trace)
    r1 = ClusterSimulator(GreedyPackerPolicy(), cfg,
                          telemetry=Telemetry()).run(trace)
    assert r0.summary() == r1.summary()
    for a, b in zip(r0.jobs, r1.jobs):
        assert (a.name, a.wait, a.turnaround, a.pod, a.units,
                a.backfilled) == (b.name, b.wait, b.turnaround, b.pod,
                                  b.units, b.backfilled)


def test_vectorized_disabled_vs_enabled_summaries_identical():
    trace = _trace(n=40)
    s0 = _vec_engine(telemetry=False).run(trace).summary()
    s1 = _vec_engine(telemetry=True).run(trace).summary()
    assert s0 == s1


def test_training_telemetry_keeps_parameter_trajectory():
    env_cfg = EnvConfig(window=6, c_max=3)
    dqn = DQNConfig(eps_decay_steps=200)
    mk = lambda tele: TrainConfig(episodes=40, eval_every=20, seed=7,  # noqa: E731
                                  dqn=dqn, telemetry=tele)
    a0, h0 = train_agent(ZOO, env_cfg, mk(False))
    a1, h1 = train_agent(ZOO, env_cfg, mk(True))
    for x, y in zip(jax.tree.leaves(a0.params), jax.tree.leaves(a1.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for r0, r1 in zip(h0, h1):
        assert r0["eval_throughput"] == r1["eval_throughput"]
        assert r0["episode"] == r1["episode"]
    # telemetry-only fields exist and are finite
    assert all(np.isfinite(r["loss"]) and np.isfinite(r["grad_norm"])
               for r in h1)
    assert all("loss" not in r for r in h0)


# ---------------------------------------------------------------------------
# Drift signals
# ---------------------------------------------------------------------------


def test_entropy_bits():
    assert entropy_bits({"a": 8}) == 0.0
    assert entropy_bits({"a": 4, "b": 4}) == pytest.approx(1.0)
    assert entropy_bits({}) == 0.0


def test_drift_monitor_seeds_then_fires_on_mix_shift():
    mon = DriftMonitor()
    flat = {"CI": 4, "MI": 4, "US": 4}
    widths = {8: 6, 1: 6}
    assert not mon.observe(flat, widths, 0.2)["drift"]       # seeds
    assert not mon.observe(flat, widths, 0.2)["drift"]       # same regime
    v = mon.observe({"US": 12}, {1: 12}, 0.2)                # mix collapses
    assert v["drift"]
    assert set(v["reasons"]) >= {"class_entropy", "width_entropy"}


def test_drift_monitor_idle_rise_and_min_arrivals():
    mon = DriftMonitor()
    mon.observe({"CI": 8}, {8: 8}, 0.1)
    v = mon.observe({"CI": 8}, {8: 8}, 0.1 + mon.idle_threshold + 0.05)
    assert v["drift"] and v["reasons"] == ["idle_slice_frac"]
    thin = DriftMonitor()
    thin.observe({"CI": 8}, {8: 8}, 0.1)
    assert not thin.observe({"US": 2}, {1: 2}, 0.9)["drift"]  # < min_arrivals


def test_drift_monitor_rebase_resets_baseline():
    mon = DriftMonitor()
    mon.observe({"CI": 4, "MI": 4}, {8: 4, 1: 4}, 0.1)
    assert mon.observe({"US": 8}, {1: 8}, 0.1)["drift"]
    mon.rebase()
    assert not mon.observe({"US": 8}, {1: 8}, 0.1)["drift"]   # new normal
    assert not mon.observe({"US": 8}, {1: 8}, 0.1)["drift"]


def test_retrainer_rejects_unknown_trigger():
    pol = RLDispatchPolicy.__new__(RLDispatchPolicy)  # no agent needed
    with pytest.raises(ValueError):
        OnlineRetrainer(policy=pol, train_cfg=TrainConfig(episodes=1),
                        interval_s=60.0, trigger="sometimes")
