"""Slice-level concurrent dispatch: placement arithmetic, occupancy
invariants, EASY backfill, and blocking-mode bit-compatibility.

Invariant contract of the concurrent event model:

  * no two groups whose segments overlap in time claim overlapping slice
    units (the occupancy map is exclusive);
  * FREE events reconcile with the timeline — per-unit busy seconds summed
    from segments equal ``SimResult.slice_busy_s``, and the union of
    segment intervals equals ``busy_time``;
  * backfill never delays the blocked head's start (EASY reservation);
  * on traces without sub-pod width hints, ``mode="concurrent"`` is
    bit-compatible with the PR-3 ``mode="blocking"`` dispatch, which stays
    available for regression.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import make_zoo
from repro.core.partition import (
    N_UNITS, Partition, Slice, aligned_offsets, find_offsets, slice_label,
    solo_partition,
)
from repro.core.perfmodel import corun
from repro.core.problem import Schedule
from repro.core.scheduler import to_placements
from repro.online import (
    Arrival, ClusterSimulator, GreedyPackerPolicy, StaticPartitionPolicy,
    TimeSharingPolicy, fragmented_trace, poisson_trace,
)

ZOO = make_zoo(dryrun_dir=None)


def _unit_set(seg):
    return {u for start, w in seg.slices for u in range(start, start + w)}


def _assert_no_overlap(res):
    segs = res.timeline
    for i in range(len(segs)):
        for j in range(i + 1, len(segs)):
            a, b = segs[i], segs[j]
            if a.t0 < b.t1 - 1e-9 and b.t0 < a.t1 - 1e-9:
                assert not (_unit_set(a) & _unit_set(b)), (a, b)


def _mouse(base, name, steps, units=1):
    return dataclasses.replace(base, name=name, steps=steps,
                               meta={**base.meta, "units": units})


US = next(j for j in ZOO if j.job_class == "US")
CI = next(j for j in ZOO if j.job_class == "CI")


# ------------------------------------------------- placement arithmetic

def test_aligned_offsets_buddy_alignment():
    assert aligned_offsets(1) == tuple(range(8))
    assert aligned_offsets(2) == (0, 2, 4, 6)
    assert aligned_offsets(4) == (0, 4)
    assert aligned_offsets(8) == (0,)


def test_find_offsets_disjoint_and_aligned():
    p = Partition((Slice(4, (1.0,)), Slice(2, (1.0,)), Slice(2, (1.0,))),
                  "test")
    starts = find_offsets(p, [True] * N_UNITS)
    assert starts is not None
    claimed = set()
    for st, s in zip(starts, p.slices):
        assert st % s.units == 0, "unaligned placement"
        rng = set(range(st, st + s.units))
        assert not (claimed & rng), "overlapping slices"
        claimed |= rng


def test_find_offsets_respects_free_mask_and_fails_cleanly():
    solo4 = solo_partition(4)
    # units 0-3 busy: the only aligned 4-range left starts at 4
    free = [False] * 4 + [True] * 4
    assert find_offsets(solo4, free) == (4,)
    # an aligned hole of 2+2 split across the boundary cannot host a 4-slice
    free = [False, False, True, True, True, True, False, False]
    assert find_offsets(solo4, free) is None
    assert find_offsets(solo_partition(2), free) == (2,)


def test_solo_partition_widths_and_labels():
    assert solo_partition().label == "[{1.0},1m]"     # table object, unchanged
    for u, lab in ((4, ".5m"), (2, ".25m"), (1, ".125m")):
        p = solo_partition(u)
        assert p.arity == 1 and p.total_units == u
        assert lab in p.label, p.label


def test_right_size_and_requested_units():
    assert US.right_size(1.05) == 1                    # faster on small slices
    assert CI.right_size(1.25) == N_UNITS              # scales, stays full-pod
    for tol in (1.05, 1.5, 2.0):
        w = US.right_size(tol)
        assert US.step_time(w) <= tol * US.step_time(N_UNITS)
    assert US.requested_units == N_UNITS               # no hint -> full pod
    assert _mouse(US, "m", 100).requested_units == 1
    bad = dataclasses.replace(US, meta={"units": 3})   # invalid width ignored
    assert bad.requested_units == N_UNITS


def test_to_placements_narrows_dedicated_slices_only():
    m = _mouse(US, "m@u1", 1000)
    sched = Schedule()
    sched.add([m], solo_partition())                         # dedicated slice
    sched.add([CI, CI], Partition((Slice(8, (0.5, 0.5)),), "mps"))  # shared
    pls = to_placements(sched)
    assert pls[0].partition.total_units == 1
    assert slice_label(pls[0].partition.slices) == pls[0].partition.label
    assert pls[1].partition is sched.partitions[1]     # MPS slice untouched
    # no hints anywhere -> identical partition objects (bit-compat path)
    sched2 = Schedule()
    sched2.add([CI], solo_partition())
    assert to_placements(sched2)[0].partition is sched2.partitions[0]


# ------------------------------------------------- occupancy invariants

@pytest.mark.parametrize("make_policy", [
    lambda: TimeSharingPolicy(),
    lambda: GreedyPackerPolicy(c_max=3),
    lambda: StaticPartitionPolicy("mig_only", c_max=3),
])
def test_concurrent_occupancy_invariants(make_policy):
    trace = fragmented_trace(ZOO, n=40, load=1.3, seed=2)
    res = ClusterSimulator(make_policy(), window=6).run(trace)
    assert len(res.jobs) == 40
    assert all(np.isfinite(j.finish) for j in res.jobs)
    _assert_no_overlap(res)
    # FREE reconciliation: per-unit busy from segments == slice_busy_s
    per_unit = [0.0] * N_UNITS
    for seg in res.timeline:
        for st, w in seg.slices:
            for u in range(st, st + w):
                per_unit[u] += seg.t1 - seg.t0
    assert np.allclose(per_unit, res.slice_busy_s)
    assert np.isclose(res.unit_busy_s, sum(res.slice_busy_s))
    # busy_time == union of segment intervals (pod busy when any unit is)
    ivs = sorted((s.t0, s.t1) for s in res.timeline)
    union, cur0, cur1 = 0.0, None, None
    for t0, t1 in ivs:
        if cur1 is None or t0 > cur1 + 1e-12:
            union += (cur1 - cur0) if cur1 is not None else 0.0
            cur0, cur1 = t0, t1
        else:
            cur1 = max(cur1, t1)
    union += (cur1 - cur0) if cur1 is not None else 0.0
    assert np.isclose(union, res.busy_time)
    assert 0.0 <= res.slice_utilization <= 1.0 + 1e-9
    assert np.isclose(res.idle_slice_frac, 1.0 - res.slice_utilization)


def test_concurrent_mode_actually_overlaps_on_fragmented_trace():
    trace = fragmented_trace(ZOO, n=40, load=1.3, seed=2)
    res = ClusterSimulator(TimeSharingPolicy(), window=6).run(trace)
    segs = res.timeline
    overlaps = sum(1 for i in range(len(segs)) for j in range(i + 1, len(segs))
                   if segs[i].t0 < segs[j].t1 - 1e-9
                   and segs[j].t0 < segs[i].t1 - 1e-9)
    assert overlaps > 0, "no concurrency on a width-mixed trace"
    assert res.throughput > ClusterSimulator(
        TimeSharingPolicy(), window=6, mode="blocking").run(trace).throughput


def test_simulator_concurrent_deterministic():
    trace = fragmented_trace(ZOO, n=30, seed=4)
    r1 = ClusterSimulator(TimeSharingPolicy(), window=5).run(trace)
    r2 = ClusterSimulator(TimeSharingPolicy(), window=5).run(trace)
    assert r1.summary() == r2.summary()
    assert [(j.dispatch, j.finish, j.units, j.backfilled) for j in r1.jobs] == \
           [(j.dispatch, j.finish, j.units, j.backfilled) for j in r2.jobs]


# --------------------------------------------------------- EASY backfill

def _crafted_window():
    """One coincident window: long 1-unit mouse, full-pod head, short
    1-unit mouse — the head blocks behind the long mouse and the short
    mouse is a textbook backfill candidate."""
    m_long = _mouse(US, "mouse-long", 40_000)
    big = dataclasses.replace(CI, name="big-head", meta=dict(CI.meta))
    m_short = _mouse(US, "mouse-short", 8_000)
    return [Arrival(t=10.0, binary=f"bin://{j.name}", profile=j)
            for j in (m_long, big, m_short)], (m_long, big, m_short)


def test_backfill_jumps_gap_without_delaying_head():
    trace, (m_long, big, m_short) = _crafted_window()
    dur_long = corun([m_long], solo_partition(1)).makespan
    on = ClusterSimulator(TimeSharingPolicy(), window=8).run(trace)
    off = ClusterSimulator(TimeSharingPolicy(), window=8,
                           backfill=False).run(trace)
    by = {r.name: r for r in on.jobs}
    by_off = {r.name: r for r in off.jobs}
    # the head's start is identical with and without backfill (EASY)
    assert np.isclose(by["big-head"].dispatch, 10.0 + dur_long)
    assert np.isclose(by["big-head"].dispatch, by_off["big-head"].dispatch)
    assert np.isclose(by["mouse-long"].dispatch, by_off["mouse-long"].dispatch)
    # the short mouse jumped the queue into the idle units...
    assert on.backfills == 1 and by["mouse-short"].backfilled
    assert np.isclose(by["mouse-short"].dispatch, 10.0)
    # ...and finished before the head's reserved start
    assert by["mouse-short"].finish <= by["big-head"].dispatch + 1e-9
    # without backfill it waited for FCFS order instead
    assert by_off["mouse-short"].dispatch > by_off["big-head"].dispatch - 1e-9
    assert off.backfills == 0 and not by_off["mouse-short"].backfilled


def test_lookahead_window_backfills_later_arrival():
    """A 1-unit job arriving while the head is blocked gets admitted
    through the bounded lookahead window and backfilled immediately."""
    m_long = _mouse(US, "mouse-long", 40_000)
    big = dataclasses.replace(CI, name="big-head", meta=dict(CI.meta))
    m_late = _mouse(US, "mouse-late", 8_000)
    trace = [Arrival(t=0.0, binary="bin://mouse-long", profile=m_long),
             Arrival(t=0.0, binary="bin://big-head", profile=big),
             Arrival(t=5.0, binary="bin://mouse-late", profile=m_late)]
    res = ClusterSimulator(TimeSharingPolicy(), window=2).run(trace)
    by = {r.name: r for r in res.jobs}
    dur_long = corun([m_long], solo_partition(1)).makespan
    assert res.backfills == 1 and by["mouse-late"].backfilled
    assert np.isclose(by["mouse-late"].dispatch, 5.0)
    assert np.isclose(by["big-head"].dispatch, dur_long)   # head undelayed
    assert res.dispatches == 2                             # lookahead window


# ------------------------------------------- blocking-mode compatibility

@pytest.mark.parametrize("window", [1, 4])
def test_concurrent_bit_compatible_with_blocking_on_full_pod_traces(window):
    """Without sub-pod width hints every placement is full-pod, so the
    slice-level engine must reproduce the PR-3 blocking results exactly
    (records bit-equal; busy time to float accumulation order)."""
    trace = poisson_trace(ZOO, n=25, seed=3)
    blk = ClusterSimulator(TimeSharingPolicy(), window=window,
                           mode="blocking").run(trace)
    con = ClusterSimulator(TimeSharingPolicy(), window=window).run(trace)
    assert [(j.dispatch, j.finish, j.group_size, j.partition)
            for j in blk.jobs] == \
           [(j.dispatch, j.finish, j.group_size, j.partition)
            for j in con.jobs]
    sb, sc = blk.summary(), con.summary()
    assert sb["mode"] == "blocking" and sc["mode"] == "concurrent"
    for k in sb:
        if k in ("mode", "busy_s", "utilization"):
            continue
        assert sb[k] == pytest.approx(sc[k]), k
    assert np.isclose(sb["busy_s"], sc["busy_s"])
    assert con.backfills == 0                      # nothing to backfill


def test_blocking_mode_segments_claim_full_pod():
    trace = poisson_trace(ZOO, n=10, seed=1)
    res = ClusterSimulator(TimeSharingPolicy(), window=4,
                           mode="blocking").run(trace)
    assert all(s.slices == ((0, N_UNITS),) for s in res.timeline)
    assert np.isclose(res.unit_busy_s, N_UNITS * res.busy_time)


# --------------------------------------------- dispatch-time context snapshot

class _RecordingPolicy(TimeSharingPolicy):
    """Time sharing that records the DispatchContext of every window."""

    def __init__(self):
        super().__init__()
        self.contexts = []

    def placements(self, submissions, context=None):
        self.contexts.append((context, [p for p, _ in submissions]))
        return super().placements(submissions, context=context)


def test_dispatch_context_matches_occupancy_and_ages():
    """The snapshot handed to the policy obeys the occupancy-map contract:
    every unit reported busy is covered by a claim segment spanning the
    dispatch instant, ages equal now - arrival for the window's
    submissions, and depth counts exactly the left-behind pending queue."""
    trace = fragmented_trace(ZOO, n=40, load=1.3, seed=2)
    pol = _RecordingPolicy()
    res = ClusterSimulator(pol, window=6).run(trace)
    assert pol.contexts and len(pol.contexts) == res.dispatches
    # windows pop the pending queue FCFS (lookahead included), so the
    # concatenated window submissions replay the time-sorted trace exactly
    order = sorted(trace, key=lambda a: a.t)
    k = 0
    partial = 0
    for ctx, bins in pol.contexts:
        assert ctx is not None and len(ctx.free_units) == N_UNITS
        busy = {u for u in range(N_UNITS) if not ctx.free_units[u]}
        covered = {u for seg in res.timeline
                   if seg.t0 <= ctx.now_s + 1e-9 and seg.t1 > ctx.now_s + 1e-9
                   for u in _unit_set(seg)}
        assert busy <= covered, (ctx.now_s, busy, covered)
        assert len(ctx.ages_s) == len(bins)
        for age, b in zip(ctx.ages_s, bins):
            assert b == order[k].binary
            assert age == pytest.approx(ctx.now_s - order[k].t)
            assert age >= -1e-9
            k += 1
        assert ctx.queue_depth >= 0
        if 0 < len(busy) < N_UNITS:
            partial += 1
    assert k == len(trace)
    # the fragmented family must exercise genuinely partial occupancies
    assert partial > 0


def test_blocking_dispatch_context_reports_idle_pod():
    trace = poisson_trace(ZOO, n=12, seed=1)

    class _Rec(TimeSharingPolicy):
        seen = []

        def dispatch(self, submissions, context=None):
            self.seen.append(context)
            return super().dispatch(submissions, context=context)

    pol = _Rec()
    ClusterSimulator(pol, window=4, mode="blocking").run(trace)
    assert pol.seen and all(all(c.free_units) for c in pol.seen)


# ------------------------------------------------------ fragmented trace

def test_fragmented_trace_mixes_slice_widths_coherently():
    trace = fragmented_trace(ZOO, n=120, seed=0)
    widths = {a.profile.requested_units for a in trace}
    assert 1 in widths and N_UNITS in widths, widths
    assert widths - {1, 2, 4, 8} == set()
    by_bin = {}
    for a in trace:
        # one profile object per (binary, width): repository keys coherent
        assert by_bin.setdefault(a.binary, a.profile) is a.profile
        if a.profile.requested_units < N_UNITS:
            assert a.profile.name.endswith(f"@u{a.profile.requested_units}")
            w = a.profile.requested_units
            assert a.profile.step_time(w) <= 1.65 * a.profile.step_time(N_UNITS)
