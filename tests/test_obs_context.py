"""Arrival-aware observation contract (docs/observation.md).

Pins the three guarantees the context block ships with:

  * **zero-context parity** — with ``obs_context=True`` and no context, the
    observation prefix bit-matches the profile-only layout (scalar and
    vectorized paths), rewards/masks/done are unchanged, and the appended
    block is all-zero; with ``obs_context=False`` nothing changes at all;
  * **scalar/vectorized agreement** — a real ``DispatchContext`` produces
    the same observation and the same fit-shaped close rewards through
    ``CoScheduleEnv`` and ``VecCoScheduleEnv``;
  * **widen warm-start** — ``widen_dqn_params`` computes the identical
    Q-function at zero context, and context training is deterministic.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DQNAgent, DQNConfig, DispatchContext, EnvConfig, RLScheduler,
    TrainConfig, dispatch_obs_context, make_zoo, train_agent,
    widen_dqn_params,
)
from repro.core.env import (
    CoScheduleEnv, VecCoScheduleEnv, age_feature, context_dim, depth_feature,
)
from repro.core.network import dqn_apply
from repro.core.partition import N_UNITS
from repro.core.scheduler import submission_protocol
from repro.core.workloads import make_queue

ZOO = make_zoo(dryrun_dir=None)

BASE = EnvConfig(window=6, c_max=3)
CTX = EnvConfig(window=6, c_max=3, obs_context=True)


def _queue(seed=0, n=6):
    return make_queue(ZOO, "balanced", n, np.random.default_rng(seed))


def _blocked_ctx(queue):
    """Half the pod busy: full-pod partitions cannot fit, narrow ones can."""
    return DispatchContext(free_units=(False,) * 4 + (True,) * 4,
                           ages_s=tuple(10.0 * i for i in range(len(queue))),
                           queue_depth=7, now_s=100.0)


# ------------------------------------------------------- zero-context parity

def test_context_dims():
    assert context_dim(BASE) == 0
    assert context_dim(CTX) == N_UNITS + 6 + 1
    assert CoScheduleEnv(CTX).state_dim == \
        CoScheduleEnv(BASE).state_dim + context_dim(CTX)
    assert VecCoScheduleEnv(CTX).state_dim == CoScheduleEnv(CTX).state_dim


@pytest.mark.parametrize("seed", range(2))
def test_zero_context_bitmatches_profile_only_scalar(seed):
    """Same queue, same random action stream: the obs prefix is bit-equal,
    the context suffix all-zero, and rewards/masks/done identical."""
    queue = _queue(seed)
    ref, ctx = CoScheduleEnv(BASE), CoScheduleEnv(CTX)
    d = ref.state_dim
    s_r, m_r = ref.reset(queue)
    s_c, m_c = ctx.reset(queue)          # context=None -> zero block
    rng = np.random.default_rng(seed)
    while True:
        assert np.array_equal(s_c[:d], s_r)
        assert not s_c[d:].any()
        assert np.array_equal(m_c, m_r)
        if ref.done:
            break
        a = int(rng.choice(np.flatnonzero(m_r)))
        s_r, r_r, d_r, m_r, _ = ref.step(a)
        s_c, r_c, d_c, m_c, _ = ctx.step(a)
        assert r_c == r_r and d_c == d_r


def test_zero_context_bitmatches_profile_only_vectorized():
    queue = _queue(1)
    ref, ctx = VecCoScheduleEnv(BASE), VecCoScheduleEnv(CTX)
    d = ref.state_dim
    st_r, o_r, m_r = ref.reset(ref.queue_arrays(queue))
    st_c, o_c, m_c = ctx.reset(ctx.queue_arrays(queue))
    rng = np.random.default_rng(1)
    while True:
        assert np.array_equal(np.asarray(o_c)[:d], np.asarray(o_r))
        assert not np.asarray(o_c)[d:].any()
        assert np.array_equal(np.asarray(m_c), np.asarray(m_r))
        valid = np.flatnonzero(np.asarray(m_r))
        if not valid.size:
            break
        a = jnp.int32(rng.choice(valid))
        st_r, o_r, r_r, done, m_r = ref.step(st_r, a)
        st_c, o_c, r_c, done_c, m_c = ctx.step(st_c, a)
        # fit table row 0 (all free) makes the shaping an exact -0.0
        assert float(r_c) == float(r_r)
        assert bool(done) == bool(done_c)
        if bool(done):
            break


# --------------------------------------------- scalar vs vectorized context

def test_real_context_scalar_vs_vectorized_parity():
    queue = _queue(2)
    dctx = _blocked_ctx(queue)
    sc, ve = CoScheduleEnv(CTX), VecCoScheduleEnv(CTX)
    s, m = sc.reset(queue, dctx)
    st, o, mv = ve.reset_ctx(ve.queue_arrays(queue),
                             dispatch_obs_context(dctx, CTX.window))
    rng = np.random.default_rng(2)
    while not sc.done:
        np.testing.assert_allclose(np.asarray(o), s, atol=1e-6)
        assert np.array_equal(np.asarray(mv), m)
        a = int(rng.choice(np.flatnonzero(m)))
        s, r, _, m, _ = sc.step(a)
        st, o, rv, _, mv = ve.step(st, jnp.int32(a))
        assert abs(float(rv) - r) <= 1e-3 + 2e-3 * abs(r), (float(rv), r)


def test_fit_penalty_blocks_nonfitting_close_only():
    """With half the pod busy a full-pod close pays ctx_fit_weight; the same
    close at zero context does not — scalar and vectorized agree exactly."""
    queue = _queue(3)
    dctx = _blocked_ctx(queue)
    blocked, free = CoScheduleEnv(CTX), CoScheduleEnv(CTX)
    s_b, m_b = blocked.reset(queue, dctx)
    s_f, m_f = free.reset(queue)
    a_sel = int(np.flatnonzero(m_b)[0])
    _, _, _, m_b, _ = blocked.step(a_sel)
    _, _, _, m_f, _ = free.step(a_sel)
    solo_close = CTX.window                   # partition 0: [{1.0},1m] solo
    assert m_b[solo_close] and m_f[solo_close]
    _, r_b, _, _, _ = blocked.step(solo_close)
    _, r_f, _, _, _ = free.step(solo_close)
    assert r_b == pytest.approx(r_f - CTX.ctx_fit_weight)

    ve = VecCoScheduleEnv(CTX)
    st, _, _ = ve.reset_ctx(ve.queue_arrays(queue),
                            dispatch_obs_context(dctx, CTX.window))
    st, _, _, _, _ = ve.step(st, jnp.int32(a_sel))
    _, _, rv, _, _ = ve.step(st, jnp.int32(solo_close))
    assert float(rv) == pytest.approx(r_b, rel=1e-4, abs=1e-3)


def test_age_depth_feature_normalization():
    assert age_feature(0.0) == 0.0
    assert age_feature(1e6 - 1.0) == pytest.approx(1.0)
    assert age_feature(-5.0) == 0.0                       # clamped
    assert depth_feature(0, 8) == 0.0
    assert depth_feature(32, 8) == 1.0
    assert depth_feature(64, 8) == 1.0                    # saturates


# ------------------------------------------------------- widen warm-start

def test_widen_dqn_params_identical_q_at_zero_context():
    agent = DQNAgent(20, 7, DQNConfig(), seed=0)
    wide = widen_dqn_params(agent.params, 6)
    assert wide["w0"].shape[0] == 26
    x = np.random.default_rng(0).normal(size=(4, 20)).astype(np.float32)
    xw = np.concatenate([x, np.zeros((4, 6), np.float32)], axis=1)
    np.testing.assert_allclose(np.asarray(dqn_apply(agent.params, jnp.asarray(x))),
                               np.asarray(dqn_apply(wide, jnp.asarray(xw))),
                               rtol=1e-6, atol=1e-6)


def _ctx_train_cfg(seed=0):
    return TrainConfig(episodes=30, eval_every=15, n_train_queues=4,
                       batch_envs=4, update_every=4, seed=seed,
                       obs_context=True,
                       dqn=DQNConfig(buffer_size=512, batch_size=32,
                                     eps_decay_steps=400))


def test_train_agent_obs_context_deterministic_and_warmstartable():
    env_cfg = EnvConfig(window=4, c_max=3)
    a1, h1 = train_agent(ZOO, env_cfg, _ctx_train_cfg())
    a2, h2 = train_agent(ZOO, env_cfg, _ctx_train_cfg())
    assert h1 == h2
    for x, y in zip(jax.tree.leaves(a1.params), jax.tree.leaves(a2.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # widen a profile-only agent into the context input and keep training
    base_cfg = dataclasses.replace(_ctx_train_cfg(), obs_context=False)
    base, _ = train_agent(ZOO, env_cfg, base_cfg)
    extra = context_dim(dataclasses.replace(env_cfg, obs_context=True))
    warm = DQNAgent(base.params["w0"].shape[0] + extra,
                    base.params["wA"].shape[1], base.cfg, seed=0)
    warm.params = widen_dqn_params(base.params, extra)
    warm.target_params = widen_dqn_params(base.target_params, extra)
    warm.opt = {"m": widen_dqn_params(base.opt["m"], extra),
                "v": widen_dqn_params(base.opt["v"], extra),
                "t": base.opt["t"]}
    a3, h3 = train_agent(ZOO, env_cfg, _ctx_train_cfg(seed=1), warm_start=warm)
    assert h3 and np.isfinite(h3[-1]["eval_throughput"])


# ------------------------------------------- protocol context re-chunking

def test_submission_protocol_rechunks_context():
    """Ages follow the *profiled* subset and later chunks inflate depth."""
    from repro.core.profiles import ProfileRepository

    repo = ProfileRepository()
    jobs = _queue(4, 5)
    for j in jobs[1:]:                     # jobs[0] stays unprofiled
        repo.insert(f"bin://{j.name}#{id(j)}", j)
    paths = ["bin://ghost"] + [f"bin://{j.name}#{id(j)}" for j in jobs[1:]]
    subs = list(zip(paths, [None] * len(paths)))
    ctx = DispatchContext(free_units=(True,) * N_UNITS,
                          ages_s=(99.0, 1.0, 2.0, 3.0, 4.0),
                          queue_depth=10, now_s=0.0)
    seen = []

    def plan(chunk, chunk_ctx):
        seen.append((tuple(j.name for j in chunk), chunk_ctx))
        from repro.core.problem import Schedule
        from repro.core.partition import solo_partition
        s = Schedule()
        for j in chunk:
            s.add([j], solo_partition())
        return s

    submission_protocol(repo, subs, plan, window=3, context=ctx)
    assert len(seen) == 2                  # 4 profiled jobs, window 3
    names1, ctx1 = seen[0]
    names2, ctx2 = seen[1]
    assert len(names1) == 3 and len(names2) == 1
    # the unprofiled ghost's 99.0 age is filtered out
    assert ctx1.ages_s == (1.0, 2.0, 3.0)
    assert ctx2.ages_s == (4.0,)
    # chunk 1 sees the 1 profiled job still waiting behind it
    assert ctx1.queue_depth == 11 and ctx2.queue_depth == 10


def test_rl_scheduler_accepts_context_for_profile_only_agent():
    """A context snapshot must be harmless for a context-blind agent."""
    env_cfg = EnvConfig(window=4, c_max=3)
    agent = DQNAgent(CoScheduleEnv(env_cfg).state_dim,
                     CoScheduleEnv(env_cfg).n_actions, DQNConfig(), seed=0)
    sched = RLScheduler(agent, env_cfg)
    queue = _queue(5, 4)
    ctx = DispatchContext(free_units=(True,) * N_UNITS,
                          ages_s=(0.0,) * 4, queue_depth=0)
    s1 = sched.schedule(queue)
    s2 = sched.schedule(queue, ctx)
    assert [p.label for p in s1.partitions] == [p.label for p in s2.partitions]
