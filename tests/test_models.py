"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, output shapes + no NaNs; decode-path
consistency against the parallel forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model import (
    count_params_analytic,
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    total, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, _batch(cfg))
    assert np.isfinite(float(total)), arch
    # random-init CE should be near ln(vocab)
    assert abs(float(metrics["loss"]) - np.log(cfg.vocab_size)) < 1.0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_grad_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, b, cfg)[0]))(params, _batch(cfg))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B = 2
    cache = init_cache(params, cfg, B, 16)
    tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))(
        params, cache, tok, pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen2.5-14b", "deepseek-moe-16b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits at position t must match the full forward's
    logits at t (same tokens), for attention architectures."""
    import dataclasses

    from repro.models.model import forward_train

    cfg = get_smoke_config(arch).replace(dtype="float32")
    if cfg.moe is not None:
        # ample capacity: the training path's capacity-based dispatch drops
        # tokens under pressure; decode never drops (per-token gather)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, KEY)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = forward_train(params, {"tokens": tokens}, cfg)

    cache = init_cache(params, cfg, B, S)
    step = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))
    for t in range(S):
        logits_t, cache = step(params, cache, tokens[:, t], jnp.full((B,), t))
        np.testing.assert_allclose(
            np.asarray(logits_t), np.asarray(full_logits[:, t]), atol=2e-3, rtol=2e-2)


def test_prefill_matches_decode_continuation():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    params = init_params(cfg, KEY)
    B, S = 2, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_p, cache = jax.jit(lambda p, t: prefill(p, t, cfg, S))(params, tokens)
    # decode from scratch should reproduce the prefill's last-position logits
    cache2 = init_cache(params, cfg, B, S)
    step = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))
    for t in range(S):
        logits_t, cache2 = step(params, cache2, tokens[:, t], jnp.full((B,), t))
    np.testing.assert_allclose(np.asarray(logits_t), np.asarray(logits_p), atol=2e-3, rtol=2e-2)


def test_mlstm_chunked_vs_sequential():
    from repro.models.xlstm import init_mlstm, mlstm_apply, mlstm_sequential

    cfg = get_smoke_config("xlstm-125m").replace(dtype="float32")
    p = init_mlstm(KEY, cfg)
    x = jax.random.normal(KEY, (2, 37, cfg.d_model)) * 0.5
    np.testing.assert_allclose(
        np.asarray(mlstm_apply(p, x, cfg)), np.asarray(mlstm_sequential(p, x, cfg)),
        atol=2e-4, rtol=2e-3)


def test_mamba_decode_vs_parallel():
    from repro.models.mamba import init_mamba, init_mamba_state, mamba_apply, mamba_decode

    cfg = get_smoke_config("jamba-v0.1-52b").replace(dtype="float32")
    p = init_mamba(KEY, cfg)
    B, S = 2, 21
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    y_par = mamba_apply(p, x, cfg)
    st = init_mamba_state(cfg, B)
    ys = []
    for t in range(S):
        yt, st = mamba_decode(p, x[:, t], st, cfg)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_par),
                               atol=2e-4, rtol=2e-3)


def test_unroll_layers_equivalence():
    """Cost-extraction unrolled variant must compute the same function."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    l1 = jax.jit(lambda p, b: loss_fn(p, b, cfg)[0])(params, batch)
    cfg_u = cfg.replace(unroll_layers=True)
    l2 = jax.jit(lambda p, b: loss_fn(p, b, cfg_u)[0])(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-4, rtol=1e-5)


def test_param_counts_match_nameplate():
    import math

    from repro.configs import get_config

    expect = {
        "qwen2.5-14b": 14.8e9, "llama3-8b": 8.0e9, "mistral-nemo-12b": 12.2e9,
        "deepseek-moe-16b": 16.9e9, "jamba-v0.1-52b": 51.6e9, "chameleon-34b": 34.3e9,
    }
    for arch, n in expect.items():
        got = count_params_analytic(get_config(arch))
        assert math.isclose(got, n, rel_tol=0.08), (arch, got, n)
    # MoE active counts
    assert count_params_analytic(get_config("qwen2-moe-a2.7b"), active_only=True) < 3.2e9
    assert count_params_analytic(get_config("jamba-v0.1-52b"), active_only=True) < 13e9
