"""Vectorized pure-functional training engine: parity + determinism.

The contract: ``EnvState.step`` (jitted, float32, in-graph reward model)
reproduces the seed ``CoScheduleEnv`` semantics (Python float64 perfmodel)
transition-for-transition — identical states, masks, and done flags, and
rewards equal to numerical tolerance — and the scanned ``train_agent`` is
bit-deterministic under a fixed seed.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DQNConfig, EnvConfig, TrainConfig, make_zoo, train_agent,
)
from repro.core.agent import act_batch, DQNAgent
from repro.core.env import CoScheduleEnv, VecCoScheduleEnv
from repro.core.replay import replay_init, replay_push, replay_sample
from repro.core.workloads import QUEUE_KINDS, make_queue

ZOO = make_zoo(dryrun_dir=None)


def _rollout_pair(env_cfg, queue, seed):
    """Drive reference + functional envs with the same valid action stream."""
    ref = CoScheduleEnv(env_cfg)
    venv = VecCoScheduleEnv(env_cfg)
    rng = np.random.default_rng(seed)
    s_ref, m_ref = ref.reset(queue)
    st, obs, m = venv.reset(venv.queue_arrays(queue))
    np.testing.assert_allclose(np.asarray(obs), s_ref, atol=1e-6)
    assert np.array_equal(np.asarray(m), m_ref)
    while not ref.done:
        a = int(rng.choice(np.flatnonzero(m_ref)))
        s_ref, r_ref, d_ref, m_ref, _ = ref.step(a)
        st, obs, r, d, m = venv.step(st, jnp.int32(a))
        np.testing.assert_allclose(np.asarray(obs), s_ref, atol=1e-6)
        assert np.array_equal(np.asarray(m), m_ref), "mask diverged"
        assert bool(d) == d_ref, "done diverged"
        assert abs(float(r) - r_ref) <= 1e-3 + 2e-3 * abs(r_ref), (
            float(r), r_ref)


@pytest.mark.parametrize("seed", range(4))
def test_envstate_step_matches_reference_env(seed):
    env_cfg = EnvConfig(window=6, c_max=4)
    rng = np.random.default_rng(seed)
    queue = make_queue(ZOO, QUEUE_KINDS[seed % len(QUEUE_KINDS)], 6, rng)
    _rollout_pair(env_cfg, queue, seed)


def test_envstate_parity_with_padded_window():
    """Queues shorter than W exercise the padding flags and mask rows."""
    env_cfg = EnvConfig(window=8, c_max=3)
    rng = np.random.default_rng(7)
    queue = make_queue(ZOO, "balanced", 5, rng)
    _rollout_pair(env_cfg, queue, 7)


def test_envstate_invalid_action_penalty_and_no_mutation():
    env_cfg = EnvConfig(window=6, c_max=4)
    ref = CoScheduleEnv(env_cfg)
    venv = VecCoScheduleEnv(env_cfg)
    rng = np.random.default_rng(3)
    queue = make_queue(ZOO, "balanced", 6, rng)
    s_ref, m_ref = ref.reset(queue)
    st, obs, m = venv.reset(venv.queue_arrays(queue))
    bad = int(np.flatnonzero(~m_ref)[0])
    s_ref, r_ref, _, m_ref, _ = ref.step(bad)
    st, obs, r, d, m = venv.step(st, jnp.int32(bad))
    assert float(r) == r_ref == env_cfg.invalid_penalty
    np.testing.assert_allclose(np.asarray(obs), s_ref, atol=1e-6)
    assert np.array_equal(np.asarray(m), m_ref)


def test_batched_step_matches_single_step():
    """vmapped reset/step must equal per-env application."""
    env_cfg = EnvConfig(window=6, c_max=4)
    venv = VecCoScheduleEnv(env_cfg)
    rng = np.random.default_rng(0)
    queues = [make_queue(ZOO, k, 6, rng) for k in QUEUE_KINDS]
    qa = venv.queue_batch(queues)
    st_b, obs_b, m_b = venv.reset_batch(qa)
    actions = jnp.asarray([int(np.flatnonzero(np.asarray(m_b[i]))[0])
                           for i in range(len(queues))], jnp.int32)
    st2_b, obs2_b, r_b, d_b, m2_b = venv.step_batch(st_b, actions)
    for i, q in enumerate(queues):
        st, obs, m = venv.reset(venv.queue_arrays(q))
        st2, obs2, r, d, m2 = venv.step(st, actions[i])
        np.testing.assert_allclose(np.asarray(obs2_b[i]), np.asarray(obs2), atol=1e-6)
        np.testing.assert_allclose(float(r_b[i]), float(r), rtol=1e-5, atol=1e-5)
        assert np.array_equal(np.asarray(m2_b[i]), np.asarray(m2))


def test_functional_replay_wraparound():
    """Aligned ring writes wrap and overwrite the oldest block."""
    rs = replay_init(8, 3, 2)
    def block(v, n=4):
        return {"s": jnp.full((n, 3), v, jnp.float32), "a": jnp.full((n,), v, jnp.int32),
                "r": jnp.full((n,), v, jnp.float32), "s2": jnp.full((n, 3), v, jnp.float32),
                "done": jnp.zeros((n,), jnp.float32), "mask2": jnp.ones((n, 2), bool)}
    rs = replay_push(rs, block(1))
    assert int(rs.size) == 4 and int(rs.ptr) == 4
    rs = replay_push(rs, block(2))
    assert int(rs.size) == 8 and int(rs.ptr) == 0
    rs = replay_push(rs, block(3))          # wraps: overwrites block 1
    assert int(rs.size) == 8 and int(rs.ptr) == 4
    vals = set(np.asarray(rs.a).tolist())
    assert vals == {2, 3}, vals
    batch = replay_sample(rs, jax.random.PRNGKey(0), 64)
    assert batch["s"].shape == (64, 3)
    assert set(np.asarray(batch["a"]).tolist()) <= {2, 3}


def test_functional_replay_sample_respects_fill_level():
    rs = replay_init(16, 2, 2)
    rs = replay_push(rs, {"s": jnp.ones((4, 2)), "a": jnp.ones((4,), jnp.int32),
                          "r": jnp.ones((4,)), "s2": jnp.ones((4, 2)),
                          "done": jnp.zeros((4,)), "mask2": jnp.ones((4, 2), bool)})
    batch = replay_sample(rs, jax.random.PRNGKey(1), 32)
    # only the 4 filled rows may be drawn: every sampled action is 1
    assert np.asarray(batch["a"]).min() == 1


def test_unaligned_push_rejected():
    rs = replay_init(8, 3, 2)
    with pytest.raises(AssertionError):
        replay_push(rs, {"s": jnp.zeros((3, 3)), "a": jnp.zeros((3,), jnp.int32),
                         "r": jnp.zeros((3,)), "s2": jnp.zeros((3, 3)),
                         "done": jnp.zeros((3,)), "mask2": jnp.ones((3, 2), bool)})


def test_act_batch_respects_mask_and_explores():
    agent = DQNAgent(12, 6, DQNConfig(), seed=0)
    obs = jnp.zeros((32, 12))
    mask = jnp.tile(jnp.array([[False, True, False, True, False, True]]), (32, 1))
    for eps in (0.0, 1.0):
        a = act_batch(agent.params, jax.random.PRNGKey(0), obs, mask, eps)
        assert bool(np.asarray(mask)[np.arange(32), np.asarray(a)].all()), eps
    # full exploration across keys covers multiple valid actions
    seen = set()
    for k in range(5):
        a = act_batch(agent.params, jax.random.PRNGKey(k), obs, mask, 1.0)
        seen |= set(np.asarray(a).tolist())
    assert seen <= {1, 3, 5} and len(seen) > 1


def _small_cfg(seed=0):
    return TrainConfig(episodes=40, eval_every=20, n_train_queues=4,
                       batch_envs=4, update_every=4, seed=seed,
                       dqn=DQNConfig(buffer_size=512, batch_size=32,
                                     eps_decay_steps=400))


def test_train_agent_deterministic_under_fixed_seed():
    env_cfg = EnvConfig(window=4, c_max=3)
    a1, h1 = train_agent(ZOO, env_cfg, _small_cfg())
    a2, h2 = train_agent(ZOO, env_cfg, _small_cfg())
    assert h1 == h2
    for x, y in zip(jax.tree.leaves(a1.params), jax.tree.leaves(a2.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_train_agent_history_contract():
    env_cfg = EnvConfig(window=4, c_max=3)
    agent, hist = train_agent(ZOO, env_cfg, _small_cfg(seed=1))
    assert hist, "history must not be empty"
    for rec in hist:
        assert set(rec) == {"episode", "eps", "ep_reward", "eval_throughput",
                            "heldout_throughput"}
        # the zoo has held-out jobs, so the generalization metric is live
        assert np.isfinite(rec["heldout_throughput"])
    assert hist[-1]["episode"] >= 40
    assert agent.env_steps > 0 and agent.updates > 0
    # ε decayed from its start value
    assert hist[-1]["eps"] < 1.0


def test_heldout_throughput_none_without_heldout_jobs():
    """heldout=set() (e.g. re-training on a live repository) disables the
    generalization batch instead of crashing or faking a number."""
    env_cfg = EnvConfig(window=4, c_max=3)
    _, hist = train_agent(ZOO, env_cfg, _small_cfg(seed=2), heldout=set())
    assert all(rec["heldout_throughput"] is None for rec in hist)


def test_train_agent_warm_start_copies_and_continues():
    env_cfg = EnvConfig(window=4, c_max=3)
    a1, _ = train_agent(ZOO, env_cfg, _small_cfg())
    snap = [np.asarray(x).copy() for x in jax.tree.leaves(a1.params)]
    a2, h2 = train_agent(ZOO, env_cfg, _small_cfg(seed=3), warm_start=a1)
    assert h2
    # donation must not invalidate or mutate the caller's agent
    for x, y in zip(snap, jax.tree.leaves(a1.params)):
        assert np.array_equal(x, np.asarray(y))
    # warm start actually seeds the run: same seed, different outcome
    a3, _ = train_agent(ZOO, env_cfg, _small_cfg(seed=3))
    diffs = [not np.array_equal(np.asarray(x), np.asarray(y))
             for x, y in zip(jax.tree.leaves(a2.params),
                             jax.tree.leaves(a3.params))]
    assert any(diffs)


def test_train_agent_default_still_validates_job_classes():
    """strict_classes=True (default) keeps the historical guard: a pool
    missing a class fails loudly instead of silently remapping recipes."""
    ci_only = [j for j in ZOO if j.job_class == "CI"]
    env_cfg = EnvConfig(window=4, c_max=3)
    with pytest.raises(ValueError, match="no .* jobs"):
        train_agent(ci_only, env_cfg, _small_cfg(), heldout=set())


def test_train_agent_warm_start_shape_mismatch_rejected():
    env_cfg = EnvConfig(window=4, c_max=3)
    wrong = DQNAgent(10, 5, DQNConfig(), seed=0)
    with pytest.raises(AssertionError, match="warm_start"):
        train_agent(ZOO, env_cfg, _small_cfg(), warm_start=wrong)
