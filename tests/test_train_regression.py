"""Regression: proxy-reward training is bit-unchanged by the queueing path.

The sim-in-the-loop additions (``train_online``, the ``train=True`` engine
mode, the retrainer's ``reward="queueing"`` branch) must be invisible to
the classic offline path: ``train_agent`` with the new code merely
*imported* has to produce bit-identical parameter trajectories to the
pre-PR code (the same pattern as the telemetry-off identity test — a
static flag that is off compiles the exact old program).

Two layers:

* an always-on determinism check — two fresh runs in this process agree
  bit-for-bit, and a run made *after* exercising ``train_online`` still
  agrees (no hidden global state leaks from the new machinery);
* a golden-checkpoint check against ``tests/golden/`` — params/targets
  captured from the pre-PR tree under a pinned tiny config.  Bit-exact
  float reproducibility only holds on the recorded jax version, backend,
  and x64 mode, so mismatching environments skip with a message rather
  than fail (CI pins all three).
"""
import json
import pathlib

import numpy as np
import pytest

import jax

from repro.core import EnvConfig, TrainConfig, make_zoo, train_agent
from repro.core.agent import DQNConfig
from repro.core.train import TrainOnlineConfig, train_online

ZOO = make_zoo(dryrun_dir=None)
GOLDEN = pathlib.Path(__file__).parent / "golden" / "train_agent_proxy_v1.npz"


def _pinned_cfg():
    env_cfg = EnvConfig(window=4)
    cfg = TrainConfig(episodes=24, eval_every=12, seed=7, batch_envs=4,
                      update_every=4, n_train_queues=4, n_heldout_queues=2,
                      dqn=DQNConfig(eps_decay_steps=200, buffer_size=2048,
                                    batch_size=32, target_sync=100))
    return env_cfg, cfg


def _leaves(agent):
    return ([np.asarray(x) for x in jax.tree.leaves(agent.params)],
            [np.asarray(x) for x in jax.tree.leaves(agent.target_params)])


def test_train_agent_deterministic_and_unaffected_by_train_online():
    env_cfg, cfg = _pinned_cfg()
    a0, h0 = train_agent(ZOO, env_cfg, cfg)
    # exercise the new path in between: it must not perturb a rerun
    ocfg = TrainOnlineConfig(rounds=1, traces_per_round=2, n_arrivals=12,
                             capacity=64, population=1, eval_traces=2,
                             updates_per_round=4, window=4,
                             scenarios=(("poisson", 1.2),))
    train_online(ZOO, EnvConfig(window=4), ocfg)
    a1, h1 = train_agent(ZOO, env_cfg, cfg)
    for x, y in zip(*map(lambda a: sum(_leaves(a), []), (a0, a1))):
        np.testing.assert_array_equal(x, y)
    for r0, r1 in zip(h0, h1):
        assert r0["eval_throughput"] == r1["eval_throughput"]
        assert r0["ep_reward"] == r1["ep_reward"]


def test_train_agent_matches_pre_pr_golden_checkpoint():
    with np.load(GOLDEN, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        golden = {k: z[k] for k in z.files if k != "meta"}
    env = {"jax": jax.__version__, "backend": jax.default_backend(),
           "x64": bool(jax.config.jax_enable_x64)}
    pinned = {k: meta[k] for k in env}
    if env != pinned:
        pytest.skip(f"golden pinned to {pinned}, running {env}: bit-exact "
                    f"float reproducibility is only defined on the "
                    f"recorded stack")
    env_cfg, cfg = _pinned_cfg()
    agent, hist = train_agent(ZOO, env_cfg, cfg)
    params, targets = _leaves(agent)
    for i, x in enumerate(params):
        np.testing.assert_array_equal(x, golden[f"param_{i}"], err_msg=(
            f"param leaf {i} drifted from the pre-PR checkpoint — the "
            f"proxy-reward path is no longer bit-unchanged"))
    for i, x in enumerate(targets):
        np.testing.assert_array_equal(x, golden[f"target_{i}"],
                                      err_msg=f"target leaf {i} drifted")
    assert [h["eval_throughput"] for h in hist] == meta["eval_throughput"]
    assert [h["ep_reward"] for h in hist] == meta["ep_reward"]
    assert [h["heldout_throughput"] for h in hist] \
        == meta["heldout_throughput"]
