"""MoE dispatch invariants + equivalence with per-token dense computation."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe_apply, moe_decode, router_topk

KEY = jax.random.PRNGKey(0)


def _cfg(cap=8.0):
    cfg = get_smoke_config("deepseek-moe-16b").replace(dtype="float32")
    moe = cfg.moe
    import dataclasses

    return cfg.replace(moe=dataclasses.replace(moe, capacity_factor=cap))


def _dense_reference(p, x, cfg):
    """Per-token explicit expert computation (no capacity)."""
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    logits = xf.astype(jnp.float32) @ p["router"]
    _, weights, ids = router_topk(logits, m.top_k)
    out = jnp.zeros_like(xf)
    for t in range(T):
        acc = jnp.zeros((xf.shape[1],), xf.dtype)
        for j in range(m.top_k):
            e = int(ids[t, j])
            g = jax.nn.silu(xf[t] @ p["experts_wg"][e])
            u = xf[t] @ p["experts_wu"][e]
            acc = acc + weights[t, j] * ((g * u) @ p["experts_wd"][e])
        out = out.at[t].set(acc)
    out = out.reshape(x.shape)
    if "shared" in p:
        from repro.models.mlp import swiglu_apply

        out = out + swiglu_apply(p["shared"], x)
    return out


def test_moe_matches_dense_reference_when_capacity_ample():
    cfg = _cfg(cap=8.0)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 6, cfg.d_model), jnp.float32) * 0.5
    out, aux = moe_apply(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-3)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_decode_matches_dense_reference():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (4, cfg.d_model), jnp.float32) * 0.5
    out = moe_decode(p, x, cfg)
    ref = _dense_reference(p, x[:, None, :], cfg)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_tokens():
    cfg = _cfg(cap=0.1)  # starve capacity
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0
    assert bool(jnp.isfinite(out).all())


@given(seed=st.integers(0, 2**30))
@settings(max_examples=10)
def test_router_topk_properties(seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (32, 8))
    probs, weights, ids = router_topk(logits, 3)
    assert bool((weights >= 0).all())
    np.testing.assert_allclose(np.asarray(weights.sum(-1)), 1.0, atol=1e-5)
    # ids are distinct per token
    assert all(len(set(np.asarray(ids)[t])) == 3 for t in range(32))


def test_aux_loss_balanced_router_is_minimal():
    """Uniform routing minimizes the load-balance loss (property: loss >= 1)."""
    from repro.models.moe import load_balance_loss

    T, E, K = 256, 8, 2
    probs = jnp.full((T, E), 1.0 / E)
    ids = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], 1)
    lb = float(load_balance_loss(probs, ids, E))
    np.testing.assert_allclose(lb, 1.0, atol=1e-3)
    # skewed routing is penalized
    ids_skew = jnp.zeros((T, K), jnp.int32)
    probs_skew = jnp.zeros((T, E)).at[:, 0].set(1.0)
    assert float(load_balance_loss(probs_skew, ids_skew, E)) > 2.0
