"""Prioritized replay (pure-JAX sum-tree) + device-resident evaluation.

Pins the PER contract from three sides: the sum-tree itself (sampling
frequencies track priorities, IS weights normalize to max 1, priorities
survive ring wraparound), the uniform-equivalence guarantee (``alpha == 0``
bit-matches the uniform sampler; the weighted update with unit weights
bit-matches the unweighted one; the forced-PER engine bit-matches the
uniform engine end-to-end), and the numpy mirror (identical tree layout and
queries, so the scalar loop's prioritized path is the same distribution).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DQNConfig, EnvConfig, TrainConfig, make_zoo, train_agent,
)
from repro.core.agent import DQNAgent, _dqn_update, _dqn_update_per, beta_at
from repro.core.env import VecCoScheduleEnv
from repro.core.metrics import relative_throughput
from repro.core.replay import (
    PrioritizedReplayBuffer, _tree_query, per_init, per_push, per_sample,
    per_update, replay_init, replay_sample,
)
from repro.core.scheduler import RLScheduler
from repro.core.train import _build_eval
from repro.core.workloads import QUEUE_KINDS, make_queue

ZOO = make_zoo(dryrun_dir=None)


def _block(v, n=4, dim=3, acts=2):
    return {"s": jnp.full((n, dim), v, jnp.float32),
            "a": jnp.full((n,), v, jnp.int32),
            "r": jnp.full((n,), v, jnp.float32),
            "s2": jnp.full((n, dim), v, jnp.float32),
            "done": jnp.zeros((n,), jnp.float32),
            "mask2": jnp.ones((n, acts), bool)}


def _filled_per(capacity=8, priorities=None):
    ps = per_init(capacity, 3, 2)
    for v in range(capacity // 4):
        ps = per_push(ps, _block(v + 1))
    if priorities is not None:
        idx = jnp.arange(capacity)
        ps = per_update(ps, idx, jnp.asarray(priorities, jnp.float32),
                        alpha=1.0, eps=0.0)
    return ps


# ---------------------------------------------------------------- sum-tree

def test_sum_tree_root_is_total_mass():
    ps = _filled_per(8, priorities=[1, 2, 3, 4, 5, 6, 7, 8])
    assert np.isclose(float(ps.tree[1]), 36.0)
    leaves = np.asarray(ps.tree[8:16])
    np.testing.assert_allclose(leaves, np.arange(1, 9, dtype=np.float32))


def test_sampling_frequencies_match_priorities():
    pri = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.float32)
    ps = _filled_per(8, priorities=pri)
    counts = np.zeros(8)
    n, rounds = 256, 16
    for k in range(rounds):
        _, idx, _ = per_sample(ps, jax.random.PRNGKey(k), n, alpha=1.0, beta=0.4)
        counts += np.bincount(np.asarray(idx), minlength=8)
    freq = counts / (n * rounds)
    np.testing.assert_allclose(freq, pri / pri.sum(), atol=0.02)


def test_is_weights_normalized_and_correct():
    pri = np.array([1, 2, 3, 4, 5, 6, 7, 8], np.float32)
    ps = _filled_per(8, priorities=pri)
    beta = 0.7
    _, idx, w = per_sample(ps, jax.random.PRNGKey(3), 64, alpha=1.0, beta=beta)
    w, idx = np.asarray(w), np.asarray(idx)
    assert np.isclose(w.max(), 1.0)
    probs = pri[idx] / pri.sum()
    expect = (8 * probs) ** (-beta)
    np.testing.assert_allclose(w, expect / expect.max(), rtol=1e-4)


def test_alpha_zero_bit_matches_uniform_sampler():
    ps = _filled_per(8)
    key = jax.random.PRNGKey(11)
    batch, idx, w = per_sample(ps, key, 32, alpha=0.0, beta=0.4)
    ref = replay_sample(ps.ring, key, 32)
    for f, v in ref.items():
        assert np.array_equal(np.asarray(batch[f]), np.asarray(v)), f
    assert np.all(np.asarray(w) == 1.0)


def test_priorities_survive_ring_wraparound():
    ps = per_init(8, 3, 2)
    ps = per_push(ps, _block(1))
    ps = per_push(ps, _block(2))
    ps = per_update(ps, jnp.arange(4, 8), jnp.array([0.5, 0.6, 0.7, 0.8]),
                    alpha=1.0, eps=0.0)
    ps = per_push(ps, _block(3))            # wraps: overwrites slots 0..3
    leaves = np.asarray(ps.tree[8:16])
    np.testing.assert_allclose(leaves[4:], [0.5, 0.6, 0.7, 0.8])
    # the overwritten block re-enters at the running max priority (1.0)
    np.testing.assert_allclose(leaves[:4], 1.0)
    assert np.isclose(float(ps.tree[1]), leaves.sum())
    assert set(np.asarray(ps.ring.a).tolist()) == {2, 3}


def test_tree_query_never_returns_zero_mass_leaf():
    ps = per_init(8, 3, 2)
    ps = per_push(ps, _block(1))            # only slots 0..3 filled
    _, idx, _ = per_sample(ps, jax.random.PRNGKey(0), 64, alpha=1.0, beta=0.4)
    assert np.asarray(idx).max() < 4


def test_incremental_ancestor_updates_bit_match_full_rebuild():
    """per_push/per_update recompute only ancestor paths (O(B log C)); the
    result must be bit-identical to a from-scratch rebuild of the same
    leaves — every touched node is the exact sum of its children, so no
    float32 drift can accumulate either."""
    from repro.core.replay import _tree_rebuild

    rng = np.random.default_rng(0)
    for capacity in (8, 12, 32):           # 12: leaves > capacity (padding)
        ps = per_init(capacity, 3, 2)
        for step in range(12):
            if step % 2 == 0:
                ps = per_push(ps, _block(step + 1))
            else:
                n_idx = int(rng.integers(1, 6))
                idx = jnp.asarray(rng.integers(0, capacity, size=n_idx))
                td = jnp.asarray(rng.gamma(1.0, 2.0, size=n_idx), jnp.float32)
                ps = per_update(ps, idx, td, alpha=0.7, eps=1e-3)
            rebuilt = np.asarray(_tree_rebuild(ps.tree))
            assert np.array_equal(np.asarray(ps.tree), rebuilt), (
                capacity, step)


def test_sample_empty_ring_asserts():
    rs = replay_init(8, 2, 2)
    with pytest.raises(AssertionError):
        replay_sample(rs, jax.random.PRNGKey(0), 4)
    ps = per_init(8, 2, 2)
    with pytest.raises(AssertionError):
        per_sample(ps, jax.random.PRNGKey(0), 4, alpha=0.6, beta=0.4)


# ------------------------------------------------------------ numpy mirror

def test_numpy_mirror_matches_jax_tree():
    ps = _filled_per(8, priorities=[1, 2, 3, 4, 5, 6, 7, 8])
    buf = PrioritizedReplayBuffer(8, 3, 2, alpha=1.0, eps=0.0)
    for v in range(2):
        for _ in range(4):
            buf.push(np.full(3, v + 1), v + 1, v + 1, np.full(3, v + 1),
                     0.0, np.ones(2, bool))
    buf.update_priorities(np.arange(8), np.arange(1, 9, dtype=np.float64))
    np.testing.assert_allclose(np.asarray(ps.tree), buf.tree, rtol=1e-6)
    # identical descent for targets placed away from segment boundaries
    targets = np.cumsum([1, 2, 3, 4, 5, 6, 7, 8]) - 0.5
    jidx = np.asarray(_tree_query(ps.tree, jnp.asarray(targets, jnp.float32)))
    nidx = np.array([buf._query(t) for t in targets])
    assert np.array_equal(jidx, nidx)
    assert np.array_equal(jidx, np.arange(8))


def test_beta_anneals_to_one():
    assert beta_at(0.4, 0, 100) == pytest.approx(0.4)
    assert beta_at(0.4, 50, 100) == pytest.approx(0.7)
    assert beta_at(0.4, 100, 100) == pytest.approx(1.0)
    assert beta_at(0.4, 10**9, 100) == pytest.approx(1.0)
    assert float(beta_at(0.4, jnp.int32(50), 100)) == pytest.approx(0.7)


# ------------------------------------------------- uniform-equivalence path

def test_weighted_update_with_unit_weights_bit_matches_uniform():
    agent = DQNAgent(24, 6, DQNConfig(batch_size=16), seed=0)
    k = jax.random.PRNGKey(5)
    ks = jax.random.split(k, 4)
    batch = {
        "s": jax.random.normal(ks[0], (16, 24)),
        "a": jax.random.randint(ks[1], (16,), 0, 6),
        "r": jax.random.normal(ks[2], (16,)) * 10.0,
        "s2": jax.random.normal(ks[3], (16, 24)),
        "done": jnp.zeros((16,)),
        "mask2": jnp.ones((16, 6), bool),
    }
    p1, o1, l1 = _dqn_update(agent.params, agent.target_params, agent.opt,
                             batch, agent.cfg)
    p2, o2, l2, td = _dqn_update_per(agent.params, agent.target_params,
                                     agent.opt, batch, jnp.ones((16,)),
                                     agent.cfg)
    assert float(l1) == float(l2)
    for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert np.all(np.isfinite(np.asarray(td)))


def _small_cfg(seed=0, **kw):
    return TrainConfig(episodes=40, eval_every=20, n_train_queues=4,
                       batch_envs=4, update_every=4, seed=seed,
                       dqn=DQNConfig(buffer_size=512, batch_size=32,
                                     eps_decay_steps=400), **kw)


def test_per_alpha_zero_engine_matches_uniform_engine_bit_exactly():
    """Regression parity: the PER machinery at alpha=0 IS the uniform engine."""
    env_cfg = EnvConfig(window=4, c_max=3)
    a_uni, h_uni = train_agent(ZOO, env_cfg, _small_cfg())
    a_per, h_per = train_agent(ZOO, env_cfg, _small_cfg(), _force_per=True)
    assert h_uni == h_per
    for x, y in zip(jax.tree.leaves(a_uni.params), jax.tree.leaves(a_per.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_prioritized_training_runs_and_decays():
    env_cfg = EnvConfig(window=4, c_max=3)
    agent, hist = train_agent(ZOO, env_cfg, _small_cfg(per_alpha=0.6))
    assert hist and hist[-1]["episode"] >= 40
    for rec in hist:
        assert set(rec) == {"episode", "eps", "ep_reward", "eval_throughput",
                            "heldout_throughput"}
        assert np.isfinite(rec["ep_reward"]) and np.isfinite(rec["eval_throughput"])
    assert hist[-1]["eps"] < 1.0
    assert agent.per_alpha == 0.6


def test_scalar_prioritized_buffer_drives_updates():
    """The numpy mirrored path trains: sample -> weighted update -> re-rank."""
    agent = DQNAgent(24, 6, DQNConfig(batch_size=8, buffer_size=64),
                     seed=0, per_alpha=0.6)
    rng = np.random.default_rng(0)
    for _ in range(16):
        agent.observe(rng.normal(size=24).astype(np.float32), 1, 1.0,
                      rng.normal(size=24).astype(np.float32), False,
                      np.ones(6, bool))
    assert isinstance(agent.replay, PrioritizedReplayBuffer)
    loss = agent.update()
    assert loss is not None and np.isfinite(loss)
    # TD-driven priorities replaced the entry max: leaves now differ
    leaves = agent.replay.tree[agent.replay.leaves:agent.replay.leaves + 16]
    assert len(np.unique(leaves.round(9))) > 1


# ------------------------------------------------- device-resident eval

def test_device_eval_matches_scalar_scheduler_throughput():
    """The jitted step_batch eval reproduces the Python RLScheduler metric."""
    env_cfg = EnvConfig(window=6, c_max=4)
    venv = VecCoScheduleEnv(env_cfg)
    agent = DQNAgent(venv.state_dim, venv.n_actions, DQNConfig(), seed=2)
    rng = np.random.default_rng(2)
    queues = [make_queue(ZOO, QUEUE_KINDS[i % len(QUEUE_KINDS)], 6, rng)
              for i in range(5)]
    qa = venv.queue_batch(queues)
    eval_fn = _build_eval(venv)
    env, obs, mask = venv.reset_batch(qa)
    tp = np.asarray(eval_fn(agent.params, env, obs, mask))
    sched = RLScheduler(agent, env_cfg)
    ref = np.array([relative_throughput(sched.schedule(q)) for q in queues])
    np.testing.assert_allclose(tp, ref, rtol=5e-3)
