"""Sim-in-the-loop training (`train_online`): stitching, PBT, updaters.

The fuzz suite (``test_queueing_reward``) pins the engine-side invariant
— buckets equal serving totals; this file covers the host-side machinery
built on top of it: transition stitching (reward attribution, terminal
handling, no-decision-window folding), the jitted update loop's target
sync, population-based training exploit/explore, the warm-start elitism
guard, config validation, and the retrainer's ``reward="queueing"``
branch.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import EnvConfig, make_zoo
from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import CoScheduleEnv
from repro.core.replay import replay_init, replay_push
from repro.core.train import (
    TrainOnlineConfig, _online_updater, _stitch_transitions, train_online,
)
from repro.online import (
    ClusterSimulator, OnlineRetrainer, TrainRollout, poisson_trace,
)
from repro.online.policies import RLDispatchPolicy
from repro.online.retrain import default_retrain_online_config

ZOO = make_zoo(dryrun_dir=None)
ENV_CFG = EnvConfig(window=4)
_ENV = CoScheduleEnv(ENV_CFG)


def _tiny_cfg(**kw):
    base = dict(rounds=2, traces_per_round=2, n_arrivals=16, capacity=64,
                window=4, population=1, eval_traces=2, updates_per_round=8,
                eps_decay_rounds=2, scenarios=(("poisson", 1.2),),
                dqn=DQNConfig(buffer_size=2048, batch_size=32,
                              eps_decay_steps=500))
    base.update(kw)
    return TrainOnlineConfig(**base)


# ------------------------------------------------------------- stitching

def _mk_roll(valid, w_wait, w_turn, n_act=4, d=3):
    a_cap, t_ep = valid.shape
    rng = np.random.default_rng(0)
    return TrainRollout(
        obs=rng.standard_normal((a_cap, t_ep, d)).astype(np.float32),
        act=rng.integers(0, n_act, (a_cap, t_ep)).astype(np.int32),
        mask=np.ones((a_cap, t_ep, n_act), bool),
        valid=valid, w_wait=np.asarray(w_wait, np.float32),
        w_turn=np.asarray(w_turn, np.float32))


def test_stitch_rewards_fold_and_terminate():
    valid = np.array([[1, 0], [0, 0], [1, 1], [1, 1]], bool)  # win3 unused
    roll = _mk_roll(valid, [10.0, 20.0, 30.0, 99.0], [0.0] * 4)
    cfg = TrainOnlineConfig(n_arrivals=10, wait_weight=1.0,
                            turnaround_weight=0.0, makespan_weight=1.0)
    tx = _stitch_transitions(roll, n_windows=3, makespan=50.0, cfg=cfg)
    assert len(tx["a"]) == 3
    # window 1 had no decisions: its bucket folds into window 0's last
    # decision; window 2's bucket + terminal makespan land on the close
    np.testing.assert_allclose(tx["r"], [-3.0, 0.0, -8.0], atol=1e-6)
    np.testing.assert_array_equal(tx["done"], [0.0, 0.0, 1.0])
    # s2 chains decisions across windows; the terminal row is zeros with
    # an all-False mask (the TD target's terminal encoding)
    np.testing.assert_array_equal(tx["s2"][0], tx["s"][1])
    assert not tx["s2"][-1].any() and not tx["mask2"][-1].any()
    np.testing.assert_array_equal(tx["s"][0], roll.obs[0, 0])
    np.testing.assert_array_equal(tx["s"][2], roll.obs[2, 1])
    assert tx["a"][1] == roll.act[2, 0]


def test_stitch_leading_windows_fold_forward():
    valid = np.array([[0, 0], [1, 0]], bool)
    roll = _mk_roll(valid, [5.0, 7.0], [1.0, 1.0])
    cfg = TrainOnlineConfig(n_arrivals=1, wait_weight=1.0,
                            turnaround_weight=2.0, makespan_weight=0.0)
    tx = _stitch_transitions(roll, n_windows=2, makespan=9.0, cfg=cfg)
    assert len(tx["a"]) == 1
    np.testing.assert_allclose(tx["r"], [-(5 + 7) - 2.0 * (1 + 1)],
                               atol=1e-5)


def test_stitch_no_decisions_returns_none():
    roll = _mk_roll(np.zeros((2, 2), bool), [1.0, 2.0], [0.0, 0.0])
    assert _stitch_transitions(roll, 2, 3.0, TrainOnlineConfig()) is None


# ---------------------------------------------------------- update engine

def test_online_updater_steps_and_syncs_target():
    d, n_act = 6, 3
    agent = DQNAgent(d, n_act, DQNConfig(batch_size=8, buffer_size=64),
                     seed=0)
    ring = replay_init(64, d, n_act)
    rng = np.random.default_rng(1)
    batch = {"s": jnp.asarray(rng.standard_normal((32, d)), jnp.float32),
             "a": jnp.asarray(rng.integers(0, n_act, 32), jnp.int32),
             "r": jnp.asarray(rng.standard_normal(32), jnp.float32),
             "s2": jnp.asarray(rng.standard_normal((32, d)), jnp.float32),
             "done": jnp.zeros(32, jnp.float32),
             "mask2": jnp.ones((32, n_act), bool)}
    ring = replay_push(ring, batch)
    upd = _online_updater(agent.cfg, n_updates=4, sync_updates=1, per=None)
    params, target, opt, ring2, _, updates = upd(
        agent.params, agent.target_params, agent.opt, ring,
        jax.random.PRNGKey(0), jnp.int32(0), jnp.float32(0.4))
    assert int(updates) == 4
    # params moved, and with sync every update the target tracks them
    assert any(not np.array_equal(np.asarray(a), np.asarray(b)) for a, b in
               zip(jax.tree.leaves(agent.params), jax.tree.leaves(params)))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(target)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ train_online

def test_train_online_population_pbt_and_history():
    cfg = _tiny_cfg(rounds=4, population=3, pbt_interval=2,
                    scenarios=(("poisson", 1.2), ("mmpp", 1.3)))
    agent, hist = train_online(ZOO, ENV_CFG, cfg)
    assert len(hist) == 4
    assert all(len(r["scores"]) == 3 for r in hist)
    assert any("pbt" in r for r in hist)            # exploit/explore fired
    assert "selected" in hist[-1] and "final_scores" in hist[-1]
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(agent.params))


def test_train_online_deterministic():
    cfg = _tiny_cfg()
    a0, h0 = train_online(ZOO, ENV_CFG, cfg)
    a1, h1 = train_online(ZOO, ENV_CFG, cfg)
    for x, y in zip(jax.tree.leaves(a0.params), jax.tree.leaves(a1.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [r["scores"] for r in h0] == [r["scores"] for r in h1]


def test_train_online_per_path():
    agent, hist = train_online(ZOO, ENV_CFG, _tiny_cfg(per_alpha=0.6))
    assert hist and np.isfinite(hist[-1]["best_p99"])


def test_train_online_warm_start_elitism_guard():
    warm = DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=5)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(warm.params)]
    cfg = _tiny_cfg(rounds=1, updates_per_round=2)
    agent, hist = train_online(ZOO, ENV_CFG, cfg, warm_start=warm)
    sel = hist[-1]["selected"]
    assert sel == "warm_start" or isinstance(sel, int)
    if sel == "warm_start":
        for x, y in zip(before, jax.tree.leaves(agent.params)):
            np.testing.assert_array_equal(x, np.asarray(y))
    # warm start copied, never donated
    for x, y in zip(before, jax.tree.leaves(warm.params)):
        np.testing.assert_array_equal(x, np.asarray(y))


def test_train_online_validates_config():
    with pytest.raises(ValueError, match="serve window"):
        train_online(ZOO, EnvConfig(window=4), _tiny_cfg(window=8))
    with pytest.raises(ValueError, match="unknown trace family"):
        train_online(ZOO, ENV_CFG,
                     _tiny_cfg(scenarios=(("nope", 1.0),)))


# --------------------------------------------------------------- retrainer

def test_retrainer_queueing_reward_refresh():
    trace = poisson_trace(ZOO, n=24, load=1.3, seed=7)
    agent = DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0)
    pol = RLDispatchPolicy(agent, ENV_CFG)
    ocfg = _tiny_cfg(rounds=1, updates_per_round=4)
    rt = OnlineRetrainer(policy=pol, reward="queueing", online_cfg=ocfg,
                         interval_s=trace[-1].t / 2.0, min_jobs=3)
    res = ClusterSimulator(pol, window=4, tick_interval_s=rt.interval_s,
                           on_tick=rt).run(trace)
    assert res.ticks >= 1 and len(rt.history) >= 1
    for h in rt.history:
        assert h["rounds"] >= 1
        assert np.isfinite(h["train_eval_p99_wait"])
        assert "train_eval_throughput" not in h


def test_retrainer_rejects_unknown_reward():
    pol = RLDispatchPolicy(
        DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0), ENV_CFG)
    with pytest.raises(ValueError, match="unknown reward"):
        OnlineRetrainer(policy=pol, reward="bogus")


def test_default_retrain_online_config_shape():
    cfg = default_retrain_online_config(rounds=5)
    assert cfg.rounds == 5 and cfg.population == 1
    assert cfg.eps_decay_rounds >= 1
