"""End-to-end behaviour tests for the paper's system.

The headline claim (paper Fig. 8): the RL co-scheduler produces valid
schedules whose throughput beats time sharing and approaches the exhaustive
oracle; plus a real end-to-end train loop with checkpoint/restart.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    EnvConfig,
    POLICIES,
    RLScheduler,
    TrainConfig,
    make_zoo,
    paper_queues,
    summarize,
    train_agent,
    validate_schedule,
)
from repro.core.agent import DQNConfig


@pytest.fixture(scope="module")
def trained():
    zoo = make_zoo(dryrun_dir=None)
    env_cfg = EnvConfig(window=6, c_max=4)
    agent, hist = train_agent(
        zoo, env_cfg,
        TrainConfig(episodes=400, eval_every=200, n_train_queues=8,
                    dqn=DQNConfig(eps_decay_steps=2500)),
    )
    return zoo, env_cfg, agent


def test_rl_beats_time_sharing_and_respects_constraints(trained):
    zoo, env_cfg, agent = trained
    sched = RLScheduler(agent, env_cfg)
    queues = paper_queues(zoo, window=6, per_kind=1)
    tps = []
    for queue in queues.values():
        s = sched.schedule(queue)
        validate_schedule(queue, s, env_cfg.c_max)
        tps.append(summarize(s)["throughput"])
    assert float(np.mean(tps)) > 1.1, tps   # clearly better than time sharing


def test_rl_within_oracle_envelope(trained):
    zoo, env_cfg, agent = trained
    sched = RLScheduler(agent, env_cfg)
    queues = paper_queues(zoo, window=6, per_kind=1)
    for queue in queues.values():
        tp_rl = summarize(sched.schedule(queue))["throughput"]
        tp_or = summarize(POLICIES["oracle"](queue, env_cfg.c_max))["throughput"]
        assert tp_rl <= tp_or + 1e-6        # oracle is the upper bound


def test_training_improves_over_untrained(trained):
    zoo, env_cfg, agent = trained
    from repro.core import DQNAgent
    from repro.core.env import CoScheduleEnv

    env = CoScheduleEnv(env_cfg)
    fresh = DQNAgent(env.state_dim, env.n_actions, DQNConfig(), seed=123)
    queues = paper_queues(zoo, window=6, per_kind=1)
    tp_trained, tp_fresh = [], []
    for queue in queues.values():
        tp_trained.append(summarize(RLScheduler(agent, env_cfg).schedule(queue))["throughput"])
        tp_fresh.append(summarize(RLScheduler(fresh, env_cfg).schedule(queue))["throughput"])
    assert np.mean(tp_trained) >= np.mean(tp_fresh) - 0.05


def test_end_to_end_tiny_training_loop(tmp_path):
    """Real model + optimizer + data + checkpoint: loss decreases, resume works."""
    from repro.configs import get_smoke_config
    from repro.data import DataPipeline
    from repro.models.model import init_params, loss_fn
    from repro.optim import OptConfig, adamw_update, init_opt_state
    from repro import checkpoint as ck

    cfg = get_smoke_config("llama3-8b")
    pipe = DataPipeline(cfg.vocab_size, 32, 16, seed=0, mode="markov")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = OptConfig(lr=5e-3, warmup_steps=5, decay_steps=300, clip_norm=1.0)

    @jax.jit
    def step(params, opt, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
        params, opt, om = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, metrics["loss"]

    losses = []
    for s in range(45):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if s == 19:
            ck.save(str(tmp_path), s, {"params": params}, extra={"data_step": s})
    assert min(losses[-5:]) < losses[0] - 0.25, losses[:3] + losses[-5:]

    # restart path: restore and continue deterministically
    tree, extra, s0 = ck.restore(str(tmp_path))
    assert s0 == 19 and extra["data_step"] == 19
