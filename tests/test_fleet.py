"""Fleet-scale serving: N-pod claim model, routers, unified dispatch API.

Contracts pinned here:

* **Pod isolation** — a claim never spans pods: every Segment's slice
  ranges lie inside its pod's width, and a job's record carries the pod
  the router assigned it.
* **Router determinism** — routers are deterministic functions of
  (arrival, view, seed): same seed replays identical assignments, a
  different hash seed produces a different (but still deterministic)
  spread, and width eligibility never lands a request on a too-narrow
  pod.
* **Single-pod parity** — ``SimConfig(pods=(8,))`` (and the legacy
  keyword construction path) is bit-identical to the historical
  single-pod simulator on every trace family.
* **Unified dispatch API** — ``decide()`` is the one entry point; the
  ``dispatch()``/``placements()`` shims raise ``DeprecationWarning`` but
  return the same plans, and legacy subclass overrides of either are
  still honored through ``decide()``.
* **Vectorized fleet parity** — the hash-routed fleet decomposes into
  independent per-pod lanes, so ``VectorizedFleetSimulator`` matches the
  heap fleet's decisions exactly and its clock to float32.
"""
import dataclasses
import math

import numpy as np
import pytest

from strategies import ZOO, make_trace

from repro.core.partition import N_UNITS, Partition, Slice, slice_label
from repro.core.scheduler import DispatchDecision
from repro.online import (
    ClusterSimulator, SimConfig, TRACE_FAMILIES, TimeSharingPolicy,
    VectorizedFleetSimulator, make_router, poisson_trace,
)
from repro.online.policies import DispatchPolicy, GreedyPackerPolicy
from repro.online.router import (
    FleetView, PodView, fragmentation_units,
)

HET = (8, 8, 4, 4)          # the heterogeneous fleet under test


def _trace(n=80, seed=3, load=1.0, pods=HET, fam="fragmented"):
    return make_trace(fam, n, seed, load, capacity=sum(pods) / N_UNITS)


def _run(pods=HET, router="frag", seed=0, trace=None, policy=None):
    cfg = SimConfig(pods=pods, router=router, router_seed=seed)
    sim = ClusterSimulator(policy or TimeSharingPolicy(), cfg)
    return sim.run(trace if trace is not None else _trace(pods=pods))


# ----------------------------------------------------------- pod isolation

@pytest.mark.parametrize("router", ["hash", "least_loaded", "frag"])
def test_claims_never_span_pods(router):
    res = _run(router=router)
    assert res.pods == HET
    for seg in res.timeline:
        width = res.pods[seg.pod]
        for start, w in seg.slices:
            assert 0 <= start and start + w <= width, (seg.pod, seg.slices)


@pytest.mark.parametrize("router", ["hash", "least_loaded", "frag"])
def test_every_job_served_on_an_eligible_pod(router):
    res = _run(router=router)
    for rec in res.jobs:
        assert 0 <= rec.pod < len(res.pods)
        assert not math.isnan(rec.finish)
        # the slice the job ran on fits its pod
        assert rec.units <= res.pods[rec.pod]


def test_slice_busy_spans_fleet_axis_and_upper_units_stay_idle():
    res = _run()
    assert len(res.slice_busy_s) == sum(HET)
    # per-pod busy never exceeds what the pod's units could serve
    offs = res.pod_offsets
    m = res.makespan
    for p, w in enumerate(HET):
        for u in range(w):
            assert res.slice_busy_s[offs[p] + u] <= m + 1e-6


# ------------------------------------------------------- router determinism

def test_router_fixed_seed_replays_identically():
    a = _run(router="hash", seed=7)
    b = _run(router="hash", seed=7)
    assert [r.pod for r in a.jobs] == [r.pod for r in b.jobs]
    assert a.summary() == b.summary()


def test_hash_router_seed_changes_assignment():
    a = _run(router="hash", seed=0)
    b = _run(router="hash", seed=1)
    assert [r.pod for r in a.jobs] != [r.pod for r in b.jobs]


def test_hash_router_is_tenant_affine_and_width_eligible():
    res = _run(router="hash")
    by_binary = {}
    for rec in res.jobs:
        assert by_binary.setdefault(rec.binary, rec.pod) == rec.pod
    # full-width requests never land on a narrow pod
    router = make_router("hash")
    view = FleetView(pods=tuple(
        PodView(idx=i, width=w, free=(True,) * w, pending=0, ready=0,
                queue_units=0, busy_units=0) for i, w in enumerate(HET)))
    for a in _trace():
        p = router.route(a, view)
        assert HET[p] >= min(a.profile.requested_units, N_UNITS)


def test_frag_router_prefers_snug_pod_for_mice():
    # an empty 4-pod fragments less than an empty 8-pod under a 1-unit job
    empty4 = (True,) * 4
    empty8 = (True,) * 8
    after4 = (False,) + (True,) * 3
    after8 = (False,) + (True,) * 7
    d4 = fragmentation_units(after4) - fragmentation_units(empty4)
    d8 = fragmentation_units(after8) - fragmentation_units(empty8)
    assert d4 < d8


# ------------------------------------------------------- single-pod parity

@pytest.mark.parametrize("family", sorted(TRACE_FAMILIES))
def test_single_pod_fleet_bit_matches_legacy_simulator(family):
    trace = TRACE_FAMILIES[family](ZOO, n=40, seed=2, load=1.25)
    legacy = ClusterSimulator(TimeSharingPolicy(), window=8).run(trace)
    fleet = ClusterSimulator(
        TimeSharingPolicy(), SimConfig(pods=(N_UNITS,))).run(trace)
    assert legacy.summary() == fleet.summary()
    assert [(r.dispatch, r.finish, r.units) for r in legacy.jobs] == \
           [(r.dispatch, r.finish, r.units) for r in fleet.jobs]


def test_capacity_scaled_poisson_halves_interarrivals_exactly():
    t1 = poisson_trace(ZOO, n=30, seed=4, load=1.0, capacity=1.0)
    t2 = poisson_trace(ZOO, n=30, seed=4, load=1.0, capacity=2.0)
    assert np.allclose([a.t for a in t2],
                       [a.t / 2.0 for a in t1], rtol=0, atol=0)
    assert [a.binary for a in t1] == [a.binary for a in t2]


# ----------------------------------------------------------- configuration

def test_simconfig_is_frozen_and_validates():
    cfg = SimConfig(pods=[8, 4])            # lists coerce to tuples
    assert cfg.pods == (8, 4)
    assert cfg.n_pods == 2 and cfg.total_units == 12
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.window = 3
    with pytest.raises(AssertionError):
        SimConfig(pods=(4, 4))              # widest pod must be full-width
    with pytest.raises(AssertionError):
        SimConfig(pods=(8, 3))              # MIG-valid widths only
    with pytest.raises(AssertionError):
        SimConfig(pods=(8, 4), mode="blocking")   # blocking is full-width
    with pytest.raises(AssertionError):
        SimConfig(router="nope") and make_router("nope")


def test_summary_schema_v2_records_fleet_fields():
    s = _run(router="least_loaded").summary()
    assert s["schema"] == 2
    assert s["n_pods"] == len(HET) and s["pods"] == list(HET)
    assert s["router"] == "least_loaded"
    assert "refits" in s and "p99_wait_s" in s


# ------------------------------------------------------ unified decide API

def test_decide_matches_deprecated_shims():
    trace = _trace(n=20, pods=(N_UNITS,))
    subs = [(a.binary, a.profile) for a in trace[:6]]
    p1, p2 = TimeSharingPolicy(), TimeSharingPolicy()
    dec = p1.decide(subs)
    with pytest.warns(DeprecationWarning):
        sched = p2.dispatch(subs)
    assert dec.schedule is not None
    from repro.core.scheduler import to_placements
    assert [pl.partition.label for pl in dec.placements] == \
           [pl.partition.label for pl in to_placements(sched)]
    assert dec.first_sight + dec.planned == len(subs)
    with pytest.warns(DeprecationWarning):
        pls = TimeSharingPolicy().placements(subs)
    assert [pl.partition.label for pl in pls] == \
           [pl.partition.label for pl in dec.placements]


def test_decide_itself_never_warns():
    """The unified entry point must stay warning-free: only the
    ``dispatch()``/``placements()`` shims are deprecated, and a policy
    without legacy overrides routes straight through ``decide()``."""
    import warnings

    subs = [(a.binary, a.profile) for a in _trace(n=6, pods=(N_UNITS,))]
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        dec = TimeSharingPolicy().decide(subs)
    assert dec.schedule is not None and len(dec.placements) > 0


def test_decide_honors_legacy_subclass_overrides():
    calls = []

    class LegacyDispatch(TimeSharingPolicy):
        def dispatch(self, submissions, context=None):
            calls.append("dispatch")
            return super().dispatch(submissions, context=context)

    class LegacyPlacements(TimeSharingPolicy):
        def placements(self, submissions, context=None):
            calls.append("placements")
            return super().placements(submissions, context=context)

    subs = [(a.binary, a.profile) for a in _trace(n=4, pods=(N_UNITS,))]
    with pytest.warns(DeprecationWarning):
        d1 = LegacyDispatch().decide(subs)
    with pytest.warns(DeprecationWarning):
        d2 = LegacyPlacements().decide(subs)
    assert calls == ["dispatch", "placements"]
    assert d1.schedule is not None and d2.schedule is not None
    assert len(d1.placements) == len(d2.placements) > 0
    assert isinstance(d1, DispatchDecision)


# ----------------------------------------------------------- refit guard

class _PairEverything(DispatchPolicy):
    """Pathological policy: pairs consecutive jobs onto a full-width
    two-slice MIG partition regardless of the serving pod — forcing the
    fleet's pod-width refit guard on narrow pods."""

    name = "pair_everything"

    def plan(self, queue):
        from repro.core.problem import Schedule
        sched = Schedule()
        half = Slice(N_UNITS // 2, (1.0,))
        pair = Partition((half, half), slice_label((half, half)))
        q = list(queue)
        while len(q) >= 2:
            sched.add([q.pop(0), q.pop(0)], pair)
        if q:
            from repro.core.partition import solo_partition
            sched.add([q.pop()], solo_partition())
        return sched


def test_overwide_placements_refit_to_narrow_pods():
    # a burst of 4-unit-hinted re-arrivals spreads over an (8, 4) fleet
    # under least-loaded routing; pairing two of them into an 8-unit MIG
    # partition cannot fit the 4-pod and must decompose
    from repro.online import Arrival
    base = ZOO[0]
    j4 = dataclasses.replace(base, name=base.name + "@u4",
                             meta={**base.meta, "units": 4})
    trace = [Arrival(t=0.0, binary="bin://j4", profile=j4)]
    trace += [Arrival(t=1e5 + 0.1 * k, binary="bin://j4", profile=j4)
              for k in range(12)]
    cfg = SimConfig(pods=(8, 4), router="least_loaded")
    res = ClusterSimulator(_PairEverything(), cfg).run(trace)
    assert res.refits > 0
    assert res.summary()["refits"] == res.refits
    for seg in res.timeline:          # decomposed placements still pod-local
        for start, w in seg.slices:
            assert start + w <= res.pods[seg.pod]
    assert all(not math.isnan(r.finish) for r in res.jobs)


# ------------------------------------------------- vectorized fleet parity

@pytest.mark.parametrize("pods", [(8, 8), HET])
def test_vectorized_fleet_matches_heap_fleet(pods):
    trace = _trace(n=100, seed=5, pods=pods)
    cfg = SimConfig(pods=pods, router="hash")
    heap = ClusterSimulator(TimeSharingPolicy(), cfg).run(trace)
    vec = VectorizedFleetSimulator(TimeSharingPolicy(), cfg,
                                   capacity=128).run(trace)
    assert [r.pod for r in heap.jobs] == [r.pod for r in vec.jobs]
    assert [r.units for r in heap.jobs] == [r.units for r in vec.jobs]
    assert [r.backfilled for r in heap.jobs] == \
           [r.backfilled for r in vec.jobs]
    assert heap.dispatches == vec.dispatches
    assert heap.backfills == vec.backfills
    for a, b in zip(heap.jobs, vec.jobs):
        assert b.dispatch == pytest.approx(a.dispatch, rel=1e-5, abs=1e-2)
        assert b.finish == pytest.approx(a.finish, rel=1e-5, abs=1e-2)
    assert vec.summary()["p99_wait_s"] == pytest.approx(
        heap.summary()["p99_wait_s"], rel=1e-5, abs=1e-2)


def test_vectorized_fleet_rejects_stateful_routers_and_other_policies():
    with pytest.raises(ValueError):
        VectorizedFleetSimulator(TimeSharingPolicy(),
                                 SimConfig(pods=HET, router="frag"))
    with pytest.raises(ValueError):
        VectorizedFleetSimulator(GreedyPackerPolicy(),
                                 SimConfig(pods=HET, router="hash"))
