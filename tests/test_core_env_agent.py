"""RL environment + agent invariants."""
import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import EnvConfig, make_zoo, validate_schedule
from repro.core.agent import DQNAgent, DQNConfig, _dqn_update
from repro.core.env import CoScheduleEnv
from repro.core.network import dqn_apply, init_dqn, masked_argmax

ZOO = make_zoo(dryrun_dir=None)


def _queue(n=6, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(ZOO), size=n, replace=False)
    return [ZOO[i] for i in idx]


@given(seed=st.integers(0, 2**30))
@settings(max_examples=15)
def test_env_random_episode_is_valid(seed):
    """Any mask-respecting action sequence terminates in a valid schedule."""
    env_cfg = EnvConfig(window=6, c_max=4)
    env = CoScheduleEnv(env_cfg)
    queue = _queue(6, seed)
    state, mask = env.reset(queue)
    rng = np.random.default_rng(seed)
    steps = 0
    while not env.done:
        assert mask.any(), "valid action must always exist"
        a = int(rng.choice(np.flatnonzero(mask)))
        state, r, done, mask, _ = env.step(a)
        assert np.isfinite(r)
        steps += 1
        assert steps < 100
    assert state.shape == (env.state_dim,)
    validate_schedule(queue, env.schedule, 4, enforce_solo_constraint=False)


def test_env_state_layout():
    env_cfg = EnvConfig(window=6, c_max=4)
    env = CoScheduleEnv(env_cfg)
    state, mask = env.reset(_queue(4))  # 2 padding slots
    s = state.reshape(6, -1)
    assert np.all(s[4:, env.n_features + 3] == 1.0)  # padding flag
    assert np.all(s[:4, env.n_features + 0] == 1.0)  # available flag
    # padded slots are never selectable
    assert not mask[4] and not mask[5]


def test_mask_forbids_oversized_groups():
    env_cfg = EnvConfig(window=6, c_max=2)
    env = CoScheduleEnv(env_cfg)
    _, mask = env.reset(_queue(6))
    env.step(0)
    _, _, _, mask, _ = env.step(1)
    # group is at c_max=2: no more job selections allowed
    assert not mask[: env.cfg.window].any()
    # only arity-2 partitions closable
    for i, p in enumerate(env.partitions):
        assert mask[env.cfg.window + i] == (p.arity == 2)


def test_masked_argmax():
    q = jnp.array([[1.0, 5.0, 3.0]])
    mask = jnp.array([[True, False, True]])
    assert int(masked_argmax(q, mask)[0]) == 2


def test_masked_argmax_tie_takes_lowest_valid_index():
    """Exact Q ties must resolve to the first valid action, deterministically."""
    q = jnp.array([[2.0, 7.0, 7.0, 7.0]])
    mask = jnp.array([[True, False, True, True]])
    assert int(masked_argmax(q, mask)[0]) == 2
    # all-equal rows: the first *valid* index wins
    q0 = jnp.zeros((1, 4))
    assert int(masked_argmax(q0, mask)[0]) == 0
    assert int(masked_argmax(q0, jnp.array([[False, False, True, True]]))[0]) == 2
    assert int(masked_argmax(q0, jnp.ones((1, 4), bool))[0]) == 0


def test_dqn_shapes_and_dueling():
    import jax

    params = init_dqn(jax.random.PRNGKey(0), 20, 7)
    q = dqn_apply(params, jnp.zeros((3, 20)))
    assert q.shape == (3, 7)
    # dueling head: mean-advantage subtraction -> adding a constant to A
    # leaves Q invariant; check V contributes uniformly
    q1 = dqn_apply(params, jnp.ones((1, 20)))
    assert bool(jnp.isfinite(q1).all())


def test_dqn_update_reduces_td_loss():
    cfg = DQNConfig(lr=1e-2)
    agent = DQNAgent(10, 4, cfg, seed=0)
    rng = np.random.default_rng(0)
    batch = {
        "s": jnp.asarray(rng.normal(size=(64, 10)), jnp.float32),
        "a": jnp.asarray(rng.integers(0, 4, 64), jnp.int32),
        "r": jnp.asarray(rng.normal(size=64), jnp.float32),
        "s2": jnp.asarray(rng.normal(size=(64, 10)), jnp.float32),
        "done": jnp.ones((64,), jnp.float32),   # terminal: y = r (fixed target)
        "mask2": jnp.ones((64, 4), bool),
    }
    params, opt = agent.params, agent.opt
    losses = []
    for _ in range(60):
        params, opt, loss = _dqn_update(params, agent.target_params, opt, batch, cfg)
        losses.append(float(loss))
    assert losses[-1] < 0.3 * losses[0], (losses[0], losses[-1])


def test_agent_act_respects_mask():
    agent = DQNAgent(10, 5, DQNConfig(eps_start=0.0, eps_end=0.0), seed=0)
    mask = np.array([False, True, False, True, False])
    for _ in range(10):
        a = agent.act(np.zeros(10, np.float32), mask)
        assert mask[a]


def test_greedy_act_does_not_advance_epsilon_schedule():
    """Evaluation (greedy) calls must not consume ε-decay env steps."""
    agent = DQNAgent(10, 5, DQNConfig(eps_decay_steps=100), seed=0)
    mask = np.ones(5, bool)
    eps0 = agent.epsilon
    for _ in range(20):
        agent.act(np.zeros(10, np.float32), mask, greedy=True)
    assert agent.env_steps == 0 and agent.epsilon == eps0
    agent.act(np.zeros(10, np.float32), mask)          # exploration step
    assert agent.env_steps == 1 and agent.epsilon < eps0


def test_replay_cycles():
    from repro.core.replay import ReplayBuffer

    rb = ReplayBuffer(8, 3, 2, seed=0)
    for i in range(20):
        rb.push(np.full(3, i, np.float32), 0, 1.0, np.zeros(3), False, np.ones(2, bool))
    assert len(rb) == 8
    batch = rb.sample(4)
    assert batch["s"].shape == (4, 3)
    assert batch["s"].max() >= 12  # only recent entries survive
