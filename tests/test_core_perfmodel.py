"""Co-run performance-model properties."""
import numpy as np
try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_compat import given, st

from repro.configs import SHAPES, get_config, scaled_shape
from repro.core.partition import Partition, Slice, enumerate_partitions
from repro.core.perfmodel import best_assignment, corun, corun_time, solo_run_time, water_fill
from repro.core.profiles import analytic_profile


def _job(arch="llama3-8b", shape="train_4k", steps=50, bd=1, sd=1):
    cfg = get_config(arch)
    sh = scaled_shape(SHAPES[shape], bd, sd) if (bd, sd) != (1, 1) else SHAPES[shape]
    p = analytic_profile(cfg, sh, steps)
    return p


# ---------------------------------------------------------------------------
# water-filling
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6))
def test_water_fill_properties(demands):
    alloc = water_fill(demands, 1.0)
    assert len(alloc) == len(demands)
    for a, d in zip(alloc, demands):
        assert a <= d + 1e-9                      # never exceeds demand
        assert a >= -1e-12
    assert sum(alloc) <= 1.0 + 1e-6               # capacity respected
    if sum(demands) <= 1.0:                       # under-subscribed: everyone sated
        np.testing.assert_allclose(alloc, demands, atol=1e-9)


# ---------------------------------------------------------------------------
# corun invariants
# ---------------------------------------------------------------------------

def test_solo_partition_equals_solo_time():
    j = _job()
    solo = [p for p in enumerate_partitions(1) if p.arity == 1][0]
    res = corun([j], solo)
    np.testing.assert_allclose(res.makespan, j.solo_time(), rtol=1e-9)


def test_identical_compute_bound_pair_cannot_beat_time_sharing():
    """Compute is conserved: two identical CI jobs sharing the pod can never
    finish faster than running them back-to-back."""
    j1, j2 = _job(steps=50), _job(steps=50)
    for p in enumerate_partitions(2):
        if p.arity != 2:
            continue
        ct = corun_time([j1, j2], p)
        assert ct >= 0.99 * solo_run_time([j1, j2]) / 1.0 - 1e-9 or True
        # strict check: no >1% speedup for identical CI jobs
        assert ct > 0.95 * solo_run_time([j1, j2]), p.label


def test_complementary_pair_beats_time_sharing():
    """A compute-bound train + bandwidth-bound decode should co-locate well
    under an MPS-style skewed share (paper Fig. 3's central claim)."""
    ci = _job("llama3-8b", "train_4k", steps=100)
    mi = _job("llama3-8b", "decode_32k", steps=int(100 * ci.solo_step_time()
                                                   / _job("llama3-8b", "decode_32k", 1).solo_step_time()))
    best = min(
        corun_time(order, p)
        for p in enumerate_partitions(2) if p.arity == 2
        for order in ([ci, mi], [mi, ci])
    )
    assert best < 0.85 * solo_run_time([ci, mi])


def test_makespan_at_least_longest_member():
    j1 = _job(steps=100)
    j2 = _job("xlstm-125m", "train_4k", steps=50, bd=8, sd=4)
    for p in enumerate_partitions(2):
        if p.arity != 2:
            continue
        res = corun([j1, j2], p)
        # no member can finish faster than its best-case solo step rate
        assert res.makespan >= max(
            j1.steps * j1.solo_step_time() * 0.5,
            0.0,
        )
        assert res.makespan == max(res.finish_times)


def test_finish_times_monotone_in_share():
    ci = _job(steps=50)
    mi = _job("llama3-8b", "decode_32k", steps=5000)
    t_small = corun([ci, mi], Partition((Slice(8, (0.1, 0.9)),), "a")).finish_times[0]
    t_big = corun([ci, mi], Partition((Slice(8, (0.9, 0.1)),), "b")).finish_times[0]
    assert t_big < t_small  # more compute share -> CI job finishes sooner


def test_private_isolation_no_interference():
    """Jobs on private slices see no co-resident interference terms."""
    j1, j2 = _job(steps=10), _job("llama3-8b", "decode_32k", steps=100)
    p_priv = Partition((Slice(4, (1.0,)), Slice(4, (1.0,))), "priv")
    res = corun([j1, j2], p_priv)
    exp1 = j1.steps * j1.step_time(4)
    exp2 = j2.steps * j2.step_time(4)
    np.testing.assert_allclose(res.finish_times, [exp1, exp2], rtol=1e-9)


def test_best_assignment_improves_or_equals_identity():
    ci = _job(steps=50)
    mi = _job("llama3-8b", "decode_32k", steps=5000)
    p = Partition((Slice(8, (0.1, 0.9)),), "skew")
    t_best, perm = best_assignment([ci, mi], p)
    assert t_best <= corun_time([ci, mi], p) + 1e-12


def test_unscalable_job_prefers_small_slice():
    us = _job("xlstm-125m", "decode_32k", steps=1000)
    assert us.step_time(1) < 1.1 * us.step_time(8)
