"""Test session setup: lock jax to the default 1-device CPU backend early so
any later import that touches XLA_FLAGS (e.g. repro.launch.dryrun helpers)
cannot change the device count, and keep hypothesis CI-friendly.

Hypothesis is optional: when it is absent the profile registration is
skipped and test modules fall back to the deterministic shim in
``_hypothesis_compat`` — the suite must never abort at collection because
of a missing dev dependency."""
import jax

jax.devices()  # initialize backend now (1 CPU device)

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    pass
else:
    settings.register_profile(
        "ci",
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")
