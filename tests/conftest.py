"""Test session setup: lock jax to the default 1-device CPU backend early so
any later import that touches XLA_FLAGS (e.g. repro.launch.dryrun helpers)
cannot change the device count, and keep hypothesis CI-friendly."""
import jax
from hypothesis import HealthCheck, settings

jax.devices()  # initialize backend now (1 CPU device)

settings.register_profile(
    "ci",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")
