"""Minimal stand-in for hypothesis so property tests run without the dep.

The container does not ship ``hypothesis``; importing it at module scope
used to abort the whole tier-1 suite at collection.  Test modules import
through this shim::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

The shim implements just the surface this repo uses — ``@given`` with
keyword strategies, ``@settings(max_examples=...)``, and the ``integers``,
``floats``, ``booleans``, ``sampled_from``, ``lists``, ``tuples``, and
``composite`` strategies — drawing examples from a deterministic per-test
RNG.  No shrinking, no database; each example is drawn from its own
``(test-name-crc32, index)``-seeded RNG so a failure report names both
the drawn values and the exact seed pair that regenerates them.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    @staticmethod
    def composite(fn):
        """``@st.composite`` lookalike: ``fn(draw, *args)`` becomes a
        strategy factory, where ``draw(strategy)`` samples sub-strategies
        from the enclosing example's RNG (the idiom tests/strategies.py
        builds its generators on)."""
        @functools.wraps(fn)
        def factory(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs))
        return factory


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"


class settings:  # noqa: N801 — decorator + profile API lookalike
    def __init__(self, max_examples=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._compat_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        pass

    @classmethod
    def load_profile(cls, name):
        pass


def given(*pos_strategies, **strategies):
    def deco(fn):
        if pos_strategies:
            # hypothesis maps positional strategies onto the rightmost params
            import inspect

            names = list(inspect.signature(fn).parameters)
            mapped = dict(zip(names[len(names) - len(pos_strategies):],
                              pos_strategies))
            assert not (set(mapped) & set(strategies))
            strategies.update(mapped)

        def wrapper():
            # zero-arg signature: pytest must not mistake drawn params
            # for fixtures.  @settings may sit above @given (stamping the
            # wrapper) or below it (stamping fn) — honor both orders.
            n = (getattr(wrapper, "_compat_max_examples", None)
                 or getattr(fn, "_compat_max_examples", None)
                 or DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__name__.encode())
            for i in range(n):
                # one RNG per example: a failure is reproducible from the
                # reported (base, i) pair alone, without replaying the
                # preceding examples' draws
                rng = np.random.default_rng((base, i))
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i} "
                        f"(np.random.default_rng(({base}, {i}))): "
                        f"{drawn!r}") from e
        functools.update_wrapper(wrapper, fn, updated=())
        del wrapper.__wrapped__             # keep pytest off fn's signature
        return wrapper
    return deco
