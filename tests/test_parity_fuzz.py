"""Property fuzz: heap-vs-vectorized parity for RL and time-sharing plans.

The in-graph RL serving seam (``vecsim._build_run_rl``) claims *decision
level* equality with the heap reference: same groups, same partitions,
same fit/fallback/refit outcomes, same backfill jumps, same record
attribution — times to f32 resolution.  This suite fuzzes that claim
across randomized traces x fleets x windows, plus the adversarial
same-instant / duplicate-tenant shapes where attribution is only pinned
by ``_form_window``'s name-keyed FIFO.  A failing example's report names
the drawn spec and the RNG seed pair that regenerates it (see
``_hypothesis_compat``); ``adversarial_traces`` failures print the trace
itself — it is already minimal (a handful of bursts).

Strictness caveat: fuzzing runs profile-only agents
(``obs_context=False``).  The context block is computed in f64 on the
heap and f32 in-graph, so a context-aware agent may flip a near-tie
action legitimately; the fixed-seed ``test_obs_context_parity`` covers
that mode on known-good seeds instead.

Engines are cached per configuration (window/backfill/topology) and all
examples share one random-init agent, so the jit compile count stays
bounded across examples.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from strategies import (
    ZOO, adversarial_traces, assert_parity, engine_knobs, fleet_topologies,
    make_trace, trace_specs,
)

from repro.core.agent import DQNAgent
from repro.core.env import CoScheduleEnv, EnvConfig
from repro.core.network import greedy_q_action
from repro.core.partition import N_UNITS
from repro.online import (
    ClusterSimulator, SimConfig, TimeSharingPolicy,
    VectorizedClusterSimulator, VectorizedFleetSimulator,
)
from repro.online.policies import RLDispatchPolicy

ENV_CFG = EnvConfig()                      # profile-only: strict parity
_ENV = CoScheduleEnv(ENV_CFG)
_AGENT = DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0)


def _rl_policy(env_cfg=ENV_CFG):
    """Fresh policy per heap run: the profile repository fills as jobs
    run, so reuse would leak first-sight state across examples.  The
    in-graph engine starts every run with an empty ``profiled`` lane, so
    its (cached) wrapper instance is safe to share."""
    return RLDispatchPolicy(DQNAgent(_ENV.state_dim, _ENV.n_actions, seed=0),
                            env_cfg)


_ENGINES: dict = {}


def _vec_rl(window=8, backfill=True, capacity=96):
    key = ("rl", window, backfill, capacity)
    if key not in _ENGINES:
        _ENGINES[key] = VectorizedClusterSimulator(
            _rl_policy(), window=window, backfill=backfill,
            capacity=capacity)
    return _ENGINES[key]


def _vec_ts(window=8, backfill=True, capacity=96):
    key = ("ts", window, backfill, capacity)
    if key not in _ENGINES:
        _ENGINES[key] = VectorizedClusterSimulator(
            TimeSharingPolicy(), window=window, backfill=backfill,
            capacity=capacity)
    return _ENGINES[key]


def _vec_fleet(pods, window=8, capacity=96):
    key = ("fleet", pods, window, capacity)
    if key not in _ENGINES:
        _ENGINES[key] = VectorizedFleetSimulator(
            _rl_policy(), SimConfig(pods=pods, window=window, router="hash"),
            capacity=capacity)
    return _ENGINES[key]


# --------------------------------------------------------- single-pod RL

@settings(max_examples=8, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=40))
def test_rl_parity_randomized_traces(spec):
    trace = make_trace(*spec)
    h = ClusterSimulator(_rl_policy(), window=8).run(trace)
    assert_parity(h, _vec_rl().run(trace))


@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=30), knobs=engine_knobs())
def test_rl_parity_window_backfill_knobs(spec, knobs):
    window, backfill = knobs
    trace = make_trace(*spec)
    h = ClusterSimulator(_rl_policy(), window=window,
                         backfill=backfill).run(trace)
    assert_parity(h, _vec_rl(window=window, backfill=backfill).run(trace))


@settings(max_examples=8, deadline=None, derandomize=True)
@given(trace=adversarial_traces())
def test_rl_parity_adversarial_duplicate_tenants(trace):
    """Same-instant duplicate-tenant bursts: record attribution must
    follow the heap's name-keyed FIFO, not the agent's row choice."""
    h = ClusterSimulator(_rl_policy(), window=8).run(trace)
    assert_parity(h, _vec_rl().run(trace))


@settings(max_examples=8, deadline=None, derandomize=True)
@given(trace=adversarial_traces())
def test_ts_parity_adversarial_duplicate_tenants(trace):
    h = ClusterSimulator(TimeSharingPolicy(), window=8).run(trace)
    assert_parity(h, _vec_ts().run(trace))


# -------------------------------------------------------------- fleet RL

@settings(max_examples=6, deadline=None, derandomize=True)
@given(spec=trace_specs(max_n=40), pods=fleet_topologies(max_pods=3))
def test_rl_fleet_parity(spec, pods):
    trace = make_trace(*spec, capacity=sum(pods) / N_UNITS)
    cfg = SimConfig(pods=pods, window=8, router="hash")
    h = ClusterSimulator(_rl_policy(), cfg).run(trace)
    assert_parity(h, _vec_fleet(pods).run(trace))


# --------------------------------------------- context-aware (fixed seed)

def test_obs_context_parity():
    """Context-aware agents see an f32 context in-graph vs f64 on the
    heap, so parity is seed-level, not universal: pin known-good seeds."""
    cfg = EnvConfig(obs_context=True)
    env = CoScheduleEnv(cfg)

    def policy():
        return RLDispatchPolicy(
            DQNAgent(env.state_dim, env.n_actions, seed=0), cfg)

    vec = VectorizedClusterSimulator(policy(), window=8, capacity=96)
    for seed in (0, 1, 2):
        trace = make_trace("poisson", 30, seed, 1.3)
        h = ClusterSimulator(policy(), window=8).run(trace)
        assert_parity(h, vec.run(trace))


# ------------------------------------------------------- greedy-Q parity

@settings(max_examples=15, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000))
def test_greedy_q_matches_agent_act_on_random_obs(seed):
    """The in-graph forward (``greedy_q_action``) and the heap agent's
    greedy ``act`` pick identical actions on identical observations."""
    rng = np.random.default_rng(seed)
    obs = rng.standard_normal(_ENV.state_dim).astype(np.float32)
    mask = rng.random(_ENV.n_actions) < 0.4
    mask[rng.integers(_ENV.n_actions)] = True      # never empty
    a_heap = _AGENT.act(obs, mask, greedy=True)
    a_graph = int(greedy_q_action(_AGENT.params, obs, mask))
    assert a_heap == a_graph


def test_greedy_q_matches_agent_act_on_env_observations():
    """Same equivalence on *real* episode observations: drive a
    CoScheduleEnv queue with the agent's greedy policy and check every
    step's action against the in-graph forward."""
    queue = [ZOO[i % len(ZOO)] for i in range(6)]
    obs, mask = _ENV.reset(queue)
    steps = 0
    while not _ENV.done and steps < 2 * ENV_CFG.window:
        a = _AGENT.act(obs, mask, greedy=True)
        assert a == int(greedy_q_action(_AGENT.params, obs, mask))
        obs, _r, _d, mask, _ = _ENV.step(a)
        steps += 1
    assert _ENV.done
