"""End-to-end training driver: real config system, data pipeline, AdamW,
fault-tolerant checkpointing, auto-resume.

Default runs a CPU-sized model; ``--model-scale 100m`` trains a ~100M-param
decoder (the deliverable-scale run — give it a beefier machine or TPU):

    PYTHONPATH=src python examples/train_lm.py --steps 300 --model-scale 100m
    PYTHONPATH=src python examples/train_lm.py --steps 60            # CPU demo
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_smoke_config
from repro import checkpoint as ck
from repro.data import DataPipeline
from repro.models.model import count_params_analytic, init_params, loss_fn
from repro.optim import OptConfig, adamw_update, init_opt_state


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=32_000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--model-scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="experiments/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = model_100m() if args.model_scale == "100m" else \
        get_smoke_config("llama3-8b").replace(n_layers=4, d_model=128, d_ff=512,
                                              n_heads=4, n_kv_heads=2, d_head=32,
                                              vocab_size=2048)
    n = count_params_analytic(cfg)
    print(f"model {cfg.name}: {n/1e6:.1f}M params, seq={args.seq}, batch={args.batch}")

    pipe = DataPipeline(cfg.vocab_size, args.seq, args.batch, seed=0, mode="markov")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, decay_steps=max(100, args.steps))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start = 0
    try:  # auto-resume from the last committed checkpoint
        tree, extra, start = ck.restore(args.ckpt_dir)
        params, opt = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
        opt["count"] = jnp.asarray(opt["count"], jnp.int32)
        print(f"resumed from step {start}")
    except FileNotFoundError:
        pass

    @jax.jit
    def step(params, opt, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
        params, opt, om = adamw_update(params, grads, opt, opt_cfg)
        metrics.update(om)
        return params, opt, metrics

    t0 = time.time()
    tokens_done = 0
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt, metrics = step(params, opt, batch)
        tokens_done += args.seq * args.batch
        if s % 10 == 0 or s == args.steps - 1:
            dt = time.time() - t0
            print(f"step {s:4d} loss={float(metrics['loss']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tokens_done/max(dt,1e-9):,.0f}")
        if (s + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, s + 1, {"params": params, "opt": opt})
            print(f"  checkpoint @ {s+1}")
    print("train_lm done")


if __name__ == "__main__":
    main()
