"""The paper's full pipeline, end to end:

  1. build the job zoo (profiles from dry-run artifacts when present),
  2. offline-train the dueling double-DQN co-scheduler,
  3. schedule queues online and compare against the baselines,
  4. EXECUTE one co-scheduled group for real with the Level-2 fused-program
     executor (tiny models, CPU) and show the measured vs predicted gain.

    PYTHONPATH=src python examples/co_schedule.py [--episodes 1500]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import (
    EnvConfig, POLICIES, RLScheduler, TrainConfig, make_zoo, paper_queues,
    summarize, train_agent, validate_schedule,
)
from repro.core.agent import DQNConfig
from repro.data import DataPipeline
from repro.models.model import init_params, loss_fn
from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.runtime.multitenant import FusedCoRunner, Tenant


def make_tiny_train_tenant(name: str, arch: str, share: float, seq=32, batch=4) -> Tenant:
    cfg = get_smoke_config(arch)
    pipe = DataPipeline(cfg.vocab_size, seq, batch, seed=hash(name) % 2**31)
    params = init_params(cfg, jax.random.PRNGKey(hash(name) % 2**31))
    opt = init_opt_state(params)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, decay_steps=1000)
    batch0 = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

    def step(state):
        params, opt = state
        (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch0, cfg)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return (params, opt)

    return Tenant(name, step, (params, opt), share)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=1500)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--batch-envs", type=int, default=16,
                    help="parallel envs in the scanned training engine")
    args = ap.parse_args()

    # 1-2: offline profiling + RL training (vectorized jit-scanned engine)
    zoo = make_zoo()
    print(f"zoo: {len(zoo)} jobs")
    t0 = time.time()
    env_cfg = EnvConfig(window=args.window, c_max=4)
    agent, hist = train_agent(zoo, env_cfg,
                              TrainConfig(episodes=args.episodes,
                                          eval_every=args.episodes // 4,
                                          batch_envs=args.batch_envs,
                                          dqn=DQNConfig(eps_decay_steps=args.episodes * 6)),
                              verbose=True)
    print(f"offline training: {time.time()-t0:.0f}s")

    # 3: online scheduling vs baselines
    sched = RLScheduler(agent, env_cfg)
    queues = paper_queues(zoo, window=args.window, per_kind=1)
    print(f"{'queue':6s} {'time_sharing':>12s} {'mps_only':>9s} {'rl':>7s} {'oracle':>7s}")
    for qname, queue in queues.items():
        s_rl = sched.schedule(queue)
        validate_schedule(queue, s_rl, env_cfg.c_max)
        row = [summarize(POLICIES["time_sharing"](queue, 4))["throughput"],
               summarize(POLICIES["mps_only"](queue, 4))["throughput"],
               summarize(s_rl)["throughput"],
               summarize(POLICIES["oracle"](queue, 4))["throughput"]]
        print(f"{qname:6s} {row[0]:12.3f} {row[1]:9.3f} {row[2]:7.3f} {row[3]:7.3f}")

    # 4: execute one co-scheduled pair with the fused Level-2 executor
    print("\nexecuting a co-scheduled pair (fused program, shares 0.75/0.25):")
    tenants = [make_tiny_train_tenant("llama-train", "llama3-8b", 0.75),
               make_tiny_train_tenant("xlstm-train", "xlstm-125m", 0.25)]
    runner = FusedCoRunner(tenants, {"llama-train": 24, "xlstm-train": 8})
    finish = runner.run()
    print({k: f"{v:.2f}s" for k, v in finish.items()})
    print("co_schedule OK")


if __name__ == "__main__":
    main()
