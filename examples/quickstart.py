"""Quickstart: build a reduced model from the registry, train a few steps,
then decode from it.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataPipeline
from repro.models.model import decode_step, init_cache, init_params, loss_fn
from repro.optim import OptConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"arch={cfg.name} d_model={cfg.d_model} layers={cfg.n_layers} vocab={cfg.vocab_size}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=args.steps * 2)
    pipe = DataPipeline(cfg.vocab_size, 64, 8, seed=0, mode="markov")

    @jax.jit
    def step(params, opt, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)
        params, opt, om = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, metrics["loss"]

    t0 = time.time()
    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, opt, loss = step(params, opt, batch)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d} loss {float(loss):.3f} ({time.time()-t0:.1f}s)")

    # greedy decode 16 tokens from a prompt
    B, prompt_len, gen = 2, 4, 16
    prompt = pipe.batch(999)["tokens"][:B, :prompt_len]
    cache = init_cache(params, cfg, B, prompt_len + gen)
    dstep = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))
    tok = jnp.asarray(prompt[:, 0])
    out = [tok]
    for t in range(prompt_len + gen - 1):
        logits, cache = dstep(params, cache, tok, jnp.full((B,), t))
        tok = jnp.asarray(prompt[:, t + 1]) if t + 1 < prompt_len else jnp.argmax(logits, -1)
        out.append(tok)
    seqs = jnp.stack(out, 1)
    print("decoded:", seqs[0].tolist())
    print("quickstart OK")


if __name__ == "__main__":
    main()
