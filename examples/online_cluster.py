"""Online cluster serving, end to end (paper §IV-B under live traffic):

  1. offline-train the co-scheduling agent on the job zoo,
  2. generate a multi-tenant arrival trace (Poisson / bursty / diurnal /
     heavy-tailed job scales),
  3. serve the same trace with time sharing, the greedy packer, and the RL
     scheduler — the RL run periodically re-trains against the live profile
     repository (MISO-style) and hot-swaps the refreshed agent,
  4. compare makespan-derived throughput, waits, turnaround, and slice-level
     packing (slice utilization, backfills), and show the slice-occupancy
     timeline of the first RL dispatches.

Groups run concurrently on disjoint slice ranges (EASY backfill included);
pick ``--trace fragmented`` to see right-sized 1-unit mice pack around
full-pod jobs, or ``--blocking`` for the whole-pod PR-3 dispatch mode.
``--context`` trains the agent on the arrival-aware observation (profiles
+ busy-unit mask + queue ages + pending depth — docs/observation.md) and
the simulator then feeds it the real cluster snapshot at every dispatch
window.  ``--pods 8,8,4,4 --router frag`` serves the trace on a
heterogeneous four-pod fleet instead of one pod — each arrival is routed
to a pod at its arrival instant, then dispatched by the unchanged
per-pod path (``--pods 8`` is the single-pod default, bit-compatible
with earlier PRs).

The RL run records the full telemetry event stream
(docs/observability.md): the dispatch timeline printed at the end is
read back from its ``place`` events, and ``--trace-out trace.json``
writes the same stream as a Chrome-trace file — load it in
https://ui.perfetto.dev to scrub the per-pod, per-slice-unit occupancy
tracks interactively.

    PYTHONPATH=src python examples/online_cluster.py [--trace fragmented]
"""
import argparse
import time

from repro.core import EnvConfig, TrainConfig, make_zoo, train_agent
from repro.core.agent import DQNConfig
from repro.online import (
    ClusterSimulator, GreedyPackerPolicy, OnlineRetrainer, RLDispatchPolicy,
    ROUTERS, SimConfig, TRACE_FAMILIES, Telemetry, TimeSharingPolicy,
    default_retrain_train_config,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=800)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--arrivals", type=int, default=80)
    ap.add_argument("--trace", choices=sorted(TRACE_FAMILIES), default="poisson")
    ap.add_argument("--load", type=float, default=1.25)
    ap.add_argument("--retrain-interval-min", type=float, default=30.0)
    ap.add_argument("--blocking", action="store_true",
                    help="PR-3 whole-pod block dispatch (no concurrency)")
    ap.add_argument("--context", action="store_true",
                    help="arrival-aware observation: train with sampled "
                         "cluster-state contexts and serve with the "
                         "simulator's real dispatch snapshots")
    ap.add_argument("--pods", default="8",
                    help="comma-separated slice widths, one per pod "
                         "(e.g. 8,8,4,4); the default single 8 is the "
                         "classic one-pod cluster")
    ap.add_argument("--router", choices=sorted(ROUTERS), default="hash",
                    help="fleet router assigning each arrival a pod")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="write the RL run's lifecycle events as a "
                         "Chrome-trace JSON (open in Perfetto / "
                         "chrome://tracing): one track per pod x slice "
                         "unit")
    args = ap.parse_args()
    mode = "blocking" if args.blocking else "concurrent"
    pods = tuple(int(w) for w in args.pods.split(","))

    zoo = make_zoo()
    env_cfg = EnvConfig(window=args.window, c_max=4, obs_context=args.context)
    feats = "profiles + cluster state" if args.context else "profiles only"
    print(f"zoo: {len(zoo)} jobs — offline training ({args.episodes} episodes, "
          f"observing {feats})")
    t0 = time.time()
    agent, hist = train_agent(
        zoo, env_cfg,
        TrainConfig(episodes=args.episodes, eval_every=args.episodes // 2,
                    dqn=DQNConfig(eps_decay_steps=args.episodes * 6)))
    print(f"trained in {time.time()-t0:.0f}s: train_tp="
          f"{hist[-1]['eval_throughput']:.3f} "
          f"heldout_tp={hist[-1]['heldout_throughput']:.3f}")

    fleet_cap = sum(pods) / max(pods)       # full-pod equivalents
    trace = TRACE_FAMILIES[args.trace](zoo, n=args.arrivals, load=args.load,
                                       seed=0, capacity=fleet_cap)
    print(f"\ntrace '{args.trace}': {len(trace)} arrivals over "
          f"{trace[-1].t/3600:.2f} simulated hours (load {args.load}, "
          f"fleet {pods} via '{args.router}' router)")

    def cfg(tick=None):
        return SimConfig(window=args.window, mode=mode, pods=pods,
                         router=args.router, tick_interval_s=tick)

    results = {}
    results["time_sharing"] = ClusterSimulator(
        TimeSharingPolicy(), cfg()).run(trace)
    results["greedy_packer"] = ClusterSimulator(
        GreedyPackerPolicy(), cfg()).run(trace)
    pol = RLDispatchPolicy(agent, env_cfg)
    retrainer = OnlineRetrainer(
        policy=pol, train_cfg=default_retrain_train_config(240),
        interval_s=args.retrain_interval_min * 60.0)
    tel = Telemetry()
    results["rl+retrain"] = ClusterSimulator(
        pol, cfg(tick=retrainer.interval_s), on_tick=retrainer,
        telemetry=tel).run(trace)

    ts = results["time_sharing"].throughput
    print(f"\n{'policy':14s} {'throughput':>10s} {'vs_ts':>6s} "
          f"{'makespan_h':>10s} {'mean_wait_m':>11s} {'p99_wait_m':>10s} "
          f"{'slice_util':>10s} {'backfills':>9s}")
    for name, r in results.items():
        print(f"{name:14s} {r.throughput:10.3f} {r.throughput/ts:6.3f} "
              f"{r.makespan/3600:10.2f} {r.mean_wait/60:11.1f} "
              f"{r.p99_wait/60:10.1f} {r.slice_utilization:10.3f} "
              f"{r.backfills:9d}")

    print(f"\nre-training cycles: {len(retrainer.history)}")
    for h in retrainer.history:
        print(f"  t={h['t_s']/60:6.0f}min repo={h['repository_jobs']:3d} jobs "
              f"{h['class_counts']} train_tp={h['train_eval_throughput']:.3f}")

    # the slice-occupancy timeline now comes from the telemetry event
    # stream — the same "place" events a --trace-out file visualizes
    print("\nfirst RL dispatches (slice occupancy, from telemetry events):")
    for e in sorted(tel.recorder.by_kind("place"),
                    key=lambda e: (e["t_s"], e["pod"], e["slices"]))[:10]:
        units = ",".join(f"{st}-{st + w - 1}" for st, w in e["slices"])
        where = f"pod{e['pod']} units {units:9s}" if len(pods) > 1 \
            else f"units {units:9s}"
        bf = " (backfilled)" if e["backfilled"] else ""
        print(f"  [{e['t_s']:8.0f}s -> {e['t1_s']:8.0f}s] {where} "
              f"{len(e['jobs'])} job(s) on {e['partition']}{bf}")

    if args.trace_out:
        tel.recorder.write_chrome_trace(args.trace_out, pods=pods)
        print(f"\nwrote {len(tel.recorder)} lifecycle events to "
              f"{args.trace_out} (load in https://ui.perfetto.dev or "
              f"chrome://tracing)")
    print("online_cluster OK")


if __name__ == "__main__":
    main()
