"""Serving example: prefill a batch of prompts, then batched greedy decode
with the KV-cache serve step (the decode_32k cell's code path, CPU-sized).

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen2.5-14b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataPipeline
from repro.models.model import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(cfg.vocab_size, args.prompt_len + args.gen, args.batch, seed=1)
    prompts = jnp.asarray(pipe.batch(0)["tokens"][:, : args.prompt_len])

    t0 = time.time()
    logits, cache = jax.jit(lambda p, t: prefill(p, t, cfg, args.prompt_len + args.gen))(
        params, prompts)
    # grow the cache to the full horizon (prefill built it at prompt length)
    pad = args.gen
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, pad)] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 4 else a, cache)
    print(f"prefill: {prompts.shape} in {time.time()-t0:.2f}s")

    dstep = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))
    tok = jnp.argmax(logits, -1)
    toks = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i)
        logits, cache = dstep(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1)
        toks.append(tok)
    dt = time.time() - t1
    out = jnp.stack(toks, 1)
    print(f"decoded {args.gen-1} steps x {args.batch} seqs in {dt:.2f}s "
          f"({(args.gen-1)*args.batch/dt:.1f} tok/s)")
    print("sample:", out[0].tolist()[:16])
    print("serve_decode OK")


if __name__ == "__main__":
    main()
